"""L2: the paper's sparse MLP forward/backward as a JAX compute graph.

Equations (2)-(4) of the paper, with every junction's FF / BP / UP routed
through the Pallas kernels via jax.custom_vjp — autodiff never opens the
kernels, so the lowered HLO contains exactly the three hardware operations
per junction, sharing one weight buffer, as in Fig. 3.

The pre-defined sparsity contract: masks are inputs held fixed; masked
FF plus the mask-multiplied UP gradient guarantee excluded weights remain
identically zero through training (they start zero and receive zero
update), so training complexity scales with |W_i| on hardware that skips
the zeros (the Rust hw/ simulator and the gather kernels), and the
dense-masked form here stays numerically identical to it.
"""

import jax
import jax.numpy as jnp

from .kernels import gather as gather_kernels
from .kernels import junction as junction_kernels


@jax.custom_vjp
def masked_linear(a, w, b, mask):
    """h = a @ (w*mask)^T + b with FF/BP/UP each a Pallas kernel."""
    return junction_kernels.junction_ff(a, w, mask, b)


def _masked_linear_fwd(a, w, b, mask):
    return junction_kernels.junction_ff(a, w, mask, b), (a, w, mask)


def _masked_linear_bwd(res, g):
    a, w, mask = res
    da = junction_kernels.junction_bp(g, w, mask)  # eq. (3b) inner sum
    dw, db = junction_kernels.junction_up(a, g, mask)  # eq. (4b)
    return da, dw, db, jnp.zeros_like(mask)


masked_linear.defvjp(_masked_linear_fwd, _masked_linear_bwd)


def init_params(layers, key, bias_init=0.1):
    """He initialization [45] for weights; constant bias (paper Sec. IV-A)."""
    params = []
    for i in range(1, len(layers)):
        key, sub = jax.random.split(key)
        std = jnp.sqrt(2.0 / layers[i - 1])
        w = jax.random.normal(sub, (layers[i], layers[i - 1]), jnp.float32) * std
        b = jnp.full((layers[i],), bias_init, jnp.float32)
        params.append((w, b))
    return params


def forward(params, masks, x):
    """Eq. (2): ReLU hidden layers, linear (pre-softmax) output layer."""
    a = x
    n_junctions = len(params)
    for i, ((w, b), mask) in enumerate(zip(params, masks)):
        h = masked_linear(a, w, b, mask)
        a = h if i == n_junctions - 1 else jax.nn.relu(h)
    return a


def gather_forward(wcs, idxs, biases, x):
    """Inference over compacted structured-sparse storage (gather kernel)."""
    a = x
    n_junctions = len(wcs)
    for i, (wc, idx, b) in enumerate(zip(wcs, idxs, biases)):
        h = gather_kernels.gather_ff(a, wc, idx, b)
        a = h if i == n_junctions - 1 else jax.nn.relu(h)
    return a


def loss_and_metrics(params, masks, x, y, l2):
    """Softmax cross-entropy + L2 penalty on the *connected* weights only."""
    logits = forward(params, masks, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    penalty = sum(jnp.sum((w * m) ** 2) for (w, _), m in zip(params, masks))
    correct = (jnp.argmax(logits, axis=-1) == y).sum().astype(jnp.float32)
    return ce + l2 * penalty, (ce, correct)


def adam_step(p, g, m, v, t, lr, beta1=0.9, beta2=0.999, eps=1e-8, decay=1e-5):
    """Adam [46] with the paper's lr decay (Sec. IV-A: decay = 1e-5)."""
    lr_t = lr / (1.0 + decay * (t - 1.0))
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    return p - lr_t * mhat / (jnp.sqrt(vhat) + eps), m, v


def train_step(params, opt_m, opt_v, masks, x, y, t, lr, l2):
    """One minibatch step. Returns (params', m', v', t+1, ce_loss, correct).

    Masks enter the gradient twice: through masked_linear's custom VJP
    (dW pre-masked by the UP kernel) and through the L2 penalty (also
    masked), so the Adam state of excluded edges stays exactly zero.
    """
    grad_fn = jax.value_and_grad(loss_and_metrics, has_aux=True)
    (_, (ce, correct)), grads = grad_fn(params, masks, x, y, l2)
    new_params, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(params, grads, opt_m, opt_v):
        w2, mw2, vw2 = adam_step(w, gw, mw, vw, t, lr)
        b2, mb2, vb2 = adam_step(b, gb, mb, vb, t, lr)
        new_params.append((w2, b2))
        new_m.append((mw2, mb2))
        new_v.append((vw2, vb2))
    return new_params, new_m, new_v, t + 1.0, ce, correct


# ---------------------------------------------------------------------------
# Flat-signature wrappers: the AOT boundary. The Rust runtime passes/receives
# positional f32/i32 literals; order is defined here and recorded in the
# manifest by aot.py (inputs: L x (w, b), 4L opt state, L masks, x, y, t,
# lr, l2 — outputs: the updated counterparts + scalars).
# ---------------------------------------------------------------------------


def _unflatten(args, n_junctions):
    pairs = lambda off: [(args[off + 2 * i], args[off + 2 * i + 1]) for i in range(n_junctions)]
    params = pairs(0)
    opt_m = pairs(2 * n_junctions)
    opt_v = pairs(4 * n_junctions)
    off = 6 * n_junctions
    masks = list(args[off : off + n_junctions])
    x, y, t, lr, l2 = args[off + n_junctions : off + n_junctions + 5]
    return params, opt_m, opt_v, masks, x, y, t, lr, l2


def flat_train_step(n_junctions, *args):
    params, opt_m, opt_v, masks, x, y, t, lr, l2 = _unflatten(args, n_junctions)
    new_params, new_m, new_v, t2, ce, correct = train_step(
        params, opt_m, opt_v, masks, x, y, t, lr, l2
    )
    out = []
    for group in (new_params, new_m, new_v):
        for w, b in group:
            out.extend((w, b))
    out.extend((t2, ce, correct))
    return tuple(out)


def flat_forward(n_junctions, *args):
    params = [(args[2 * i], args[2 * i + 1]) for i in range(n_junctions)]
    masks = list(args[2 * n_junctions : 3 * n_junctions])
    x = args[3 * n_junctions]
    return (forward(params, masks, x),)


def flat_gather_forward(n_junctions, *args):
    wcs = args[0:n_junctions]
    idxs = args[n_junctions : 2 * n_junctions]
    biases = args[2 * n_junctions : 3 * n_junctions]
    x = args[3 * n_junctions]
    return (gather_forward(wcs, idxs, biases, x),)
