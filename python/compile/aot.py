"""AOT compile path: lower the L2 graphs once to HLO *text* artifacts.

HLO text (not HloModuleProto.serialize) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Emits, per named config:
  artifacts/<name>_train.hlo.txt     flat_train_step
  artifacts/<name>_forward.hlo.txt   flat_forward
plus artifacts/<name>_gather<d..>_forward.hlo.txt for configs with a
canonical structured d_out (compacted-weight inference path), and a
single artifacts/manifest.json describing every input/output literal so
the Rust runtime can marshal positionally without guessing.

Python runs exactly once (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# name -> (layer sizes, batch, canonical gather d_out or None)
#
# The layer sizes mirror the paper's N_net configurations (Sec. IV-A);
# batch sizes are scaled to the synthetic surrogate workloads. `tiny` is a
# fast path for tests.
CONFIGS = {
    "tiny": {"layers": (32, 16, 8), "batch": 16, "gather_dout": (4, 4)},
    "mnist_fc2": {"layers": (800, 100, 10), "batch": 256, "gather_dout": (20, 10)},
    "mnist_l4": {"layers": (800, 100, 100, 100, 10), "batch": 256, "gather_dout": None},
    "reuters": {"layers": (2000, 50, 50), "batch": 256, "gather_dout": (10, 10)},
    "timit": {"layers": (39, 390, 39), "batch": 256, "gather_dout": (90, 9)},
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def train_signature(layers, batch):
    """Input/output literal order for flat_train_step (must match model.py)."""
    n_junctions = len(layers) - 1
    inputs = []
    for group in ("w", "m_opt", "v_opt"):
        for i in range(1, len(layers)):
            inputs.append(_spec(f"{group}{i}", (layers[i], layers[i - 1])))
            inputs.append(_spec(f"{group}{i}_bias", (layers[i],)))
    for i in range(1, len(layers)):
        inputs.append(_spec(f"mask{i}", (layers[i], layers[i - 1])))
    inputs.append(_spec("x", (batch, layers[0])))
    inputs.append(_spec("y", (batch,), "i32"))
    inputs.append(_spec("t", ()))
    inputs.append(_spec("lr", ()))
    inputs.append(_spec("l2", ()))
    outputs = inputs[: 6 * n_junctions] + [_spec("t", ()), _spec("loss", ()), _spec("correct", ())]
    return inputs, outputs


def forward_signature(layers, batch):
    inputs = []
    for i in range(1, len(layers)):
        inputs.append(_spec(f"w{i}", (layers[i], layers[i - 1])))
        inputs.append(_spec(f"b{i}", (layers[i],)))
    for i in range(1, len(layers)):
        inputs.append(_spec(f"mask{i}", (layers[i], layers[i - 1])))
    inputs.append(_spec("x", (batch, layers[0])))
    return inputs, [_spec("logits", (batch, layers[-1]))]


def gather_signature(layers, batch, dout):
    """d_in_i = N_{i-1} * d_out_i / N_i (Sec. II-A)."""
    d_in = [layers[i - 1] * dout[i - 1] // layers[i] for i in range(1, len(layers))]
    inputs = []
    for i in range(1, len(layers)):
        inputs.append(_spec(f"wc{i}", (layers[i], d_in[i - 1])))
    for i in range(1, len(layers)):
        inputs.append(_spec(f"idx{i}", (layers[i], d_in[i - 1]), "i32"))
    for i in range(1, len(layers)):
        inputs.append(_spec(f"b{i}", (layers[i],)))
    inputs.append(_spec("x", (batch, layers[0])))
    return inputs, [_spec("logits", (batch, layers[-1]))]


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _shape_structs(specs):
    return [jax.ShapeDtypeStruct(tuple(s["shape"]), _DTYPES[s["dtype"]]) for s in specs]


def lower_entry(fn, in_specs):
    return jax.jit(fn).lower(*_shape_structs(in_specs))


def build_config(name, cfg, outdir):
    layers, batch = cfg["layers"], cfg["batch"]
    n_junctions = len(layers) - 1
    entry = {"layers": list(layers), "batch": batch, "programs": {}}

    jobs = [
        ("train", functools.partial(model.flat_train_step, n_junctions), train_signature(layers, batch)),
        ("forward", functools.partial(model.flat_forward, n_junctions), forward_signature(layers, batch)),
    ]
    if cfg.get("gather_dout"):
        dout = cfg["gather_dout"]
        tag = "gather_forward"
        jobs.append(
            (tag, functools.partial(model.flat_gather_forward, n_junctions), gather_signature(layers, batch, dout))
        )
        entry["gather_dout"] = list(dout)

    for tag, fn, (in_specs, out_specs) in jobs:
        fname = f"{name}_{tag}.hlo.txt"
        text = to_hlo_text(lower_entry(fn, in_specs))
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entry["programs"][tag] = {"file": fname, "inputs": in_specs, "outputs": out_specs}
        print(f"  {fname}: {len(text)} chars, {len(in_specs)} in / {len(out_specs)} out")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=list(CONFIGS))
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest = {"configs": {}}
    for name in args.configs:
        print(f"lowering config {name} {CONFIGS[name]['layers']}")
        manifest["configs"][name] = build_config(name, CONFIGS[name], args.outdir)
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(args.outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
