"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Shape conventions (paper notation, Sec. II-A):
  a     [B, Nl]      left-layer activations (layer i-1)
  w     [Nr, Nl]     junction weights, W[j, k] = edge (right j <- left k)
  mask  [Nr, Nl]     0/1 pre-defined sparsity pattern (fixed before training)
  b     [Nr]         right-layer biases
  delta [B, Nr]      error signal at the right layer (eq. 3)
  wc    [Nr, d_in]   compacted weights, row j = the d_in weights into right
                     neuron j (the paper's weight-memory layout, Fig. 4)
  idx   [Nr, d_in]   left-neuron index of each compacted weight
"""

import jax.numpy as jnp


def junction_ff(a, w, mask, b):
    """Feedforward (eq. 2a): h = a @ (w*mask)^T + b."""
    return a @ (w * mask).T + b


def junction_bp(delta, w, mask):
    """Backprop (eq. 3b, pre-activation part): da = delta @ (w*mask)."""
    return delta @ (w * mask)


def junction_up(a, delta, mask):
    """Update gradients (eq. 4b): dW = (delta^T @ a) * mask, db = sum delta."""
    return (delta.T @ a) * mask, delta.sum(axis=0)


def gather_ff(a, wc, idx, b):
    """Structured-sparse feedforward over compacted weights (eq. 2a).

    h[n, j] = sum_f wc[j, f] * a[n, idx[j, f]] + b[j]

    This is the true edge-based data layout: storage and MACs are
    proportional to |W_i| = Nr * d_in, not Nr * Nl.
    """
    gathered = jnp.take(a, idx, axis=1)  # [B, Nr, d_in]
    return jnp.einsum("bjf,jf->bj", gathered, wc) + b


def gather_bp(delta, wc, idx, n_left):
    """Structured-sparse backprop: scatter-add transpose of gather_ff."""
    # contrib[b, j, f] = delta[b, j] * wc[j, f] accumulated at column idx[j, f]
    contrib = delta[:, :, None] * wc[None, :, :]  # [B, Nr, d_in]
    flat_idx = idx.reshape(-1)  # [Nr*d_in]
    flat = contrib.reshape(contrib.shape[0], -1)  # [B, Nr*d_in]
    out = jnp.zeros((contrib.shape[0], n_left), dtype=delta.dtype)
    return out.at[:, flat_idx].add(flat)


def gather_up(a, delta, idx):
    """Structured-sparse update: dwc[j, f] = sum_b delta[b, j] * a[b, idx[j, f]]."""
    gathered = jnp.take(a, idx, axis=1)  # [B, Nr, d_in]
    return jnp.einsum("bj,bjf->jf", delta, gathered)
