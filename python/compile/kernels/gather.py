"""Structured-sparse gather kernel: the paper's edge-based inference path.

Stores only the |W_i| = Nr * d_in connected weights in compacted form
(Fig. 4's weight memory: edges numbered sequentially by right neuron →
row j of wc/idx holds right neuron j's d_in in-edges). The activation
reads a[:, idx[j, f]] are the *interleaved-order* accesses of Sec. III-B;
on the FPGA the clash-free seed-vector pattern guarantees one read per
bank per cycle, here the same reads become a VMEM gather over the
resident activation tile.

z_i (edges processed per cycle) maps to the tile_r * d_in edge block a
single grid step consumes; the d_out sweeps over the left activations
map to the batch grid dimension re-reading the same activation block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .junction import pick_tile


def _gather_ff_kernel(a_ref, wc_ref, idx_ref, b_ref, o_ref):
    """o[tb, tr] = einsum(a[tb, Nl] gathered by idx[tr, d_in], wc[tr, d_in]) + b."""
    gathered = jnp.take(a_ref[...], idx_ref[...], axis=1)  # [tb, tr, d_in]
    o_ref[...] = (
        jnp.einsum("bjf,jf->bj", gathered, wc_ref[...].astype(a_ref.dtype))
        + b_ref[...].astype(a_ref.dtype)[None, :]
    )


def gather_ff(a, wc, idx, b, *, tile_b=128, tile_r=128):
    """Eq. (2a) over compacted weights: h[n,j] = sum_f wc[j,f]*a[n,idx[j,f]] + b[j]."""
    bsz, nl = a.shape
    nr, d_in = wc.shape
    tb, tr = pick_tile(bsz, tile_b), pick_tile(nr, tile_r)
    grid = (bsz // tb, nr // tr)
    return pl.pallas_call(
        _gather_ff_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, nl), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, d_in), lambda i, j: (j, 0)),
            pl.BlockSpec((tr, d_in), lambda i, j: (j, 0)),
            pl.BlockSpec((tr,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tb, tr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, nr), a.dtype),
        interpret=True,
    )(a, wc, idx, b)


def _gather_up_kernel(a_ref, d_ref, idx_ref, o_ref):
    """dwc[tr, d_in] = einsum(delta[B, tr], a[B, Nl] gathered by idx)."""
    gathered = jnp.take(a_ref[...], idx_ref[...], axis=1)  # [B, tr, d_in]
    o_ref[...] = jnp.einsum("bj,bjf->jf", d_ref[...], gathered)


def gather_up(a, delta, idx, *, tile_r=128):
    """Eq. (4b) over compacted weights: dwc[j,f] = sum_b delta[b,j]*a[b,idx[j,f]].

    Full batch per grid step (UP consumes every input's contribution to a
    weight before moving on — the weight bank is written once per junction
    cycle, Fig. 3).
    """
    bsz, nl = a.shape
    nr, d_in = idx.shape
    tr = pick_tile(nr, tile_r)
    grid = (nr // tr,)
    return pl.pallas_call(
        _gather_up_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, nl), lambda j: (0, 0)),
            pl.BlockSpec((bsz, tr), lambda j: (0, j)),
            pl.BlockSpec((tr, d_in), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tr, d_in), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, d_in), delta.dtype),
        interpret=True,
    )(a, delta, idx)
