"""Pallas kernels for the paper's three per-junction hardware operations.

The FPGA architecture (Sec. III) streams z_i edges per clock out of banked
BRAM with clash-free interleaved addressing. The TPU-shaped analogue
(DESIGN.md §Hardware-Adaptation) blocks each junction into
(tile_b × tile_r × tile_l) VMEM tiles — the BlockSpec index maps play the
role the seed-vector address generators played on FPGA — and realizes the
z-parallel MAC array as MXU matmuls over the masked weight tile.

All three operations (FF / BP / UP) share the single weight bank, exactly
as in Fig. 3: the same (w, mask) tiles feed all three kernels.

Kernels run with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret lowering produces portable HLO that the Rust
runtime executes. Tile choices still follow MXU-friendly shapes where the
layer dimensions allow (multiples of 128/8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Pallas grids execute as XLA while-loops after interpret lowering (and as a
# python loop while tracing), so tiles are chosen as the largest "nice"
# divisor to keep grids shallow. 128 first: MXU lane width.
_TILE_PREF = (128, 100, 64, 50, 39, 32, 25, 16, 13, 10, 8, 5, 4, 3, 2, 1)


def pick_tile(n: int, cap: int = 128) -> int:
    """Largest preferred divisor of n, capped; falls back to n itself."""
    if n <= cap:
        return n
    for t in _TILE_PREF:
        if t <= cap and n % t == 0:
            return t
    return n


def _matmul_ff_kernel(a_ref, w_ref, m_ref, o_ref):
    """o[tb, tr] += a[tb, tl] @ (w*m)[tr, tl]^T, accumulated over grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    masked = (w_ref[...] * m_ref[...]).astype(a_ref.dtype)
    o_ref[...] += jax.lax.dot_general(
        a_ref[...],
        masked,
        (((1,), (1,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def junction_ff(a, w, mask, b, *, tile_b=128, tile_r=128, tile_l=128):
    """Eq. (2a) as a blocked Pallas matmul: h = a @ (w*mask)^T + b."""
    bsz, nl = a.shape
    nr = w.shape[0]
    tb, tr, tl = pick_tile(bsz, tile_b), pick_tile(nr, tile_r), pick_tile(nl, tile_l)
    grid = (bsz // tb, nr // tr, nl // tl)
    h = pl.pallas_call(
        _matmul_ff_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tl), lambda i, j, k: (i, k)),
            pl.BlockSpec((tr, tl), lambda i, j, k: (j, k)),
            pl.BlockSpec((tr, tl), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tb, tr), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, nr), a.dtype),
        interpret=True,
    )(a, w, mask)
    return h + b


def _matmul_bp_kernel(d_ref, w_ref, m_ref, o_ref):
    """o[tb, tl] += d[tb, tr] @ (w*m)[tr, tl], accumulated over grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    masked = (w_ref[...] * m_ref[...]).astype(d_ref.dtype)
    o_ref[...] += jax.lax.dot_general(
        d_ref[...],
        masked,
        (((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def junction_bp(delta, w, mask, *, tile_b=128, tile_r=128, tile_l=128):
    """Eq. (3b) pre-activation part as a blocked Pallas matmul: delta @ (w*mask)."""
    bsz, nr = delta.shape
    nl = w.shape[1]
    tb, tr, tl = pick_tile(bsz, tile_b), pick_tile(nr, tile_r), pick_tile(nl, tile_l)
    grid = (bsz // tb, nl // tl, nr // tr)
    return pl.pallas_call(
        _matmul_bp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tr), lambda i, j, k: (i, k)),
            pl.BlockSpec((tr, tl), lambda i, j, k: (k, j)),
            pl.BlockSpec((tr, tl), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tb, tl), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, nl), delta.dtype),
        interpret=True,
    )(delta, w, mask)


def _matmul_up_kernel(d_ref, a_ref, m_ref, o_ref, *, nsteps):
    """o[tr, tl] += d[tb, tr]^T @ a[tb, tl]; masked once fully accumulated.

    The mask multiply on the final accumulation step enforces eq. (4b):
    excluded edges receive *no* update ever, so they stay exactly zero —
    the pre-defined pattern is fixed through training.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        d_ref[...],
        a_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _apply_mask():
        o_ref[...] *= m_ref[...].astype(o_ref.dtype)


def junction_up(a, delta, mask, *, tile_b=128, tile_r=128, tile_l=128):
    """Eq. (4b) gradients: dW = (delta^T @ a) * mask and db = sum_b delta."""
    bsz, nr = delta.shape
    nl = a.shape[1]
    tb, tr, tl = pick_tile(bsz, tile_b), pick_tile(nr, tile_r), pick_tile(nl, tile_l)
    grid = (nr // tr, nl // tl, bsz // tb)
    dw = pl.pallas_call(
        functools.partial(_matmul_up_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tr), lambda i, j, k: (k, i)),
            pl.BlockSpec((tb, tl), lambda i, j, k: (k, j)),
            pl.BlockSpec((tr, tl), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((tr, tl), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, nl), delta.dtype),
        interpret=True,
    )(delta, a, mask)
    return dw, delta.sum(axis=0)
