"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
ref.py. This is the core correctness signal for the compute hot path that
the Rust runtime executes via the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gather, junction, ref

DIMS = st.sampled_from([1, 2, 3, 4, 5, 8, 13, 16, 24, 32, 39, 64, 100])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


def make(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def make_mask(rng, shape, density):
    # guarantee at least one connected edge so the junction is non-trivial
    m = (rng.random(shape) < density).astype(np.float32)
    m.flat[rng.integers(0, m.size)] = 1.0
    return jnp.asarray(m)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, nl=DIMS, nr=DIMS, dtype=DTYPES, density=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1))
def test_junction_ff_matches_ref(b, nl, nr, dtype, density, seed):
    rng = np.random.default_rng(seed)
    a, w = make(rng, (b, nl), dtype), make(rng, (nr, nl), dtype)
    mask, bias = make_mask(rng, (nr, nl), density).astype(dtype), make(rng, (nr,), dtype)
    got = junction.junction_ff(a, w, mask, bias)
    want = ref.junction_ff(a, w, mask, bias)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(b=DIMS, nl=DIMS, nr=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_junction_bp_matches_ref(b, nl, nr, dtype, seed):
    rng = np.random.default_rng(seed)
    d, w = make(rng, (b, nr), dtype), make(rng, (nr, nl), dtype)
    mask = make_mask(rng, (nr, nl), 0.4).astype(dtype)
    got = junction.junction_bp(d, w, mask)
    want = ref.junction_bp(d, w, mask)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(b=DIMS, nl=DIMS, nr=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_junction_up_matches_ref(b, nl, nr, dtype, seed):
    rng = np.random.default_rng(seed)
    a, d = make(rng, (b, nl), dtype), make(rng, (b, nr), dtype)
    mask = make_mask(rng, (nr, nl), 0.4).astype(dtype)
    dw, db = junction.junction_up(a, d, mask)
    dw_ref, db_ref = ref.junction_up(a, d, mask)
    np.testing.assert_allclose(np.asarray(dw, np.float32), np.asarray(dw_ref, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(db, np.float32), np.asarray(db_ref, np.float32), **tol(dtype))


def test_up_kernel_zeroes_excluded_edges():
    """Eq. (4b) hardware contract: excluded edges get *exactly* zero update."""
    rng = np.random.default_rng(7)
    a, d = make(rng, (16, 32), jnp.float32), make(rng, (16, 24), jnp.float32)
    mask = make_mask(rng, (24, 32), 0.3)
    dw, _ = junction.junction_up(a, d, mask)
    assert float(jnp.abs(dw * (1.0 - mask)).max()) == 0.0


@st.composite
def gather_case(draw):
    nl = draw(st.sampled_from([8, 13, 16, 32, 64, 100]))
    d_in = draw(st.integers(1, nl))
    nr = draw(st.sampled_from([1, 2, 4, 8, 10, 24, 39]))
    b = draw(st.sampled_from([1, 2, 8, 16, 32]))
    seed = draw(st.integers(0, 2**31 - 1))
    return nl, d_in, nr, b, seed


@settings(max_examples=25, deadline=None)
@given(case=gather_case(), dtype=DTYPES)
def test_gather_ff_matches_ref(case, dtype):
    nl, d_in, nr, b, seed = case
    rng = np.random.default_rng(seed)
    a, wc = make(rng, (b, nl), dtype), make(rng, (nr, d_in), dtype)
    bias = make(rng, (nr,), dtype)
    idx = jnp.asarray(
        np.stack([rng.choice(nl, d_in, replace=False) for _ in range(nr)]), jnp.int32
    )
    got = gather.gather_ff(a, wc, idx, bias)
    want = ref.gather_ff(a, wc, idx, bias)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


@settings(max_examples=15, deadline=None)
@given(case=gather_case())
def test_gather_up_matches_ref(case):
    nl, d_in, nr, b, seed = case
    rng = np.random.default_rng(seed)
    a, d = make(rng, (b, nl), jnp.float32), make(rng, (b, nr), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.choice(nl, d_in, replace=False) for _ in range(nr)]), jnp.int32
    )
    got = gather.gather_up(a, d, idx)
    want = ref.gather_up(a, d, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gather_equals_masked_dense():
    """Compacted-weight FF == masked-dense FF when wc/idx encode the mask."""
    rng = np.random.default_rng(3)
    nl, nr, d_in, b = 32, 16, 8, 8
    idx_np = np.stack([rng.choice(nl, d_in, replace=False) for _ in range(nr)])
    wc = rng.standard_normal((nr, d_in)).astype(np.float32)
    w = np.zeros((nr, nl), np.float32)
    mask = np.zeros((nr, nl), np.float32)
    for j in range(nr):
        w[j, idx_np[j]] = wc[j]
        mask[j, idx_np[j]] = 1.0
    a = rng.standard_normal((b, nl)).astype(np.float32)
    bias = rng.standard_normal(nr).astype(np.float32)
    dense = junction.junction_ff(jnp.asarray(a), jnp.asarray(w), jnp.asarray(mask), jnp.asarray(bias))
    compact = gather.gather_ff(jnp.asarray(a), jnp.asarray(wc), jnp.asarray(idx_np, jnp.int32), jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(compact), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,cap,expect_divides", [(800, 128, True), (256, 128, True), (39, 128, False), (2000, 128, True)])
def test_pick_tile_divides(n, cap, expect_divides):
    t = junction.pick_tile(n, cap)
    assert n % t == 0
    if expect_divides:
        assert t <= cap
