"""AOT boundary tests: signatures match the flat wrappers, HLO text parses,
and the manifest the Rust runtime consumes is faithful."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

TINY = aot.CONFIGS["tiny"]


def test_train_signature_counts():
    for name, cfg in aot.CONFIGS.items():
        layers, batch = cfg["layers"], cfg["batch"]
        nj = len(layers) - 1
        ins, outs = aot.train_signature(layers, batch)
        assert len(ins) == 6 * nj + nj + 5
        assert len(outs) == 6 * nj + 3
        assert ins[-5]["name"] == "x" and ins[-5]["shape"] == [batch, layers[0]]
        assert ins[-4]["dtype"] == "i32"


def test_forward_signature_counts():
    layers, batch = TINY["layers"], TINY["batch"]
    ins, outs = aot.forward_signature(layers, batch)
    assert len(ins) == 3 * (len(layers) - 1) + 1
    assert outs[0]["shape"] == [batch, layers[-1]]


def test_gather_signature_din_math():
    # d_in_i = N_{i-1} d_out_i / N_i  (Sec. II-A)
    ins, _ = aot.gather_signature((800, 100, 10), 256, (20, 10))
    wc1 = next(s for s in ins if s["name"] == "wc1")
    wc2 = next(s for s in ins if s["name"] == "wc2")
    assert wc1["shape"] == [100, 160]
    assert wc2["shape"] == [10, 100]


def test_lowered_train_step_runs_and_matches_eager():
    """Execute the lowered (AOT) tiny train step via jax and compare to eager."""
    layers, batch = TINY["layers"], TINY["batch"]
    nj = len(layers) - 1
    ins, _ = aot.train_signature(layers, batch)
    import functools

    fn = functools.partial(model.flat_train_step, nj)
    lowered = aot.lower_entry(fn, ins)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    args = []
    for spec in ins:
        shape, dtype = tuple(spec["shape"]), spec["dtype"]
        if dtype == "i32":
            args.append(jnp.asarray(rng.integers(0, layers[-1], shape), jnp.int32))
        elif spec["name"] == "t":
            args.append(jnp.float32(1.0))
        elif spec["name"] == "lr":
            args.append(jnp.float32(1e-3))
        elif spec["name"] == "l2":
            args.append(jnp.float32(0.0))
        elif spec["name"].startswith("mask"):
            args.append(jnp.asarray(rng.random(shape) < 0.5, jnp.float32))
        else:
            args.append(jnp.asarray(rng.standard_normal(shape), jnp.float32))
    got = compiled(*args)
    want = fn(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)


def test_hlo_text_mentions_entry_and_params():
    layers, batch = TINY["layers"], TINY["batch"]
    ins, _ = aot.forward_signature(layers, batch)
    import functools

    text = aot.to_hlo_text(aot.lower_entry(functools.partial(model.flat_forward, len(layers) - 1), ins))
    assert "HloModule" in text
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent_with_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["configs"], "empty manifest"
    for name, entry in manifest["configs"].items():
        layers = entry["layers"]
        for tag, prog in entry["programs"].items():
            path = os.path.join(root, prog["file"])
            assert os.path.exists(path), f"missing {path}"
            with open(path) as f:
                head = f.read(64)
            assert "HloModule" in head
            if tag == "train":
                assert len(prog["inputs"]) == 7 * (len(layers) - 1) + 5
