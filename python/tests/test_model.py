"""L2 correctness: SparseMLP custom-VJP grads vs jax autodiff of the oracle,
mask fixedness through training, Adam step math, flat AOT wrappers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref as kref


def ref_forward(params, masks, x):
    a = x
    for i, ((w, b), m) in enumerate(zip(params, masks)):
        h = kref.junction_ff(a, w, m, b)
        a = h if i == len(params) - 1 else jax.nn.relu(h)
    return a


def ref_loss(params, masks, x, y, l2):
    logits = ref_forward(params, masks, x)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    return ce + l2 * sum(jnp.sum((w * m) ** 2) for (w, _), m in zip(params, masks))


def setup(layers, batch, seed, density=0.5):
    rng = np.random.default_rng(seed)
    params = model.init_params(layers, jax.random.PRNGKey(seed))
    masks = [
        jnp.asarray((rng.random((layers[i + 1], layers[i])) < density), jnp.float32)
        for i in range(len(layers) - 1)
    ]
    x = jnp.asarray(rng.standard_normal((batch, layers[0])), jnp.float32)
    y = jnp.asarray(rng.integers(0, layers[-1], batch), jnp.int32)
    return params, masks, x, y


@settings(max_examples=10, deadline=None)
@given(
    layers=st.sampled_from([(8, 6, 4), (12, 10, 6), (16, 8, 8, 4), (10, 10, 10, 10, 5)]),
    batch=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 1000),
    l2=st.sampled_from([0.0, 1e-3, 1e-2]),
)
def test_grads_match_autodiff_of_oracle(layers, batch, seed, l2):
    params, masks, x, y = setup(layers, batch, seed)
    g1 = jax.grad(lambda p: model.loss_and_metrics(p, masks, x, y, l2)[0])(params)
    g2 = jax.grad(lambda p: ref_loss(p, masks, x, y, l2))(params)
    for (gw1, gb1), (gw2, gb2) in zip(g1, g2):
        np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gb1, gb2, rtol=1e-4, atol=1e-5)


def test_forward_matches_oracle():
    params, masks, x, _ = setup((20, 16, 10), 8, 0)
    np.testing.assert_allclose(
        model.forward(params, masks, x), ref_forward(params, masks, x), rtol=1e-5, atol=1e-5
    )


def test_excluded_weights_stay_zero_over_many_steps():
    """The pre-defined sparsity contract (Sec. II): pattern fixed through training."""
    layers = (12, 10, 6)
    params, masks, x, y = setup(layers, 8, 1, density=0.3)
    params = [(w * m, b) for (w, b), m in zip(params, masks)]
    zeros = lambda: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    m_st, v_st, t = zeros(), zeros(), 1.0
    for _ in range(5):
        params, m_st, v_st, t, _, _ = model.train_step(params, m_st, v_st, masks, x, y, t, 1e-2, 1e-3)
    for (w, _), m in zip(params, masks):
        assert float(jnp.abs(w * (1 - m)).max()) == 0.0
    for (mw, _), (vw, _), m in zip(m_st, v_st, masks):
        assert float(jnp.abs(mw * (1 - m)).max()) == 0.0
        assert float(jnp.abs(vw * (1 - m)).max()) == 0.0


def test_train_step_reduces_loss():
    layers = (16, 32, 4)
    params, masks, x, y = setup(layers, 32, 2, density=1.0)
    zeros = lambda: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    m_st, v_st, t = zeros(), zeros(), 1.0
    first = None
    for _ in range(30):
        params, m_st, v_st, t, ce, _ = model.train_step(params, m_st, v_st, masks, x, y, t, 1e-2, 0.0)
        first = first if first is not None else float(ce)
    assert float(ce) < first


def test_adam_step_matches_reference_formula():
    p = jnp.asarray([1.0, -2.0, 0.5])
    g = jnp.asarray([0.1, 0.2, -0.3])
    m = jnp.asarray([0.01, 0.0, 0.02])
    v = jnp.asarray([0.001, 0.0, 0.002])
    t = 3.0
    p2, m2, v2 = model.adam_step(p, g, m, v, t, lr=1e-2, decay=0.0)
    m_ref = 0.9 * np.asarray(m) + 0.1 * np.asarray(g)
    v_ref = 0.999 * np.asarray(v) + 0.001 * np.asarray(g) ** 2
    mhat = m_ref / (1 - 0.9**3)
    vhat = v_ref / (1 - 0.999**3)
    p_ref = np.asarray(p) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p2, p_ref, rtol=1e-6)
    np.testing.assert_allclose(m2, m_ref, rtol=1e-6)
    np.testing.assert_allclose(v2, v_ref, rtol=1e-6)


def test_flat_train_step_roundtrip():
    """Flat AOT wrapper computes the same update as the structured API."""
    layers = (12, 10, 6)
    nj = len(layers) - 1
    params, masks, x, y = setup(layers, 8, 4, density=0.4)
    params = [(w * m, b) for (w, b), m in zip(params, masks)]
    zeros = lambda: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    m_st, v_st = zeros(), zeros()
    flat_args = []
    for group in (params, m_st, v_st):
        for w, b in group:
            flat_args.extend((w, b))
    flat_args.extend(masks)
    flat_args.extend((x, y, jnp.float32(1.0), jnp.float32(1e-3), jnp.float32(0.0)))
    out = model.flat_train_step(nj, *flat_args)
    assert len(out) == 6 * nj + 3
    sp, sm, sv, st_, ce, corr = model.train_step(params, m_st, v_st, masks, x, y, 1.0, 1e-3, 0.0)
    np.testing.assert_allclose(out[0], sp[0][0], rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(out[2 * nj - 1], sp[-1][1], rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(float(out[-2]), float(ce), rtol=1e-4)
    assert float(out[-3]) == 2.0  # t advanced
    assert float(out[-1]) == float(corr)


def test_flat_forward_matches_forward():
    layers = (12, 10, 6)
    nj = len(layers) - 1
    params, masks, x, _ = setup(layers, 8, 5)
    flat_args = []
    for w, b in params:
        flat_args.extend((w, b))
    flat_args.extend(masks)
    flat_args.append(x)
    (logits,) = model.flat_forward(nj, *flat_args)
    np.testing.assert_allclose(logits, model.forward(params, masks, x), rtol=1e-6)


def test_gather_forward_matches_masked_forward():
    """Compacted inference path == masked-dense path for an encoded pattern."""
    rng = np.random.default_rng(9)
    layers = (16, 8, 4)
    douts = (4, 2)
    params = model.init_params(layers, jax.random.PRNGKey(9))
    wcs, idxs, biases, masks, dense = [], [], [], [], []
    for i, (w, b) in enumerate(params):
        nr, nl = w.shape
        d_in = nl * douts[i] // nr
        idx = np.stack([rng.choice(nl, d_in, replace=False) for _ in range(nr)])
        wc = np.asarray(w)[np.arange(nr)[:, None], idx]
        m = np.zeros((nr, nl), np.float32)
        for j in range(nr):
            m[j, idx[j]] = 1.0
        wcs.append(jnp.asarray(wc))
        idxs.append(jnp.asarray(idx, jnp.int32))
        biases.append(b)
        masks.append(jnp.asarray(m))
        dense.append((w, b))
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    got = model.gather_forward(wcs, idxs, biases, x)
    want = model.forward(dense, masks, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
