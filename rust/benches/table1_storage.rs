//! Bench + regeneration of Table I: storage model evaluation cost and the
//! FC-vs-sparse reduction factors across the paper's configurations.

use pds::hw::storage::{training_storage, StorageComparison};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::util::bench::bench_auto;
use std::time::Duration;

fn main() {
    println!("== Table I regeneration ==");
    let cases = [
        (vec![800usize, 100, 10], vec![20usize, 10]),
        (vec![800, 100, 100, 100, 10], vec![20, 20, 20, 10]),
        (vec![2000, 50, 50], vec![10, 10]),
        (vec![39, 390, 39], vec![90, 9]),
        (vec![4000, 500, 100], vec![100, 100]),
    ];
    for (layers, dout) in &cases {
        let netc = NetConfig::new(layers.clone());
        let d = DoutConfig(dout.clone());
        let cmp = StorageComparison::new(&netc, &d);
        println!(
            "{:<28} rho {:>5.1}%  FC {:>8} w | sparse {:>8} w | mem {:.1}X compute {:.1}X",
            format!("{layers:?}"),
            netc.rho_net(&d) * 100.0,
            cmp.fc.total(),
            cmp.sparse.total(),
            cmp.memory_reduction(),
            cmp.compute_reduction()
        );
    }

    println!("\n== model evaluation cost ==");
    let netc = NetConfig::new(vec![800, 100, 100, 100, 10]);
    let dout = DoutConfig(vec![20, 20, 20, 10]);
    bench_auto("training_storage (L=4)", Duration::from_millis(300), || {
        std::hint::black_box(training_storage(&netc, &dout));
    })
    .report();
}
