//! Runtime execution benches: end-to-end train-step and forward latency
//! through the backend-agnostic `runtime::Engine` (the L3 dispatch
//! overhead target in DESIGN.md §Perf), across configs, plus the
//! parallel-speedup report for the native backend's batched hot paths.
//!
//! Runs with no xla/PJRT libraries installed: the default engine is the
//! pure-Rust native backend with built-in configs. With `--features pjrt`
//! and built artifacts the same harness times the compiled executables.

use pds::data::Spec;
use pds::runtime::Engine;
use pds::sparsity::config::{DoutConfig, NetConfig};

use pds::sparsity::{generate, Method};
use pds::util::bench::bench_auto;
use pds::util::parallel;
use pds::util::rng::Rng;
use std::time::Duration;

/// Build a clash-free ~25%-density session plus one matching minibatch.
fn setup(
    engine: &Engine,
    config: &str,
) -> Option<(pds::coordinator::TrainSession, Vec<f32>, Vec<i32>)> {
    let entry = engine.manifest.configs.get(config)?;
    let layers = entry.layers.clone();
    let batch = entry.batch;
    let netc = NetConfig::new(layers.clone());
    let dout = DoutConfig(
        (0..netc.n_junctions())
            .map(|i| netc.junction(i).dout_for_density(0.25))
            .collect(),
    );
    let mut rng = Rng::new(1);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    let session =
        pds::coordinator::TrainSession::new(engine, config, &pattern, 1e-3, 1e-4, 2).unwrap();
    let spec = Spec {
        name: "bench",
        features: layers[0],
        classes: *layers.last().unwrap(),
        latent_dim: (layers[0] / 4).clamp(4, 64),
        shaping: pds::data::Shaping::Continuous,
        separation: 2.5,
        noise: 0.5,
    };
    let mut drng = Rng::new(3);
    let ds = spec.generate(batch, &mut drng);
    let idx: Vec<usize> = (0..batch).collect();
    let (x, y) = ds.gather(&idx);
    Some((session, x, y))
}

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let engine = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("runtime_exec: engine unavailable: {e:#}");
            return;
        }
    };
    println!("== end-to-end step latency ({}) ==", engine.platform());

    for config in ["tiny", "mnist_fc2", "timit"] {
        let Some((mut session, x, y)) = setup(&engine, config) else {
            continue;
        };
        let batch = session.batch;
        bench_auto(
            &format!("{config} train step (batch {batch})"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.step(&x, &y).unwrap());
            },
        )
        .report_throughput("samples", batch as f64);
        bench_auto(
            &format!("{config} forward (batch {batch})"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.logits(&x).unwrap());
            },
        )
        .report_throughput("samples", batch as f64);
    }

    // Parallel speedup of the native backend's batched hot paths over the
    // single-threaded seed kernels. Only meaningful on the native backend
    // (PJRT parallelism is XLA's business), at batch >= 64.
    if !engine.platform().starts_with("native") {
        return;
    }
    println!("\n== native parallel speedup vs single-threaded kernels ==");
    for config in ["mnist_fc2", "timit"] {
        let Some((mut session, x, y)) = setup(&engine, config) else {
            continue;
        };
        let batch = session.batch;
        if batch < 64 {
            eprintln!("{config}: batch {batch} < 64, skipping speedup comparison");
            continue;
        }

        parallel::set_threads(1);
        let fwd_1 = bench_auto(
            &format!("{config} forward (batch {batch}) 1 thread"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.logits(&x).unwrap());
            },
        );
        fwd_1.report_throughput("samples", batch as f64);
        let step_1 = bench_auto(
            &format!("{config} train step (batch {batch}) 1 thread"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.step(&x, &y).unwrap());
            },
        );
        step_1.report_throughput("samples", batch as f64);

        parallel::set_threads(0); // restore auto-detection
        let threads = parallel::max_threads();
        let fwd_n = bench_auto(
            &format!("{config} forward (batch {batch}) {threads} threads"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.logits(&x).unwrap());
            },
        );
        fwd_n.report_throughput("samples", batch as f64);
        let step_n = bench_auto(
            &format!("{config} train step (batch {batch}) {threads} threads"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.step(&x, &y).unwrap());
            },
        );
        step_n.report_throughput("samples", batch as f64);

        let fwd_speedup = fwd_1.median.as_secs_f64() / fwd_n.median.as_secs_f64().max(1e-12);
        let step_speedup = step_1.median.as_secs_f64() / step_n.median.as_secs_f64().max(1e-12);
        println!(
            "{config}: parallel forward speedup {fwd_speedup:.2}X, train-step speedup \
             {step_speedup:.2}X over the single-threaded kernels ({threads} threads, batch {batch})"
        );
    }
}
