//! PJRT runtime benches: end-to-end train-step and forward latency of the
//! AOT artifacts from the Rust hot path (the L3 dispatch overhead target
//! in DESIGN.md §Perf), across artifact configs.
//!
//! Skips with a notice when artifacts are not built.

use pds::data::Spec;
use pds::runtime::Engine;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::bench::bench_auto;
use pds::util::rng::Rng;
use std::time::Duration;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let Ok(engine) = Engine::new(dir) else {
        eprintln!("runtime_exec: artifacts not built, skipping (run `make artifacts`)");
        return;
    };
    println!("== PJRT end-to-end step latency ({}) ==", engine.platform());

    for config in ["tiny", "mnist_fc2", "timit"] {
        let Some(entry) = engine.manifest.configs.get(config) else {
            continue;
        };
        let layers = entry.layers.clone();
        let batch = entry.batch;
        let netc = NetConfig::new(layers.clone());
        let dout = DoutConfig(
            (0..netc.n_junctions())
                .map(|i| netc.junction(i).dout_for_density(0.25))
                .collect(),
        );
        let mut rng = Rng::new(1);
        let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
        let mut session =
            pds::coordinator::TrainSession::new(&engine, config, &pattern, 1e-3, 1e-4, 2).unwrap();
        let spec = Spec {
            name: "bench",
            features: layers[0],
            classes: *layers.last().unwrap(),
            latent_dim: (layers[0] / 4).clamp(4, 64),
            shaping: pds::data::Shaping::Continuous,
            separation: 2.5,
            noise: 0.5,
        };
        let mut drng = Rng::new(3);
        let ds = spec.generate(batch, &mut drng);
        let idx: Vec<usize> = (0..batch).collect();
        let (x, y) = ds.gather(&idx);

        let edges = pattern.junctions.iter().map(|j| j.n_edges()).sum::<usize>() as f64;
        bench_auto(
            &format!("{config} train step (batch {batch})"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.step(&x, &y).unwrap());
            },
        )
        .report_throughput("samples", batch as f64);
        bench_auto(
            &format!("{config} forward (batch {batch})"),
            Duration::from_secs(1),
            || {
                std::hint::black_box(session.logits(&x).unwrap());
            },
        )
        .report_throughput("samples", batch as f64);
        let _ = edges;
    }
}
