//! Networked serving bench: sustained throughput and achieved
//! micro-batch coalescing of the TCP front-end under concurrent
//! pipelined socket clients, against the single-client baseline.
//!
//! Each scenario starts a fresh service + `NetServer` on an ephemeral
//! loopback port, drives the closed-loop socket load generator through
//! real TCP connections, and reads the coalescing counters back over
//! the wire. Merges a `net` section (including the achieved mean
//! coalesced batch size — the number that proves socket traffic reaches
//! the parallel batch kernels as batches, not batch-1 calls) into
//! `BENCH_serve.json` at the repo root, preserving the `serve_load` and
//! `quant_exec` sections.
//!
//!     cargo bench --bench net_load

use std::sync::Arc;
use std::time::Duration;

use pds::coordinator::loadgen::{self, SocketLoadSpec};
use pds::coordinator::{InferenceService, ServerConfig};
use pds::net::{NetServer, NetServerConfig};

const BATCH_WINDOW: Duration = Duration::from_micros(1000);

fn run_scenario(
    dir: &str,
    models: &[String],
    spec: SocketLoadSpec,
) -> anyhow::Result<Vec<loadgen::SocketLoadReport>> {
    let specs = models
        .iter()
        .map(|m| {
            // host as many parameter banks as the load will spread over
            loadgen::model_spec(dir, m, 0.25, 7).map(|s| s.with_contexts(spec.contexts.max(1)))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let svc = Arc::new(InferenceService::start(
        dir,
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_depth: 256,
            tune_kernel_threads: true,
        },
    )?);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            max_connections: 64,
            batch_window: BATCH_WINDOW,
        },
    )?;
    let reports = loadgen::run_socket_load(server.local_addr(), models, &spec, 0x5EED)?;
    let svc = server.shutdown()?;
    drop(svc);
    Ok(reports)
}

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    // sweep offered concurrency: 1 client x 1 pipeline is the
    // batch-1 degenerate baseline; the others give the micro-batcher
    // something to coalesce. The tail of the sweep holds concurrency
    // fixed and scales the tenant-context count (1/4/16 banks per
    // model) to measure context-grouped batching through the socket
    // path under the same offered load.
    let sweep = [
        SocketLoadSpec { clients: 1, requests: 64, pipeline: 1, contexts: 1 },
        SocketLoadSpec { clients: 4, requests: 96, pipeline: 8, contexts: 1 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 1 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 4 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 16 },
    ];
    let mut scenarios = Vec::new();
    for spec in sweep {
        println!(
            "== {} client(s) x pipeline {} x {} context(s) per model ==",
            spec.clients, spec.pipeline, spec.contexts
        );
        match run_scenario(dir, &models, spec) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                scenarios.push((spec, reports));
            }
            Err(e) => {
                eprintln!(
                    "net_load: scenario {}x{}x{} failed: {e:#}",
                    spec.clients, spec.pipeline, spec.contexts
                );
                return;
            }
        }
    }
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let doc = loadgen::net_bench_json(&scenarios, BATCH_WINDOW);
    // print the same flush-weighted aggregate the document records, so
    // the console headline cannot diverge from BENCH_serve.json
    if let Some(mean) = doc
        .get("net")
        .and_then(|n| n.get("mean_coalesced_batch"))
        .and_then(|v| v.as_f64())
    {
        println!(
            "\nachieved mean coalesced batch size {mean:.2} \
             (pipelined socket traffic reaches the engine as batches)"
        );
    }
    // merge-write so the serve_load and quant_exec sections survive
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("net_load: cannot write {out}: {e}"),
    }
}
