//! Quantized vs f32 execution bench: fixed-point (Qm.n, `nn::fixed`)
//! against the f32 reference on the same models, at two levels —
//!
//! 1. **kernel**: batched sparse forward throughput of
//!    `FixedSparseNet::logits_q` vs `SparseNet::logits` on an
//!    mnist_fc2-shaped clash-free net (batch 256),
//! 2. **service**: sustained req/s of the multi-worker inference service
//!    serving the same models quantized vs f32, under identical
//!    closed-loop load ([`pds::coordinator::loadgen::bench_service`]
//!    with and without a quant format).
//!
//! Merges a `quant_exec` section into `BENCH_serve.json` at the repo
//! root, preserving the `serve_load` scenario section.
//!
//!     cargo bench --bench quant_exec

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::nn::fixed::{FixedSparseNet, QFormat};
use pds::nn::sparse::SparseNet;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::json::Json;
use pds::util::parallel;
use pds::util::rng::Rng;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Median wall-time of `reps` runs of `f`, in milliseconds.
fn time_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

fn main() {
    let fmt = QFormat::default();
    println!("quant_exec: fixed-point {fmt} vs f32");

    // -- kernel level: mnist_fc2-shaped sparse forward, batch 256 --
    let layers = vec![800usize, 100, 10];
    let batch = 256usize;
    let netc = NetConfig::new(layers.clone());
    let mut rng = Rng::new(11);
    let pattern = generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(vec![20, 10]),
        None,
        &mut rng,
    );
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
    let qnet = FixedSparseNet::from_f32(&snet, fmt);
    let x: Vec<f32> = (0..batch * layers[0])
        .map(|_| rng.uniform() * 2.0 - 1.0)
        .collect();
    let xq = fmt.quantize_slice(&x);
    // warmup + saturation check
    snet.logits(&x, batch);
    let (_, saturations) = qnet.logits_q(&xq, batch);
    let reps = 30;
    let f32_ms = time_ms(reps, || {
        snet.logits(&x, batch);
    });
    let quant_ms = time_ms(reps, || {
        qnet.logits_q(&xq, batch);
    });
    let kernel_speedup = f32_ms / quant_ms.max(1e-9);
    println!(
        "kernel (mnist_fc2-like, batch {batch}): f32 {f32_ms:.3} ms, {fmt} {quant_ms:.3} ms \
         ({kernel_speedup:.2}X), {saturations} saturated outputs"
    );

    // -- service level: same models, quantized vs f32 workers --
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    let load = LoadSpec {
        clients: 8,
        requests: 150,
        think_time: Duration::ZERO,
        burst: 1,
        contexts: 1,
    };
    let workers = 2usize;
    let mut rps = Vec::new();
    for quant in [None, Some(fmt)] {
        let label = match quant {
            Some(f) => format!("{f}"),
            None => "f32".to_string(),
        };
        println!("-- service, {workers} workers/model, {label} --");
        match loadgen::bench_service(
            dir,
            &models,
            workers,
            256,
            Duration::from_millis(2),
            &load,
            13,
            quant,
            None,
        ) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                rps.push(reports.iter().map(|r| r.throughput).sum::<f64>());
            }
            Err(e) => {
                eprintln!("quant_exec: {label} scenario failed: {e:#}");
                return;
            }
        }
    }
    let serve_speedup = rps[1] / rps[0].max(1e-9);
    println!(
        "service throughput: {:.0} req/s quantized vs {:.0} req/s f32 ({serve_speedup:.2}X)",
        rps[1], rps[0]
    );

    // -- merge the section into BENCH_serve.json --
    let section = obj(vec![
        ("recorded", Json::Bool(true)),
        ("format", Json::Str(format!("{fmt}"))),
        (
            "kernel_threads_total",
            Json::Num(parallel::machine_threads() as f64),
        ),
        (
            "kernel",
            obj(vec![
                ("config", Json::Str("mnist_fc2-like".into())),
                ("batch", Json::Num(batch as f64)),
                ("f32_ms", Json::Num(f32_ms)),
                ("quant_ms", Json::Num(quant_ms)),
                ("quant_speedup", Json::Num(kernel_speedup)),
                ("saturations", Json::Num(saturations as f64)),
            ]),
        ),
        (
            "serve",
            obj(vec![
                ("workers", Json::Num(workers as f64)),
                ("f32_rps", Json::Num(rps[0])),
                ("quant_rps", Json::Num(rps[1])),
                ("quant_speedup", Json::Num(serve_speedup)),
            ]),
        ),
    ]);
    let doc = obj(vec![("quant_exec", section)]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("merged quant_exec section into {out}"),
        Err(e) => eprintln!("quant_exec: cannot write {out}: {e}"),
    }
}
