//! Bench + regeneration of Table III: pattern-space counting and the cost
//! of *generating* clash-free patterns of each type (the hardware's
//! address-generation workload, amortized at configuration time).

use pds::sparsity::clash_free::{address_storage_cost, generate, pattern_space, Flavor};
use pds::sparsity::config::JunctionShape;
use pds::util::bench::bench_auto;
use pds::util::rng::Rng;
use std::time::Duration;

const FLAVORS: [Flavor; 6] = [
    Flavor::Type1 { dither: false },
    Flavor::Type1 { dither: true },
    Flavor::Type2 { dither: false },
    Flavor::Type2 { dither: true },
    Flavor::Type3 { dither: false },
    Flavor::Type3 { dither: true },
];

fn main() {
    println!("== Table III regeneration (12, 12, d_out 2, d_in 2, z 4) ==");
    let toy = JunctionShape { n_left: 12, n_right: 12 };
    for f in FLAVORS {
        let s = pattern_space(toy, 2, 4, f);
        println!(
            "{:<24} |S_Mi| = {:<12} addr storage = {:>3} words",
            f.name(),
            s.exact
                .map(|v| v.to_string())
                .unwrap_or_else(|| format!("1e{:.1}", s.log10)),
            address_storage_cost(toy, 2, 4, f)
        );
    }

    println!("\n== pattern generation throughput (800x100, d_out 20, z 200) ==");
    let big = JunctionShape { n_left: 800, n_right: 100 };
    for f in FLAVORS {
        let mut rng = Rng::new(1);
        let edges = 16_000f64;
        bench_auto(&format!("generate {}", f.name()), Duration::from_millis(400), || {
            std::hint::black_box(generate(big, 20, 200, f, &mut rng));
        })
        .report_throughput("edges", edges);
    }

    println!("\n== structured / random generation for comparison ==");
    let mut rng = Rng::new(2);
    bench_auto("generate structured", Duration::from_millis(400), || {
        std::hint::black_box(pds::sparsity::structured::generate(big, 20, &mut rng));
    })
    .report_throughput("edges", 16_000.0);
    bench_auto("generate random", Duration::from_millis(400), || {
        std::hint::black_box(pds::sparsity::random::generate(big, 16_000, &mut rng));
    })
    .report_throughput("edges", 16_000.0);
}
