//! Training-path bench: epoch throughput of the pipelined engine
//! (`nn::pipeline`, the Sec. III-A FF/BP/UP interleave at full depth)
//! against the sequential `nn::trainer` loop, on the same nets, data and
//! batch sizes (batch >= 64). Writes the numbers to `BENCH_train.json`
//! at the repo root.
//!
//! Both sides run exactly one epoch + one small-test evaluation per
//! iteration, so the comparison is work-for-work: the pipelined side
//! wins only by overlapping the FF/BP/UP stages of different minibatches
//! across cores (its kernels are the same batch-parallel CSR kernels the
//! sequential loop uses, with the kernel-thread budget divided across
//! stages).
//!
//!     cargo bench --bench train_pipeline

use std::collections::BTreeMap;

use pds::coordinator::loadgen;
use pds::data::Spec;
use pds::nn::pipeline::{PipelineConfig, PipelinedTrainer};
use pds::nn::sparse::SparseNet;
use pds::nn::trainer::{self, Network, TrainConfig};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::bench::bench;
use pds::util::json::Json;
use pds::util::parallel;
use pds::util::rng::Rng;

struct Case {
    name: &'static str,
    layers: Vec<usize>,
    dout: Vec<usize>,
    batch: usize,
    n_train: usize,
}

fn run_case(case: &Case) -> Json {
    let l = case.layers.len() - 1;
    let netc = NetConfig::new(case.layers.clone());
    let mut prng = Rng::new(7);
    let pattern = generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(case.dout.clone()),
        None,
        &mut prng,
    );
    let spec = Spec {
        name: "train-bench",
        features: case.layers[0],
        classes: *case.layers.last().unwrap(),
        latent_dim: (case.layers[0] / 4).clamp(4, 64),
        shaping: pds::data::Shaping::Continuous,
        separation: 2.5,
        noise: 0.5,
    };
    let splits = spec.splits(case.n_train, 0, 64, 21);

    // sequential baseline: the nn::trainer epoch loop
    let mut init_rng = Rng::new(9);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut init_rng);
    let mut seq_net = Network::Sparse(snet);
    let seq_cfg = TrainConfig {
        epochs: 1,
        batch: case.batch,
        seed: 9,
        ..Default::default()
    };
    let r_seq = bench(
        &format!("{} sequential epoch (batch {})", case.name, case.batch),
        1,
        5,
        || {
            std::hint::black_box(trainer::train(
                &mut seq_net,
                &splits.train,
                &splits.test,
                &seq_cfg,
            ));
        },
    );
    r_seq.report_throughput("samples", case.n_train as f64);

    // pipelined engine at full depth (2L minibatches in flight)
    let mut pipe = PipelinedTrainer::from_pattern(
        &case.layers,
        &pattern,
        &PipelineConfig {
            epochs: 1,
            batch: case.batch,
            depth: 0,
            seed: 9,
            tune_kernel_threads: true,
            ..Default::default()
        },
    )
    .expect("pipelined trainer");
    let depth = pipe.depth();
    let r_pipe = bench(
        &format!("{} pipelined epoch (depth {depth})", case.name),
        1,
        5,
        || {
            std::hint::black_box(pipe.train(&splits.train, &splits.test).unwrap());
        },
    );
    r_pipe.report_throughput("samples", case.n_train as f64);
    pipe.audit_banked().expect("banked audit after the run");

    let speedup = r_seq.median.as_secs_f64() / r_pipe.median.as_secs_f64().max(1e-12);
    println!(
        "{}: pipelined {speedup:.2}X over sequential epochs (L = {l}, \
         steady ops/cycle = {})\n",
        case.name,
        3 * l - 1
    );

    let mut obj = BTreeMap::new();
    obj.insert("name".to_string(), Json::Str(case.name.to_string()));
    obj.insert(
        "layers".to_string(),
        Json::Arr(case.layers.iter().map(|&n| Json::Num(n as f64)).collect()),
    );
    obj.insert("l".to_string(), Json::Num(l as f64));
    obj.insert("batch".to_string(), Json::Num(case.batch as f64));
    obj.insert("depth".to_string(), Json::Num(depth as f64));
    obj.insert(
        "samples_per_epoch".to_string(),
        Json::Num(case.n_train as f64),
    );
    obj.insert(
        "seq_epoch_ms".to_string(),
        Json::Num(r_seq.median.as_secs_f64() * 1e3),
    );
    obj.insert(
        "pipe_epoch_ms".to_string(),
        Json::Num(r_pipe.median.as_secs_f64() * 1e3),
    );
    obj.insert("speedup".to_string(), Json::Num(speedup));
    Json::Obj(obj)
}

/// One *profiled* epoch of a case on a fresh trainer (the timing runs
/// above stay unprofiled so the speedup numbers are not contaminated by
/// the per-op timestamps): the per-stage wall + modelled-clock section
/// merged into `BENCH_train.json` as `profile`.
fn profile_case(case: &Case) -> Json {
    let netc = NetConfig::new(case.layers.clone());
    let mut prng = Rng::new(7);
    let pattern = generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(case.dout.clone()),
        None,
        &mut prng,
    );
    let spec = Spec {
        name: "train-bench",
        features: case.layers[0],
        classes: *case.layers.last().unwrap(),
        latent_dim: (case.layers[0] / 4).clamp(4, 64),
        shaping: pds::data::Shaping::Continuous,
        separation: 2.5,
        noise: 0.5,
    };
    let splits = spec.splits(case.n_train, 0, 64, 21);
    let mut pipe = PipelinedTrainer::from_pattern(
        &case.layers,
        &pattern,
        &PipelineConfig {
            epochs: 1,
            batch: case.batch,
            depth: 0,
            seed: 9,
            tune_kernel_threads: true,
            profile: true,
            ..Default::default()
        },
    )
    .expect("profiled pipelined trainer");
    pipe.train(&splits.train, &splits.test)
        .expect("profiled epoch");
    print!("{}", pipe.prof.report());
    let Json::Obj(mut obj) = pipe.prof.to_json() else {
        unreachable!("StageProf::to_json returns an object")
    };
    obj.insert("recorded".to_string(), Json::Bool(true));
    obj.insert("case".to_string(), Json::Str(case.name.to_string()));
    Json::Obj(obj)
}

fn main() {
    let cores = parallel::machine_threads();
    println!("train_pipeline bench: {cores} kernel threads available\n");
    let cases = [
        Case {
            name: "timit L=2",
            layers: vec![39, 390, 39],
            dout: vec![90, 9],
            batch: 128,
            n_train: 1024,
        },
        Case {
            name: "mnist L=4",
            layers: vec![800, 100, 100, 100, 10],
            dout: vec![20, 20, 20, 10],
            batch: 256,
            n_train: 2048,
        },
    ];
    let mut results = Vec::new();
    let mut max_speedup = 0f64;
    for case in &cases {
        let json = run_case(case);
        if let Some(s) = json.get("speedup").and_then(|v| v.as_f64()) {
            max_speedup = max_speedup.max(s);
        }
        results.push(json);
    }
    if cores >= 4 && max_speedup < 1.5 {
        eprintln!(
            "WARNING: best pipelined speedup {max_speedup:.2}X is below the 1.5X \
             acceptance target on {cores} cores"
        );
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("train_pipeline".to_string()));
    root.insert("recorded".to_string(), Json::Bool(true));
    root.insert(
        "kernel_threads_total".to_string(),
        Json::Num(cores as f64),
    );
    root.insert("cases".to_string(), Json::Arr(results));
    root.insert("max_speedup".to_string(), Json::Num(max_speedup));
    root.insert("target_speedup".to_string(), Json::Num(1.5));
    println!("\n-- per-stage profile ({}) --", cases[1].name);
    root.insert("profile".to_string(), profile_case(&cases[1]));
    let doc = Json::Obj(root);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train.json");
    // merge-write so sibling sections (actsparse) survive the refresh
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("train_pipeline: cannot write {out}: {e}"),
    }
}
