//! Serving-layer load bench: sustained throughput of the multi-worker
//! sharded inference service against the single-worker configuration,
//! under the same closed-loop load (see DESIGN.md §Perf).
//!
//! Two models are served concurrently to exercise the per-model worker
//! pools; each scenario starts a fresh service so its metrics cover
//! exactly that run. Writes the baseline numbers to `BENCH_serve.json`
//! at the repo root.
//!
//!     cargo bench --bench serve_load

use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    let load = LoadSpec {
        clients: 8,
        requests: 150,
        think_time: Duration::ZERO,
        burst: 1,
    };
    let mut scenarios = Vec::new();
    for workers in [1usize, 2, 4] {
        println!("== {workers} worker(s) per model ==");
        match loadgen::bench_service(
            dir,
            &models,
            workers,
            256,
            Duration::from_millis(2),
            &load,
            7,
            None,
        ) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                scenarios.push((workers, reports));
            }
            Err(e) => {
                eprintln!("serve_load: scenario with {workers} workers failed: {e:#}");
                return;
            }
        }
    }
    let t1: f64 = scenarios[0].1.iter().map(|r| r.throughput).sum();
    let (wn, last) = scenarios.last().unwrap();
    let tn: f64 = last.iter().map(|r| r.throughput).sum();
    println!(
        "\nsustained throughput: {tn:.0} req/s at {wn} workers vs {t1:.0} req/s single-worker \
         ({:.2}X)",
        tn / t1.max(1e-9)
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let doc = loadgen::bench_json(&scenarios);
    // merge-write so the quant_exec bench's section survives
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("serve_load: cannot write {out}: {e}"),
    }
}
