//! Serving-layer load bench: sustained throughput of the multi-worker
//! sharded inference service against the single-worker configuration,
//! under the same closed-loop load (see DESIGN.md §Perf).
//!
//! Two models are served concurrently to exercise the per-model worker
//! pools; each scenario starts a fresh service so its metrics cover
//! exactly that run. A second sweep holds the worker count fixed and
//! scales the tenant-context count (1/4/16 parameter banks per model)
//! to measure the cost of context-grouped batching under the same
//! offered load. Writes the baseline numbers to `BENCH_serve.json`
//! at the repo root.
//!
//!     cargo bench --bench serve_load

use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    let load = LoadSpec {
        clients: 8,
        requests: 150,
        think_time: Duration::ZERO,
        burst: 1,
        contexts: 1,
    };
    let mut scenarios = Vec::new();
    // axis 1: worker count at a single tenant context (the speedup
    // baseline); axis 2: tenant contexts at a fixed worker count
    let sweep: Vec<(usize, usize)> = [(1usize, 1usize), (2, 1), (4, 1), (2, 4), (2, 16)].to_vec();
    for (workers, contexts) in sweep {
        println!("== {workers} worker(s) per model, {contexts} tenant context(s) ==");
        let load = LoadSpec { contexts, ..load };
        match loadgen::bench_service(
            dir,
            &models,
            workers,
            256,
            Duration::from_millis(2),
            &load,
            7,
            None,
            None,
        ) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                scenarios.push((workers, reports));
            }
            Err(e) => {
                eprintln!(
                    "serve_load: scenario with {workers} workers x {contexts} contexts \
                     failed: {e:#}"
                );
                return;
            }
        }
    }
    // headline compares worker counts at a single tenant context; the
    // multi-context scenarios are recorded but not part of the speedup
    let single_ctx: Vec<_> = scenarios
        .iter()
        .filter(|(_, reports)| reports.first().is_some_and(|r| r.contexts == 1))
        .collect();
    let t1: f64 = single_ctx[0].1.iter().map(|r| r.throughput).sum();
    let (wn, last) = single_ctx.last().unwrap();
    let tn: f64 = last.iter().map(|r| r.throughput).sum();
    println!(
        "\nsustained throughput: {tn:.0} req/s at {wn} workers vs {t1:.0} req/s single-worker \
         ({:.2}X)",
        tn / t1.max(1e-9)
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let doc = loadgen::bench_json(&scenarios);
    // merge-write so the quant_exec bench's section survives
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("serve_load: cannot write {out}: {e}"),
    }
}
