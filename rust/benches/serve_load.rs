//! Serving-layer load bench: sustained throughput of the multi-worker
//! sharded inference service against the single-worker configuration,
//! under the same closed-loop load (see DESIGN.md §Perf).
//!
//! Two models are served concurrently to exercise the per-model worker
//! pools; each scenario starts a fresh service so its metrics cover
//! exactly that run. A second sweep holds the worker count fixed and
//! scales the tenant-context count (1/4/16 parameter banks per model)
//! to measure the cost of context-grouped batching under the same
//! offered load. Writes the baseline numbers to `BENCH_serve.json`
//! at the repo root.
//!
//!     cargo bench --bench serve_load

use std::collections::BTreeMap;
use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::obs::Sampler;
use pds::util::bench::bench;
use pds::util::json::Json;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    let load = LoadSpec {
        clients: 8,
        requests: 150,
        think_time: Duration::ZERO,
        burst: 1,
        contexts: 1,
    };
    let mut scenarios = Vec::new();
    // axis 1: worker count at a single tenant context (the speedup
    // baseline); axis 2: tenant contexts at a fixed worker count
    let sweep: Vec<(usize, usize)> = [(1usize, 1usize), (2, 1), (4, 1), (2, 4), (2, 16)].to_vec();
    for (workers, contexts) in sweep {
        println!("== {workers} worker(s) per model, {contexts} tenant context(s) ==");
        let load = LoadSpec { contexts, ..load };
        match loadgen::bench_service(
            dir,
            &models,
            workers,
            256,
            Duration::from_millis(2),
            &load,
            7,
            None,
            None,
        ) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                scenarios.push((workers, reports));
            }
            Err(e) => {
                eprintln!(
                    "serve_load: scenario with {workers} workers x {contexts} contexts \
                     failed: {e:#}"
                );
                return;
            }
        }
    }
    // headline compares worker counts at a single tenant context; the
    // multi-context scenarios are recorded but not part of the speedup
    let single_ctx: Vec<_> = scenarios
        .iter()
        .filter(|(_, reports)| reports.first().is_some_and(|r| r.contexts == 1))
        .collect();
    let t1: f64 = single_ctx[0].1.iter().map(|r| r.throughput).sum();
    let (wn, last) = single_ctx.last().unwrap();
    let tn: f64 = last.iter().map(|r| r.throughput).sum();
    println!(
        "\nsustained throughput: {tn:.0} req/s at {wn} workers vs {t1:.0} req/s single-worker \
         ({:.2}X)",
        tn / t1.max(1e-9)
    );
    let obs = obs_overhead_section(&single_ctx[0].1);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let mut doc = loadgen::bench_json(&scenarios);
    if let Json::Obj(root) = &mut doc {
        root.insert("obs_overhead".to_string(), obs);
    }
    // merge-write so the quant_exec bench's section survives
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("serve_load: cannot write {out}: {e}"),
    }
}

/// Measure the observability layer's *disabled-path* cost per request
/// and bound it against the measured request latency.
///
/// With tracing off, the only obs code an untraced request executes
/// beyond the pre-existing atomic counter bumps is one
/// [`Sampler::sample`] call at the net front door (the registry's
/// collector closures run at snapshot time, never per request; the
/// engine takes exec timestamps only when a group carries a trace). So
/// the disabled-path overhead is `sample()`'s cost over the request's
/// own service time — the ISSUE acceptance bound is < 2%.
fn obs_overhead_section(baseline: &[loadgen::LoadReport]) -> Json {
    let sampler = Sampler::new(0); // sampling disabled, the serve default
    const CALLS: u32 = 1024;
    let r = bench("obs disabled path (1024 sampler calls)", 3, 50, || {
        for _ in 0..CALLS {
            std::hint::black_box(sampler.sample());
        }
    });
    r.report();
    let ns_per_request = r.median.as_nanos() as f64 / CALLS as f64;
    // compare against the *fastest* model's median request so the
    // reported percentage is the worst case over the sweep
    let request_us = baseline
        .iter()
        .map(|rep| rep.p50.as_micros() as f64)
        .fold(f64::INFINITY, f64::min);
    let overhead_pct = 100.0 * (ns_per_request / 1e3) / request_us.max(1e-9);
    println!(
        "obs disabled-path overhead: {ns_per_request:.1}ns/request over a \
         {request_us:.0}us median request = {overhead_pct:.4}% (bound 2%)"
    );
    if overhead_pct >= 2.0 {
        eprintln!(
            "WARNING: observability disabled-path overhead {overhead_pct:.2}% \
             exceeds the 2% acceptance bound"
        );
    }
    let mut obj = BTreeMap::new();
    obj.insert("recorded".to_string(), Json::Bool(true));
    obj.insert(
        "disabled_path_ns_per_request".to_string(),
        Json::Num(ns_per_request),
    );
    obj.insert("request_us".to_string(), Json::Num(request_us));
    obj.insert("overhead_pct".to_string(), Json::Num(overhead_pct));
    obj.insert("bound_pct".to_string(), Json::Num(2.0));
    Json::Obj(obj)
}
