//! Native trainer kernels: masked-dense vs compacted-CSR step cost at the
//! paper's densities — the software realization of the "complexity
//! proportional to |W|" claim (Sec. II-B). This is the bench behind the
//! Table-II sweep wall-time and the §Perf hot-path iteration.

use pds::data::Spec;
use pds::nn::dense::DenseNet;
use pds::nn::sparse::SparseNet;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::bench::bench_auto;
use pds::util::rng::Rng;
use std::time::Duration;

fn main() {
    let layers = vec![800usize, 100, 10];
    let netc = NetConfig::new(layers.clone());
    let batch = 64usize;
    let mut rng = Rng::new(1);
    let spec = Spec::mnist_like();
    let ds = spec.generate(batch, &mut rng);
    let idx: Vec<usize> = (0..batch).collect();
    let (x, y) = ds.gather(&idx);

    println!("== native step cost vs density (N_net = (800,100,10), batch 64) ==");
    let dnet = DenseNet::init_he(&layers, 0.1, &mut rng);
    let fc_edges = 81_000f64;
    let r = bench_auto("dense FC fwd+bwd step", Duration::from_millis(800), || {
        std::hint::black_box(dnet.step(&x, &y, batch, 1e-4, None));
    });
    r.report_throughput("edges", fc_edges);
    let fc_time = r.median;

    for (d1, d2) in [(50usize, 10usize), (20, 10), (5, 10), (1, 10)] {
        let dout = DoutConfig(vec![d1, d2]);
        if netc.validate_dout(&dout).is_err() {
            continue;
        }
        let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
        let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
        let edges = snet.n_edges() as f64;
        let rho = netc.rho_net(&dout);
        let r = bench_auto(
            &format!("sparse step rho={:.1}%", rho * 100.0),
            Duration::from_millis(800),
            || {
                std::hint::black_box(snet.step(&x, &y, batch, 1e-4));
            },
        );
        r.report_throughput("edges", edges);
        println!(
            "    -> speedup over FC dense: {:.2}X (ideal 1/rho = {:.1}X)",
            fc_time.as_secs_f64() / r.median.as_secs_f64(),
            1.0 / rho
        );
    }

    println!("\n== raw matmul kernels ==");
    let (m, k, n) = (64usize, 800usize, 100usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
    let mut out = vec![0f32; m * n];
    let flops = (2 * m * k * n) as f64;
    bench_auto("matmul_nt 64x800x100", Duration::from_millis(800), || {
        pds::nn::matrix::matmul_nt(&a, &b, m, k, n, &mut out);
        std::hint::black_box(&out);
    })
    .report_throughput("flop", flops);
}
