//! Hardware-simulator throughput: edges simulated per second for
//! FF/BP/UP on the Table-I junction, plus the modeled FPGA throughput
//! (inputs/s at 100 MHz) this corresponds to — the bench behind the
//! Sec. III-A pipeline accounting.

use pds::hw::junction::{Act, JunctionUnit};
use pds::hw::pipeline::{speedup, throughput_inputs_per_sec};
use pds::hw::zconfig;
use pds::sparsity::clash_free::{schedule, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};
use pds::util::bench::bench_auto;
use pds::util::rng::Rng;
use std::time::Duration;

fn main() {
    let shape = JunctionShape { n_left: 800, n_right: 100 };
    let (d_out, z) = (20usize, 200usize);
    let d_in = shape.n_left * d_out / shape.n_right;
    let n_edges = (shape.n_right * d_in) as f64;
    let mut rng = Rng::new(1);
    let sched = schedule(800, z, d_out, Flavor::Type1 { dither: false }, &mut rng);
    let z_next = JunctionUnit::required_z_next(shape.n_right * d_in, z, d_in);
    let mut unit = JunctionUnit::new(shape, d_in, sched, z_next);
    let dense: Vec<f32> = (0..100 * 800).map(|_| rng.normal()).collect();
    unit.load_weights_dense(&dense);
    let a: Vec<f32> = (0..800).map(|_| rng.normal()).collect();
    let bias = vec![0.1f32; 100];
    let dr: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
    let adot = vec![1.0f32; 800];

    println!("== cycle-accurate simulator throughput (Table-I junction, 16k edges) ==");
    bench_auto("hw FF (800x100 @ z=200)", Duration::from_millis(500), || {
        std::hint::black_box(unit.feedforward(&a, &bias, Act::Relu).unwrap());
    })
    .report_throughput("edges", n_edges);
    bench_auto("hw BP", Duration::from_millis(500), || {
        std::hint::black_box(unit.backprop(&dr, &adot).unwrap());
    })
    .report_throughput("edges", n_edges);
    let mut b2 = bias.clone();
    bench_auto("hw UP", Duration::from_millis(500), || {
        std::hint::black_box(unit.update(&a, &dr, &mut b2, 1e-4).unwrap());
    })
    .report_throughput("edges", n_edges);

    println!("\n== modeled FPGA operating points (Sec. III-A) ==");
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout_cfg = DoutConfig(vec![20, 10]);
    for z0 in [40usize, 160, 320] {
        if let Ok(cfg) = zconfig::derive(&netc, &dout_cfg, z0) {
            println!(
                "z_net {:?}: C = {} cycles -> {:.0} inputs/s @ 100 MHz (speedup over sequential ~{:.1}X)",
                cfg.z,
                cfg.junction_cycle,
                throughput_inputs_per_sec(100e6, cfg.junction_cycle, 2),
                speedup(2, 100_000)
            );
        }
    }
}
