//! Activation-sparsity bench: sparse-sparse execution (pre-defined
//! weight sparsity + run-time top-k activation masking, `nn::actsparse`)
//! against the weight-sparse-only kernels on the same nets, at three
//! levels —
//!
//! 1. **kernel**: batched forward throughput of
//!    `SparseNet::logits_act` vs `SparseNet::logits` (f32) and
//!    `FixedSparseNet::logits_q_act` vs `logits_q` (Q5.10) on two
//!    Table-II configs, swept over a density axis (top-k fractions
//!    1, 1/2, 1/4, 1/8 of the hidden width) with the *achieved*
//!    activation density and the argmax agreement against the unmasked
//!    net recorded at every point,
//! 2. **train**: fused native train-step wall time with and without an
//!    `ActSpec` on the manifest entry (the sparse-sparse `step_act`
//!    path vs the dense-activation reference),
//! 3. **service**: sustained req/s of the multi-worker inference
//!    service with and without `--act-topk`, f32 and quantized
//!    ([`pds::coordinator::loadgen::bench_service`]).
//!
//! Merges an `actsparse` section into `BENCH_serve.json` (kernel +
//! service) and `BENCH_train.json` (train) at the repo root, preserving
//! the sibling benches' sections.
//!
//!     cargo bench --bench actsparse

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::nn::actsparse::ActSpec;
use pds::nn::fixed::{FixedSparseNet, QFormat};
use pds::nn::sparse::SparseNet;
use pds::runtime::Engine;
use pds::util::json::Json;
use pds::util::parallel;
use pds::util::rng::Rng;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Median wall-time of `reps` runs of `f`, in milliseconds.
fn time_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

/// Argmax agreement between two logit matrices, as a fraction.
fn agreement(a: &[f32], b: &[f32], batch: usize, classes: usize) -> f64 {
    let mut agree = 0usize;
    for i in 0..batch {
        let row = |l: &[f32]| {
            let r = &l[i * classes..(i + 1) * classes];
            (0..classes).max_by(|&x, &y| r[x].total_cmp(&r[y])).unwrap()
        };
        if row(a) == row(b) {
            agree += 1;
        }
    }
    agree as f64 / batch.max(1) as f64
}

/// Kernel-level sweep for one Table-II config: f32 and Q5.10 forward
/// throughput, weight-sparse-only vs sparse-sparse, over the top-k
/// density axis. Returns the config's JSON subsection.
fn kernel_sweep(dir: &str, config: &str, fmt: QFormat, reps: usize) -> Json {
    let layers = pds::runtime::Manifest::probe(dir, config).unwrap().layers;
    let batch = 256usize;
    let classes = *layers.last().unwrap();
    let spec = loadgen::model_spec(dir, config, 0.25, 17).unwrap();
    let mut rng = Rng::new(17);
    let snet = SparseNet::init_he(&spec.pattern, 0.1, &mut rng);
    let qnet = FixedSparseNet::from_f32(&snet, fmt);
    let x: Vec<f32> = (0..batch * layers[0])
        .map(|_| rng.uniform() * 2.0 - 1.0)
        .collect();
    let xq = fmt.quantize_slice(&x);

    // weight-sparse-only baselines
    let (base_logits, _) = (snet.logits(&x, batch), ());
    let f32_base_ms = time_ms(reps, || {
        snet.logits(&x, batch);
    });
    let q_base_ms = time_ms(reps, || {
        qnet.logits_q(&xq, batch);
    });

    // density axis: top-k at 1, 1/2, 1/4, 1/8 of the hidden width
    let hidden = &layers[1..layers.len() - 1];
    let max_hidden = hidden.iter().copied().max().unwrap_or(1);
    let min_hidden = hidden.iter().copied().min().unwrap_or(1);
    let mut points = Vec::new();
    for (label, k) in [
        ("1", max_hidden),
        ("1/2", (min_hidden / 2).max(1)),
        ("1/4", (min_hidden / 4).max(1)),
        ("1/8", (min_hidden / 8).max(1)),
    ] {
        let aspec = ActSpec::top_k(k);
        let (act_logits, stats) = snet.logits_act(&x, batch, &aspec);
        let f32_act_ms = time_ms(reps, || {
            snet.logits_act(&x, batch, &aspec);
        });
        let (_, _, qstats) = qnet.logits_q_act(&xq, batch, &aspec);
        let q_act_ms = time_ms(reps, || {
            qnet.logits_q_act(&xq, batch, &aspec);
        });
        let agree = agreement(&base_logits, &act_logits, batch, classes);
        println!(
            "  {config} topk({k}) density {:.3}: f32 {f32_act_ms:.3} ms vs {f32_base_ms:.3} ms \
             ({:.2}X), {fmt} {q_act_ms:.3} ms vs {q_base_ms:.3} ms ({:.2}X), \
             argmax agreement {:.1}%",
            stats.density(),
            f32_base_ms / f32_act_ms.max(1e-9),
            q_base_ms / q_act_ms.max(1e-9),
            agree * 100.0,
        );
        points.push(obj(vec![
            ("fraction", Json::Str(label.into())),
            ("k", Json::Num(k as f64)),
            ("density", Json::Num(stats.density())),
            ("quant_density", Json::Num(qstats.density())),
            ("f32_ms", Json::Num(f32_act_ms)),
            ("f32_speedup", Json::Num(f32_base_ms / f32_act_ms.max(1e-9))),
            ("quant_ms", Json::Num(q_act_ms)),
            ("quant_speedup", Json::Num(q_base_ms / q_act_ms.max(1e-9))),
            ("argmax_agreement", Json::Num(agree)),
        ]));
    }
    obj(vec![
        ("layers", Json::Arr(layers.iter().map(|&l| Json::Num(l as f64)).collect())),
        ("batch", Json::Num(batch as f64)),
        ("f32_base_ms", Json::Num(f32_base_ms)),
        ("quant_base_ms", Json::Num(q_base_ms)),
        ("densities", Json::Arr(points)),
    ])
}

/// Fused native train-step wall time with and without an `ActSpec` on
/// the manifest entry (same config, same seed, same minibatch).
fn train_step_sweep(dir: &str, config: &str, k: usize, reps: usize) -> anyhow::Result<Json> {
    let mut times = Vec::new();
    let mut losses = Vec::new();
    for act in [None, Some(ActSpec::top_k(k))] {
        let mut engine = Engine::new(dir.to_string())?;
        if let Some(spec) = act {
            engine.manifest.configs.get_mut(config).unwrap().act = Some(spec);
        }
        let entry = engine.manifest.configs.get(config).unwrap();
        let layers = entry.layers.clone();
        let batch = entry.batch;
        let netc = pds::sparsity::config::NetConfig::new(layers.clone());
        let dout = pds::sparsity::config::DoutConfig(
            entry
                .gather_dout
                .clone()
                .unwrap_or_else(|| netc.fc_dout().0.clone()),
        );
        let mut rng = Rng::new(29);
        let pattern = pds::sparsity::generate(pds::sparsity::Method::ClashFree, &netc, &dout, None, &mut rng);
        let mut session =
            pds::coordinator::TrainSession::new(&engine, config, &pattern, 1e-3, 1e-4, 29)?;
        let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..batch)
            .map(|_| (rng.uniform() * *layers.last().unwrap() as f32) as i32)
            .collect();
        session.step(&x, &y)?; // warmup
        let mut last_loss = 0f32;
        let ms = time_ms(reps, || {
            last_loss = session.step(&x, &y).unwrap().loss;
        });
        times.push(ms);
        losses.push(last_loss);
        println!(
            "  {config} train step ({}): {ms:.3} ms, loss {last_loss:.4}",
            match act {
                Some(a) => format!("{a}"),
                None => "dense activations".into(),
            }
        );
    }
    Ok(obj(vec![
        ("k", Json::Num(k as f64)),
        ("dense_ms", Json::Num(times[0])),
        ("act_ms", Json::Num(times[1])),
        ("act_speedup", Json::Num(times[0] / times[1].max(1e-9))),
        ("dense_loss", Json::Num(losses[0] as f64)),
        ("act_loss", Json::Num(losses[1] as f64)),
    ]))
}

fn main() {
    let fmt = QFormat::default();
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let configs = ["mnist_fc2", "timit"];
    println!("actsparse: sparse-sparse vs weight-sparse-only ({fmt} for the quantized lane)");

    // -- kernel level: both Table-II configs, both formats --
    let mut kernel = Vec::new();
    for config in configs {
        println!("== kernel sweep: {config} ==");
        kernel.push((config, kernel_sweep(dir, config, fmt, 20)));
    }

    // -- train level: fused native step with/without the ActSpec --
    let mut train = Vec::new();
    for config in configs {
        println!("== train step: {config} ==");
        match train_step_sweep(dir, config, 16, 10) {
            Ok(j) => train.push((config, j)),
            Err(e) => {
                eprintln!("actsparse: train sweep for {config} failed: {e:#}");
                return;
            }
        }
    }

    // -- service level: serve with/without --act-topk, f32 and quant --
    let models = vec!["mnist_fc2".to_string()];
    let load = LoadSpec {
        clients: 8,
        requests: 100,
        think_time: Duration::ZERO,
        burst: 1,
        contexts: 1,
    };
    let mut serve = Vec::new();
    for (quant, act) in [
        (None, None),
        (None, Some(ActSpec::top_k(16))),
        (Some(fmt), None),
        (Some(fmt), Some(ActSpec::top_k(16))),
    ] {
        let label = format!(
            "{}{}",
            match quant {
                Some(f) => format!("{f}"),
                None => "f32".into(),
            },
            match act {
                Some(a) => format!(" + {a}"),
                None => String::new(),
            }
        );
        println!("== service: {label} ==");
        match loadgen::bench_service(
            dir,
            &models,
            2,
            256,
            Duration::from_millis(2),
            &load,
            19,
            quant,
            act,
        ) {
            Ok(reports) => {
                for r in &reports {
                    r.print();
                }
                let rps: f64 = reports.iter().map(|r| r.throughput).sum();
                let density = reports.first().map(|r| r.act_density).unwrap_or(1.0);
                serve.push((label, quant.is_some(), act.is_some(), rps, density));
            }
            Err(e) => {
                eprintln!("actsparse: service scenario '{label}' failed: {e:#}");
                return;
            }
        }
    }

    // -- merge sections into the BENCH files --
    let serve_section = obj(vec![
        ("recorded", Json::Bool(true)),
        ("format", Json::Str(format!("{fmt}"))),
        (
            "kernel_threads_total",
            Json::Num(parallel::machine_threads() as f64),
        ),
        (
            "kernel",
            Json::Obj(
                kernel
                    .into_iter()
                    .map(|(c, j)| (c.to_string(), j))
                    .collect::<BTreeMap<_, _>>(),
            ),
        ),
        (
            "serve",
            Json::Arr(
                serve
                    .iter()
                    .map(|(label, quant, act, rps, density)| {
                        obj(vec![
                            ("scenario", Json::Str(label.clone())),
                            ("quant", Json::Bool(*quant)),
                            ("act", Json::Bool(*act)),
                            ("rps", Json::Num(*rps)),
                            ("density", Json::Num(*density)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out_serve = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match loadgen::write_bench_json(out_serve, obj(vec![("actsparse", serve_section)])) {
        Ok(()) => println!("merged actsparse section into {out_serve}"),
        Err(e) => eprintln!("actsparse: cannot write {out_serve}: {e}"),
    }

    let train_section = obj(vec![
        ("recorded", Json::Bool(true)),
        (
            "train",
            Json::Obj(
                train
                    .into_iter()
                    .map(|(c, j)| (c.to_string(), j))
                    .collect::<BTreeMap<_, _>>(),
            ),
        ),
    ]);
    let out_train = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train.json");
    match loadgen::write_bench_json(out_train, obj(vec![("actsparse", train_section)])) {
        Ok(()) => println!("merged actsparse section into {out_train}"),
        Err(e) => eprintln!("actsparse: cannot write {out_train}: {e}"),
    }
}
