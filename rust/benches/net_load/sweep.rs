//! Concurrency sweep: sustained throughput and achieved micro-batch
//! coalescing of the TCP front-end under concurrent pipelined socket
//! clients, against the 1-client x 1-pipeline batch-1 baseline. The
//! tail of the sweep holds concurrency fixed and scales the
//! tenant-context count (1/4/16 banks per model) to measure
//! context-grouped batching through the socket path.

use std::sync::Arc;
use std::time::Duration;

use pds::coordinator::loadgen::{self, SocketLoadSpec};
use pds::coordinator::{InferenceService, ServerConfig};
use pds::net::{NetServer, NetServerConfig};

fn run_scenario(
    dir: &str,
    models: &[String],
    spec: SocketLoadSpec,
    batch_window: Duration,
) -> anyhow::Result<Vec<loadgen::SocketLoadReport>> {
    let specs = models
        .iter()
        .map(|m| {
            // host as many parameter banks as the load will spread over
            loadgen::model_spec(dir, m, 0.25, 7).map(|s| s.with_contexts(spec.contexts.max(1)))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let svc = Arc::new(InferenceService::start(
        dir,
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_depth: 256,
            tune_kernel_threads: true,
        },
    )?);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            max_connections: 64,
            batch_window,
            ..Default::default()
        },
    )?;
    let reports = loadgen::run_socket_load(server.local_addr(), models, &spec, 0x5EED)?;
    let svc = server.shutdown()?;
    drop(svc);
    Ok(reports)
}

/// Run the whole sweep; a failing scenario aborts the sweep (partial
/// sweeps would record a misleading aggregate).
pub fn run(
    dir: &str,
    batch_window: Duration,
) -> anyhow::Result<Vec<(SocketLoadSpec, Vec<loadgen::SocketLoadReport>)>> {
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];
    // sweep offered concurrency: 1 client x 1 pipeline is the
    // batch-1 degenerate baseline; the others give the micro-batcher
    // something to coalesce
    let sweep = [
        SocketLoadSpec { clients: 1, requests: 64, pipeline: 1, contexts: 1 },
        SocketLoadSpec { clients: 4, requests: 96, pipeline: 8, contexts: 1 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 1 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 4 },
        SocketLoadSpec { clients: 8, requests: 96, pipeline: 8, contexts: 16 },
    ];
    let mut scenarios = Vec::new();
    for spec in sweep {
        println!(
            "== {} client(s) x pipeline {} x {} context(s) per model ==",
            spec.clients, spec.pipeline, spec.contexts
        );
        let reports = run_scenario(dir, &models, spec, batch_window).map_err(|e| {
            anyhow::anyhow!(
                "scenario {}x{}x{}: {e:#}",
                spec.clients,
                spec.pipeline,
                spec.contexts
            )
        })?;
        for r in &reports {
            r.print();
        }
        scenarios.push((spec, reports));
    }
    Ok(scenarios)
}
