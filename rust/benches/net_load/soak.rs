//! Mostly-idle connection soak: the reactor scale-out number. One
//! reactor thread multiplexes ~1k open connections (override with
//! `PDS_SOAK_CONNS`) while a small sweeper pool drives a heavy-tailed
//! request mix — per connection per round ~90% idle, ~9% one sample,
//! ~1% a pipelined burst — and the report records p99/p999 tail
//! latency plus the server's shed rate. The connection cap is set
//! above the population (4096) so a healthy run sheds nothing; a
//! nonzero shed rate in `BENCH_serve.json` is a finding, not noise.

use std::sync::Arc;
use std::time::Duration;

use pds::coordinator::loadgen::{self, SoakReport, SoakSpec};
use pds::coordinator::{InferenceService, ServerConfig};
use pds::net::{NetServer, NetServerConfig};

/// Run the soak against the `tiny` model (small enough that request
/// cost does not drown the multiplexing cost being measured).
pub fn run(dir: &str, batch_window: Duration) -> anyhow::Result<SoakReport> {
    let connections: usize = std::env::var("PDS_SOAK_CONNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let spec = SoakSpec {
        connections,
        ..SoakSpec::default()
    };
    println!(
        "== soak: {} mostly-idle connections, {} rounds, one reactor thread ==",
        spec.connections, spec.rounds
    );
    let model_spec = loadgen::model_spec(dir, "tiny", 0.25, 7)?;
    let svc = Arc::new(InferenceService::start(
        dir,
        vec![model_spec],
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_depth: 256,
            tune_kernel_threads: true,
        },
    )?);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            max_connections: 4096,
            batch_window,
            ..Default::default()
        },
    )?;
    let report = loadgen::run_soak_load(server.local_addr(), "tiny", &spec, 0x50AC)?;
    report.print();
    let peak = server
        .metrics()
        .peak_active
        .load(std::sync::atomic::Ordering::Relaxed);
    println!("   reactor peak {peak} concurrent connections");
    let svc = server.shutdown()?;
    drop(svc);
    Ok(report)
}
