//! Networked serving bench, one scenario module per concern:
//!
//! - [`sweep`] — sustained throughput and achieved micro-batch
//!   coalescing under concurrent pipelined socket clients, against the
//!   single-client baseline (the `net.scenarios` section of
//!   `BENCH_serve.json`).
//! - [`soak`] — the reactor scale-out claim: ~1k mostly-idle
//!   connections multiplexed by one reactor thread under a
//!   heavy-tailed request mix, reporting p99/p999 tail latency and the
//!   server's shed rate (the `net.soak` subsection).
//!
//! Each scenario starts a fresh service + `NetServer` on an ephemeral
//! loopback port, drives the socket load generators in
//! `coordinator::loadgen`, and reads the counters back over the wire.
//! The merged `net` section lands in `BENCH_serve.json` at the repo
//! root, preserving the `serve_load` and `quant_exec` sections.
//!
//!     cargo bench --bench net_load
//!
//! `PDS_SOAK_CONNS` overrides the soak's connection count (default
//! 1000; the reactor is sized for thousands, CI machines sometimes are
//! not).

mod soak;
mod sweep;

use std::time::Duration;

use pds::coordinator::loadgen;

const BATCH_WINDOW: Duration = Duration::from_micros(1000);

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let scenarios = match sweep::run(dir, BATCH_WINDOW) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("net_load: sweep failed: {e:#}");
            return;
        }
    };
    let soak_report = match soak::run(dir, BATCH_WINDOW) {
        Ok(r) => Some(r),
        Err(e) => {
            // the sweep's numbers are still worth recording; the soak
            // subsection stays at its placeholder
            eprintln!("net_load: soak failed: {e:#}");
            None
        }
    };
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let doc = loadgen::net_bench_json(&scenarios, BATCH_WINDOW, soak_report.as_ref());
    // print the same flush-weighted aggregate the document records, so
    // the console headline cannot diverge from BENCH_serve.json
    if let Some(mean) = doc
        .get("net")
        .and_then(|n| n.get("mean_coalesced_batch"))
        .and_then(|v| v.as_f64())
    {
        println!(
            "\nachieved mean coalesced batch size {mean:.2} \
             (pipelined socket traffic reaches the engine as batches)"
        );
    }
    // merge-write so the serve_load and quant_exec sections survive
    match loadgen::write_bench_json(out, doc) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("net_load: cannot write {out}: {e}"),
    }
}
