//! Property tests over coordinator-adjacent invariants that don't need
//! PJRT: native-trainer state management (sparse/dense equivalence,
//! mask fixedness under training), softmax-CE gradient structure, and
//! dataset batching.

use pds::data::{Dataset, Shaping, Spec};
use pds::nn::dense::DenseNet;
use pds::nn::sparse::SparseNet;
use pds::nn::softmax_ce;
use pds::prop_assert;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

#[test]
fn sparse_and_masked_dense_agree_on_random_nets() {
    for_all(
        "sparse == masked dense",
        61,
        24,
        |r| {
            let layers = vec![4 * (1 + r.below(8)), 4 * (1 + r.below(6)), 2 + r.below(8)];
            (layers, r.next_u64())
        },
        |case| {
            let (layers, seed) = case;
            let netc = NetConfig::new(layers.clone());
            let mut rng = Rng::new(*seed);
            let dout = DoutConfig(
                (0..2).map(|i| netc.junction(i).min_dout()).collect(),
            );
            netc.validate_dout(&dout)?;
            let pattern = generate(Method::Structured, &netc, &dout, None, &mut rng);
            let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
            let mut dnet = DenseNet::init_he(layers, 0.1, &mut rng);
            let mut masks = Vec::new();
            for (i, j) in snet.junctions.iter().enumerate() {
                let (w, m) = j.to_dense();
                dnet.w[i] = w;
                dnet.b[i] = j.bias.clone();
                masks.push(m);
            }
            dnet.set_masks(masks);
            let batch = 4;
            let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
            let y: Vec<i32> = (0..batch)
                .map(|_| rng.below(layers[2]) as i32)
                .collect();
            let so = snet.step(&x, &y, batch, 0.001);
            let dor = dnet.step(&x, &y, batch, 0.001, None);
            prop_assert!(
                (so.loss - dor.loss).abs() < 1e-4 * (1.0 + dor.loss.abs()),
                "loss {} vs {}",
                so.loss,
                dor.loss
            );
            prop_assert!(so.correct == dor.correct, "correct count");
            Ok(())
        },
    );
}

#[test]
fn excluded_weights_never_move_under_training() {
    for_all(
        "mask fixedness",
        67,
        12,
        |r| r.next_u64(),
        |&seed| {
            let spec = Spec {
                name: "prop",
                features: 16,
                classes: 4,
                latent_dim: 6,
                shaping: Shaping::Continuous,
                separation: 3.0,
                noise: 0.4,
            };
            let splits = spec.splits(120, 0, 40, seed);
            let netc = NetConfig::new(vec![16, 12, 4]);
            let dout = DoutConfig(vec![3, 2]);
            let mut rng = Rng::new(seed);
            let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
            let masks: Vec<Vec<f32>> = pattern.junctions.iter().map(|p| p.mask()).collect();
            let mut dnet = DenseNet::init_he(&[16, 12, 4], 0.1, &mut rng);
            dnet.set_masks(masks.clone());
            let mut net = pds::nn::trainer::Network::Dense(dnet);
            let cfg = pds::nn::trainer::TrainConfig {
                epochs: 3,
                batch: 16,
                seed,
                ..Default::default()
            };
            pds::nn::trainer::train(&mut net, &splits.train, &splits.test, &cfg);
            if let pds::nn::trainer::Network::Dense(n) = &net {
                for (i, m) in masks.iter().enumerate() {
                    for (idx, (&wv, &mv)) in n.w[i].iter().zip(m).enumerate() {
                        prop_assert!(
                            mv == 1.0 || wv == 0.0,
                            "junction {i} weight {idx} moved off-mask: {wv}"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn softmax_ce_gradient_structure() {
    for_all(
        "softmax-CE grads",
        71,
        64,
        |r| {
            let batch = 1 + r.below(8);
            let classes = 2 + r.below(10);
            let mut rng = r.fork();
            let logits: Vec<f32> = (0..batch * classes).map(|_| rng.normal() * 3.0).collect();
            let y: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();
            (logits, y, classes)
        },
        |case| {
            let (logits, y, classes) = case;
            let (loss, correct, d) = softmax_ce(logits, y, *classes);
            prop_assert!(loss >= 0.0 && loss.is_finite(), "loss {loss}");
            prop_assert!(correct <= y.len(), "correct > batch");
            for i in 0..y.len() {
                let row = &d[i * classes..(i + 1) * classes];
                let sum: f32 = row.iter().sum();
                prop_assert!(sum.abs() < 1e-5, "row {i} grads sum to {sum}");
                // target grad negative, all others positive
                prop_assert!(row[y[i] as usize] < 0.0, "target grad not negative");
                for (c, &g) in row.iter().enumerate() {
                    if c != y[i] as usize {
                        prop_assert!(g >= 0.0, "non-target grad negative");
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dataset_gather_preserves_rows() {
    for_all(
        "gather rows",
        73,
        32,
        |r| (r.next_u64(), 10 + r.below(50)),
        |&(seed, n)| {
            let spec = Spec {
                name: "prop",
                features: 9,
                classes: 3,
                latent_dim: 4,
                shaping: Shaping::Continuous,
                separation: 2.0,
                noise: 0.5,
            };
            let mut rng = Rng::new(seed);
            let ds: Dataset = spec.generate(n, &mut rng);
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            let take = &idx[..n / 2];
            let (x, y) = ds.gather(take);
            for (pos, &i) in take.iter().enumerate() {
                prop_assert!(
                    x[pos * 9..(pos + 1) * 9] == *ds.row(i),
                    "row {i} mangled at {pos}"
                );
                prop_assert!(y[pos] == ds.y[i], "label {i} mangled");
            }
            Ok(())
        },
    );
}

#[test]
fn lss_prune_hits_requested_density_and_keeps_magnitude_order() {
    for_all(
        "LSS prune",
        79,
        32,
        |r| (r.next_u64(), 1 + r.below(9)),
        |&(seed, tenths)| {
            let rho = tenths as f64 / 10.0;
            let mut rng = Rng::new(seed);
            let mut net = DenseNet::init_he(&[20, 15, 5], 0.1, &mut rng);
            net.prune_to_density(&[rho, 1.0]);
            let d = net.mask_densities();
            prop_assert!(
                (d[0] - rho).abs() < 0.05,
                "junction 1 density {} != {rho}",
                d[0]
            );
            // every surviving weight >= every pruned weight in magnitude
            let kept_min = net.w[0]
                .iter()
                .zip(&net.masks[0])
                .filter(|(_, &m)| m == 1.0)
                .map(|(w, _)| w.abs())
                .fold(f32::INFINITY, f32::min);
            prop_assert!(kept_min > 0.0 || rho == 0.0, "zero weight kept");
            Ok(())
        },
    );
}
