//! Golden regression tests for the paper-reproduction numbers behind
//! `pds exp table1` / `pds exp table3`.
//!
//! The values below are *committed* goldens, not recomputed from the
//! same formulas at test time: a refactor of `hw::storage` or
//! `sparsity::clash_free` that silently shifts a count must fail here,
//! because these are the numbers the paper comparison rests on
//! (Table I storage words and reduction factors; Table III clash-free
//! pattern-space sizes |S_Mi| and address-generation storage).

use pds::hw::storage::{training_storage, StorageComparison, StorageCost};
use pds::sparsity::clash_free::{address_storage_cost, pattern_space, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};

// ---------------------------------------------------------------------
// Table I — N_net = (800, 100, 10), sparse d_out = (20, 10)
// ---------------------------------------------------------------------

#[test]
fn golden_table1_fc_storage() {
    let net = NetConfig::new(vec![800, 100, 10]);
    let c = training_storage(&net, &net.fc_dout());
    // committed golden values (paper Table I, FC column)
    assert_eq!(c.activations, 4_300);
    assert_eq!(c.act_derivatives, 300);
    assert_eq!(c.deltas, 220);
    assert_eq!(c.biases, 110);
    assert_eq!(c.weights, 81_000);
    assert_eq!(c.total(), 85_930);
}

#[test]
fn golden_table1_sparse_storage_and_reductions() {
    let net = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    let c = training_storage(&net, &dout);
    // committed golden values (paper Table I, sparse column)
    assert_eq!(c.weights, 17_000);
    assert_eq!(c.total(), 21_930);
    let cmp = StorageComparison::new(&net, &dout);
    // paper: 3.9X memory, 4.8X compute
    assert!((cmp.memory_reduction() - 85_930.0 / 21_930.0).abs() < 1e-12);
    assert!((cmp.compute_reduction() - 81.0 / 17.0).abs() < 1e-12);
    // inference-only variant drops the training banks
    let inf = StorageCost::inference_only(&net, &dout);
    assert_eq!(inf.total(), 900 + 110 + 17_000);
}

// ---------------------------------------------------------------------
// Table III — junction (N_l, N_r, d_out, d_in, z) = (12, 12, 2, 2, 4)
// ---------------------------------------------------------------------

const T3_SHAPE: JunctionShape = JunctionShape {
    n_left: 12,
    n_right: 12,
};

#[test]
fn golden_table3_pattern_space_counts() {
    // committed goldens: (flavor, |S_Mi| exact, exact-formula?)
    // depth = N_l / z = 3; dither factor K = 4!/(2!)^2 = 6 (z % d_in = 0)
    let cases: [(Flavor, u128, bool); 6] = [
        (Flavor::Type1 { dither: false }, 81, true), // 3^4
        (Flavor::Type1 { dither: true }, 486, true), // 81 * 6
        (Flavor::Type2 { dither: false }, 6_561, true), // 3^8
        (Flavor::Type2 { dither: true }, 236_196, true), // 6561 * 36
        (Flavor::Type3 { dither: false }, 1_679_616, true), // 6^8
        (Flavor::Type3 { dither: true }, 60_466_176, true), // 6^8 * 36
    ];
    for (flavor, want, exact_formula) in cases {
        let got = pattern_space(T3_SHAPE, 2, 4, flavor);
        assert_eq!(got.exact, Some(want), "{flavor:?}");
        assert_eq!(got.is_exact_formula, exact_formula, "{flavor:?}");
        // the log10 channel must agree with the exact count
        assert!(
            (got.log10 - (want as f64).log10()).abs() < 1e-9,
            "{flavor:?}: log10 {} vs exact {want}",
            got.log10
        );
    }
}

#[test]
fn golden_table3_address_storage() {
    // committed goldens (Table III, last column), z = 4, d_out = 2
    let cases: [(Flavor, usize); 6] = [
        (Flavor::Type1 { dither: false }, 4),
        (Flavor::Type1 { dither: true }, 8),
        (Flavor::Type2 { dither: false }, 8),
        (Flavor::Type2 { dither: true }, 16),
        (Flavor::Type3 { dither: false }, 24),
        (Flavor::Type3 { dither: true }, 32),
    ];
    for (flavor, want) in cases {
        assert_eq!(address_storage_cost(T3_SHAPE, 2, 4, flavor), want, "{flavor:?}");
    }
}

#[test]
fn golden_table3_mnist_junction() {
    // the production-sized (800, 100, d_out=20, z=200) junction the
    // table3 harness also prints: counts overflow u128, so the goldens
    // pin the log10 channel and the storage words
    let big = JunctionShape {
        n_left: 800,
        n_right: 100,
    };
    let t1 = pattern_space(big, 20, 200, Flavor::Type1 { dither: false });
    // depth = 4: |S| = 4^200 -> log10 = 200 * log10(4)
    assert_eq!(t1.exact, None, "4^200 must overflow u128");
    // golden: 200 * log10(4) = 120.41199826559248
    assert!(
        (t1.log10 - 120.411_998_265_592_48).abs() < 1e-9,
        "type1 log10 {}",
        t1.log10
    );
    assert!(t1.is_exact_formula);
    let t3 = pattern_space(big, 20, 200, Flavor::Type3 { dither: true });
    // z = 200, d_in = 160: mutually non-divisible -> (z!)^d_out upper bound
    assert!(!t3.is_exact_formula);
    assert_eq!(t3.exact, None);
    assert_eq!(
        address_storage_cost(big, 20, 200, Flavor::Type1 { dither: false }),
        200
    );
    assert_eq!(
        address_storage_cost(big, 20, 200, Flavor::Type3 { dither: true }),
        20_000
    );
}
