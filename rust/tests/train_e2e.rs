//! End-to-end headline reproduction at test scale (the full run lives in
//! examples/train_mnist_like.rs): a pre-defined sparse net at ~21% density
//! trains through the runtime backend (native by default, PJRT behind the
//! `pjrt` feature) to accuracy near its FC twin while storing ~4X fewer
//! weights — the paper's core claim.

use pds::data::Spec;
use pds::runtime::Engine;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::Pattern;
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

#[test]
fn sparse_trains_close_to_fc_via_pjrt() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let Ok(engine) = Engine::new(dir) else {
        eprintln!("skipping e2e: artifacts not built");
        return;
    };
    let layers = engine.manifest.configs["tiny"].layers.clone();
    let netc = NetConfig::new(layers.clone());
    let spec = Spec {
        name: "e2e",
        features: layers[0],
        classes: *layers.last().unwrap(),
        latent_dim: 10,
        shaping: pds::data::Shaping::Continuous,
        separation: 2.5,
        noise: 0.5,
    };
    let splits = spec.splits(320, 0, 160, 21);

    let run = |pattern, seed| -> f64 {
        let mut session =
            pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 5e-3, 1e-4, seed)
                .unwrap();
        let mut rng = Rng::new(seed);
        for _ in 0..10 {
            session.epoch(&splits.train, &mut rng).unwrap();
        }
        session.check_mask_invariant().unwrap();
        session.evaluate(&splits.test).unwrap()
    };

    // FC twin
    let fc_pattern = pds::sparsity::pattern::NetPattern {
        junctions: (0..netc.n_junctions())
            .map(|i| Pattern::fully_connected(netc.junction(i)))
            .collect(),
    };
    let fc_acc = run(fc_pattern, 30);

    // ~25% density clash-free
    let dout = DoutConfig(vec![4, 2]);
    let mut rng = Rng::new(31);
    let sparse_pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    let rho = sparse_pattern.rho_net();
    let sparse_acc = run(sparse_pattern, 32);

    eprintln!("e2e: FC acc {fc_acc:.3}, sparse(rho={rho:.2}) acc {sparse_acc:.3}");
    assert!(fc_acc > 0.5, "FC failed to learn ({fc_acc})");
    assert!(
        sparse_acc > fc_acc - 0.15,
        "sparse {sparse_acc} too far below FC {fc_acc}"
    );
    assert!(rho < 0.3, "density {rho} not sparse");
}
