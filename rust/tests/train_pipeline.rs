//! Integration tests for the pipelined training engine (`nn::pipeline`
//! + `runtime::Engine::train_pipelined`):
//!
//! - depth-1 runs reproduce the sequential `nn::trainer` *bit for bit*
//!   (same kernels, same Adam trajectory, same shuffles),
//! - the full-depth schedule's measured weight staleness equals the
//!   paper's Sec. III-D closed form (cross-checked against the
//!   `hw::pipeline` model itself),
//! - bounded-staleness training still converges on the synthesized
//!   config (the paper's "no performance degradation" claim),
//! - the runtime engine exposes the path end to end and validates its
//!   inputs.
//!
//! No test here touches the global kernel-thread override — bit parity
//! relies on both paths running under the same thread budget.

use pds::data::Spec;
use pds::hw::pipeline::Pipeline;
use pds::nn::pipeline::{PipelineConfig, PipelinedTrainer};
use pds::nn::sparse::SparseNet;
use pds::nn::trainer::{self, Network, TrainConfig};
use pds::runtime::Engine;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn pattern_for(layers: &[usize], dout: &[usize], seed: u64) -> NetPattern {
    let netc = NetConfig::new(layers.to_vec());
    let mut rng = Rng::new(seed);
    generate(
        Method::Structured,
        &netc,
        &DoutConfig(dout.to_vec()),
        None,
        &mut rng,
    )
}

fn toy_splits(features: usize, classes: usize, n_train: usize, n_test: usize, seed: u64) -> (pds::data::Dataset, pds::data::Dataset) {
    let spec = Spec {
        name: "pipe-test",
        features,
        classes,
        latent_dim: (features / 3).max(4),
        shaping: pds::data::Shaping::Continuous,
        separation: 3.0,
        noise: 0.4,
    };
    let s = spec.splits(n_train, 0, n_test, seed);
    (s.train, s.test)
}

#[test]
fn depth_1_matches_sequential_trainer_bit_for_bit() {
    let layers = [20usize, 16, 12, 6];
    let pattern = pattern_for(&layers, &[8, 6, 3], 5);
    let (train_ds, test_ds) = toy_splits(20, 6, 200, 60, 11);
    let seed = 5u64;

    // sequential reference: same init draws, same shuffle recipe
    let mut init_rng = Rng::new(seed);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut init_rng);
    let mut seq_net = Network::Sparse(snet);
    let seq_cfg = TrainConfig {
        epochs: 3,
        batch: 32,
        l2: 1e-4,
        seed,
        ..Default::default()
    };
    let h_seq = trainer::train(&mut seq_net, &train_ds, &test_ds, &seq_cfg);

    // pipelined at depth 1: one batch in flight, staleness 0
    let mut pipe = PipelinedTrainer::from_pattern(
        &layers,
        &pattern,
        &PipelineConfig {
            epochs: 3,
            batch: 32,
            depth: 1,
            l2: 1e-4,
            seed,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(pipe.depth(), 1);
    let h_pipe = pipe.train(&train_ds, &test_ds).unwrap();

    // histories agree to the bit
    assert_eq!(h_seq.epochs.len(), h_pipe.epochs.len());
    for (a, b) in h_seq.epochs.iter().zip(&h_pipe.epochs) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {} train loss diverged: {} vs {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc", a.epoch);
        assert_eq!(a.test_acc, b.test_acc, "epoch {} test acc", a.epoch);
    }
    // ...and so do all trained parameters
    let seq_snet = match &seq_net {
        Network::Sparse(n) => n,
        _ => unreachable!(),
    };
    for (j, (sj, pj)) in seq_snet
        .junctions
        .iter()
        .zip(&pipe.net().junctions)
        .enumerate()
    {
        for (e, (a, b)) in sj.wc.iter().zip(&pj.wc).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "junction {j} weight {e}: {a} vs {b}"
            );
        }
        for (a, b) in sj.bias.iter().zip(&pj.bias) {
            assert_eq!(a.to_bits(), b.to_bits(), "junction {j} bias diverged");
        }
    }
    // sequential-equivalent schedule measures zero staleness
    for i in 1..=3 {
        assert_eq!(pipe.measured_staleness(i), Some(0), "junction {i}");
        assert_eq!(pipe.expected_staleness(i), 0);
    }
}

#[test]
fn full_depth_staleness_matches_paper_closed_form() {
    let layers = [20usize, 16, 12, 6];
    let l = layers.len() - 1;
    let pattern = pattern_for(&layers, &[8, 6, 3], 7);
    let (train_ds, _) = toy_splits(20, 6, 320, 32, 13);
    let mut pipe = PipelinedTrainer::from_pattern(
        &layers,
        &pattern,
        &PipelineConfig {
            batch: 32,
            depth: 0, // full Fig. 2c schedule
            seed: 7,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(pipe.stride(), 1);
    assert_eq!(pipe.depth(), 2 * l);
    let mut rng = Rng::new(17);
    pipe.epoch(&train_ds, &mut rng).unwrap();

    let model = Pipeline::new(l);
    for i in 1..=l {
        let want = model.staleness(i); // 2(L-i)+1
        assert_eq!(
            pipe.measured_staleness(i),
            Some(want),
            "junction {i}: live run disagrees with Sec. III-D"
        );
        assert_eq!(pipe.expected_staleness(i), want);
        // the analytical model measures the same value on its own timetable
        assert_eq!(model.measured_staleness(i, 200), Some(want));
    }
    // steady state co-schedules 3L - 1 operations per junction cycle
    assert_eq!(pipe.metrics.max_ops_in_tau, 3 * l - 1);
    // 320 samples / batch 32 = 10 minibatches, all retired
    assert_eq!(pipe.metrics.flights, 10);
    pipe.audit_banked().unwrap();
}

#[test]
fn bounded_staleness_training_converges() {
    // Sec. III-D: "no performance degradation due to this variation from
    // the standard backpropagation algorithm"
    let layers = [16usize, 24, 4];
    let pattern = pattern_for(&layers, &[12, 2], 1);
    let (train_ds, test_ds) = toy_splits(16, 4, 400, 120, 19);
    let mut pipe = PipelinedTrainer::from_pattern(
        &layers,
        &pattern,
        &PipelineConfig {
            epochs: 16,
            batch: 32,
            depth: 0,
            seed: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let h = pipe.train(&train_ds, &test_ds).unwrap();
    assert!(
        h.final_test_acc() > 0.7,
        "stale pipelined training collapsed: acc {} (chance 0.25)",
        h.final_test_acc()
    );
    assert!(h.epochs[0].train_loss > h.epochs.last().unwrap().train_loss);
    // full schedule for L = 2: staleness (3, 1)
    assert_eq!(pipe.measured_staleness(1), Some(3));
    assert_eq!(pipe.measured_staleness(2), Some(1));
}

#[test]
fn runtime_engine_exposes_the_pipelined_path() {
    let engine = Engine::native("/nonexistent/dir").unwrap();
    let layers = engine.manifest.configs["tiny"].layers.clone();
    let netc = NetConfig::new(layers.clone());
    let mut rng = Rng::new(3);
    let pattern = generate(Method::ClashFree, &netc, &DoutConfig(vec![4, 2]), None, &mut rng);

    let cfg = PipelineConfig {
        seed: 3,
        batch: 0, // adopt the manifest config's batch
        ..Default::default()
    };
    let mut session =
        pds::coordinator::PipelinedTrainSession::new(&engine, "tiny", &pattern, &cfg).unwrap();
    // batch 0 adopts the config's batch
    assert_eq!(session.batch, engine.manifest.configs["tiny"].batch);
    let (train_ds, test_ds) = toy_splits(layers[0], *layers.last().unwrap(), 160, 64, 23);
    let mut erng = Rng::new(29);
    let mut last_loss = f32::INFINITY;
    for _ in 0..3 {
        let (loss, acc) = session.epoch(&train_ds, &mut erng).unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        last_loss = loss;
    }
    assert!(last_loss.is_finite());
    let acc = session.evaluate(&test_ds);
    assert!((0.0..=1.0).contains(&acc));
    session.trainer().audit_banked().unwrap();
    assert!(session.metrics().taus > 0);

    // validation: unknown config and mismatched pattern are rejected
    assert!(engine.train_pipelined("bogus", &pattern, &cfg).is_err());
    assert!(engine.train_pipelined("timit", &pattern, &cfg).is_err());
}
