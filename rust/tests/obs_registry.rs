//! Observability-layer integration tests: the metrics registry over a
//! *real* `InferenceService` (one snapshot supersedes the ad-hoc metric
//! structs), plus the monotonic-clock audit — no runtime path may use
//! `SystemTime`, whose jumps (NTP steps, suspend/resume) would corrupt
//! latency histograms, trace spans, and profile timings. `Instant` is
//! the only clock allowed outside of explicitly wall-clock contexts.

use std::sync::Arc;
use std::time::Duration;

use pds::coordinator::loadgen;
use pds::coordinator::{InferenceService, ServerConfig};
use pds::util::json::Json;
use pds::util::rng::Rng;

fn dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

/// Recursively collect every `.rs` file under `root`.
fn rust_sources(root: &std::path::Path, out: &mut Vec<std::path::PathBuf>) {
    for entry in std::fs::read_dir(root).expect("readable source dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_sources(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Monotonic-clock regression: every timestamp on the serving, tracing,
/// profiling, and benching paths must come from `Instant`. A
/// `SystemTime` creeping in would go unnoticed until a clock step
/// produced a negative or absurd latency in production, so the source
/// tree itself is the test surface.
#[test]
fn runtime_paths_use_monotonic_clocks_only() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    rust_sources(&src, &mut files);
    assert!(
        files.len() > 20,
        "source scan found suspiciously few files ({})",
        files.len()
    );
    let mut offenders = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
        for (i, line) in text.lines().enumerate() {
            if line.contains("SystemTime") {
                offenders.push(format!("{}:{}: {}", path.display(), i + 1, line.trim()));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "SystemTime found on runtime paths (use Instant — wall clocks \
         jump):\n{}",
        offenders.join("\n")
    );
}

/// The tentpole acceptance: one registry snapshot over a live service
/// carries the engine counters, gauges, and the latency histogram —
/// exactly what the CLI dump, the wire Metrics frame, and the load
/// generators consume — and both expositions (JSON, Prometheus text)
/// render it faithfully.
#[test]
fn registry_snapshot_covers_a_live_service() {
    const REQUESTS: usize = 12;
    let spec = loadgen::model_spec(dir(), "tiny", 0.25, 51).unwrap();
    let svc = InferenceService::start(
        dir(),
        vec![spec],
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 2,
            queue_depth: 64,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let client = svc.client("tiny").unwrap();
    let mut rng = Rng::new(0x0B5);
    for _ in 0..REQUESTS {
        let x: Vec<f32> = (0..client.features()).map(|_| rng.normal()).collect();
        client.classify(x).unwrap();
    }
    let labels: &[(&str, &str)] = &[("model", "tiny")];
    let snap = svc.registry().snapshot();
    assert_eq!(
        snap.counter("serve.requests", labels),
        Some(REQUESTS as u64),
        "the registry counter must equal the requests served"
    );
    assert_eq!(snap.counter("serve.rejected", labels), Some(0));
    let batches = snap
        .counter("serve.batches", labels)
        .expect("serve.batches counter");
    assert!(batches >= 1 && batches <= REQUESTS as u64);
    let hist = snap
        .histogram("serve.latency", labels)
        .expect("serve.latency histogram");
    assert_eq!(hist.count, REQUESTS as u64);
    assert!(hist.p50_us >= 1 && hist.p50_us <= hist.p99_us);
    assert_eq!(hist.overflow, 0);
    assert_eq!(snap.gauge("serve.workers", labels), Some(2.0));
    assert!(snap.gauge("serve.occupancy_mean", labels).is_some());

    // JSON exposition parses and carries the same counter
    let parsed = Json::parse(&snap.to_json().to_string()).unwrap();
    let samples = parsed.get("samples").unwrap().as_arr().unwrap();
    assert!(
        samples.iter().any(|s| {
            s.get("name").and_then(|v| v.as_str()) == Some("serve.requests")
                && s.get("value").and_then(|v| v.as_usize()) == Some(REQUESTS)
        }),
        "JSON exposition must carry serve.requests = {REQUESTS}"
    );
    // Prometheus text exposition renders labelled series
    let prom = snap.to_prometheus();
    assert!(prom.contains("# TYPE serve_requests counter"));
    assert!(prom.contains(&format!("serve_requests{{model=\"tiny\"}} {REQUESTS}")));
    assert!(prom.contains("serve_latency_us_count{model=\"tiny\"}"));
    // human report lists the same series
    assert!(snap.report().contains("serve.requests{model=tiny}"));

    // a second snapshot after more traffic moves monotonically
    let x: Vec<f32> = (0..client.features()).map(|_| rng.normal()).collect();
    client.classify(x).unwrap();
    let snap2 = svc.registry().snapshot();
    assert_eq!(
        snap2.counter("serve.requests", labels),
        Some(REQUESTS as u64 + 1)
    );
    drop(client);
    svc.shutdown().unwrap();
}

/// Collectors hold `Weak` subsystem handles: registering them must not
/// extend the service's lifetime — the `Arc::try_unwrap` teardown the
/// TCP front-end relies on still succeeds after snapshots were taken.
#[test]
fn registry_collectors_do_not_block_service_teardown() {
    let spec = loadgen::model_spec(dir(), "tiny", 0.25, 52).unwrap();
    let svc = Arc::new(
        InferenceService::start(
            dir(),
            vec![spec],
            ServerConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_depth: 16,
                tune_kernel_threads: false,
            },
        )
        .unwrap(),
    );
    let registry = Arc::clone(svc.registry());
    let _snap = registry.snapshot();
    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown().unwrap(),
        Err(_) => panic!("registry collectors must not hold strong service refs"),
    }
    // after teardown the collectors' Weak upgrades fail: the snapshot
    // simply loses those samples instead of erroring
    let after = registry.snapshot();
    assert_eq!(
        after.counter("serve.requests", &[("model", "tiny")]),
        None,
        "dead subsystems must vanish from snapshots, not dangle"
    );
}
