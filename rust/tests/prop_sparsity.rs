//! Property tests over the sparsity module: structural invariants of every
//! pattern family under randomized shapes/degrees/seeds.

use pds::prop_assert;
use pds::sparsity::clash_free::{self, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};
use pds::sparsity::{attention, generate, random, structured, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;
use pds::util::{ceil_div, gcd};

/// Random admissible (shape, d_out) with d_in integral.
fn junction_case(r: &mut Rng) -> (JunctionShape, usize) {
    loop {
        let n_left = 2 + r.below(60);
        let n_right = 2 + r.below(40);
        let shape = JunctionShape { n_left, n_right };
        let step = shape.min_dout();
        let max_k = n_right / step;
        if max_k == 0 {
            continue;
        }
        let d_out = step * (1 + r.below(max_k));
        return (shape, d_out);
    }
}

#[test]
fn structured_patterns_have_exact_degrees() {
    for_all(
        "structured degrees",
        11,
        96,
        |r| {
            let (shape, d_out) = junction_case(r);
            (shape, d_out, r.next_u64())
        },
        |&(shape, d_out, seed)| {
            let p = structured::generate(shape, d_out, &mut Rng::new(seed));
            p.audit()?;
            let d_in = shape.n_left * d_out / shape.n_right;
            prop_assert!(p.is_structured(), "not structured");
            prop_assert!(
                p.out_degrees().iter().all(|&d| d == d_out),
                "out-degree wrong"
            );
            prop_assert!(p.in_degrees().iter().all(|&d| d == d_in), "in-degree wrong");
            Ok(())
        },
    );
}

#[test]
fn clash_free_schedules_never_clash_and_cover_each_sweep() {
    for_all(
        "clash-free schedule",
        13,
        64,
        |r| {
            let z_choices = [1usize, 2, 3, 4, 5, 6, 8, 10, 12];
            let z = z_choices[r.below(z_choices.len())];
            let depth = 1 + r.below(12);
            let n_left = z * depth;
            let d_out = 1 + r.below(6);
            let flavor = match r.below(6) {
                0 => Flavor::Type1 { dither: false },
                1 => Flavor::Type1 { dither: true },
                2 => Flavor::Type2 { dither: false },
                3 => Flavor::Type2 { dither: true },
                4 => Flavor::Type3 { dither: false },
                _ => Flavor::Type3 { dither: true },
            };
            (n_left, z, d_out, flavor, r.next_u64())
        },
        |&(n_left, z, d_out, flavor, seed)| {
            let s = clash_free::schedule(n_left, z, d_out, flavor, &mut Rng::new(seed));
            s.verify_clash_free().map_err(|e| e.to_string())?;
            prop_assert!(
                s.cycles.len() == d_out * n_left / z,
                "cycle count {} != {}",
                s.cycles.len(),
                d_out * n_left / z
            );
            Ok(())
        },
    );
}

#[test]
fn clash_free_patterns_are_structured_and_respect_right_bound() {
    for_all(
        "clash-free pattern",
        17,
        48,
        |r| {
            // need z | n_left and d_in integral: build from factors
            let z = 1 + r.below(8);
            let depth = 1 + r.below(8);
            let n_left = z * depth;
            let n_right = 1 + r.below(24);
            let step = n_right / gcd(n_left, n_right);
            let d_out = step * (1 + r.below((n_right / step).max(1)));
            (
                JunctionShape { n_left, n_right },
                d_out.min(n_right),
                z,
                r.next_u64(),
            )
        },
        |&(shape, d_out, z, seed)| {
            if (shape.n_left * d_out) % shape.n_right != 0 || d_out == 0 {
                return Ok(()); // inadmissible draw, skip
            }
            let p = clash_free::generate(
                shape,
                d_out,
                z,
                Flavor::Type1 { dither: false },
                &mut Rng::new(seed),
            );
            p.audit()?;
            prop_assert!(p.is_structured(), "clash-free must be structured");
            let d_in = shape.n_left * d_out / shape.n_right;
            // Sec. III-B bound: the z edges of one cycle span at most
            // ceil(z/d_in) distinct right neurons when groups align, +1
            // when a neuron straddles the cycle boundary
            let bound = ceil_div(z, d_in) + 1;
            let n_edges = p.n_edges();
            for t in 0..n_edges / z {
                let rights: std::collections::BTreeSet<usize> =
                    (t * z..(t + 1) * z).map(|e| e / d_in).collect();
                prop_assert!(rights.len() <= bound, "rights {} > bound {bound}", rights.len());
            }
            Ok(())
        },
    );
}

#[test]
fn random_patterns_place_exact_edges() {
    for_all(
        "random edges",
        19,
        96,
        |r| {
            let shape = JunctionShape {
                n_left: 1 + r.below(50),
                n_right: 1 + r.below(30),
            };
            let n_edges = r.below(shape.n_left * shape.n_right + 1);
            (shape, n_edges, r.next_u64())
        },
        |&(shape, n_edges, seed)| {
            let p = random::generate(shape, n_edges, &mut Rng::new(seed));
            p.audit()?;
            prop_assert!(p.n_edges() == n_edges, "edge count");
            Ok(())
        },
    );
}

#[test]
fn attention_patterns_hit_edge_budget_with_min_degree_one() {
    for_all(
        "attention pattern",
        23,
        48,
        |r| {
            let n_left = 4 + r.below(40);
            let n_right = 4 + r.below(20);
            let base = 1 + r.below(n_right.min(8));
            let seed = r.next_u64();
            (n_left, n_right, base, seed)
        },
        |&(n_left, n_right, base, seed)| {
            let mut rng = Rng::new(seed);
            let var: Vec<f32> = (0..n_left).map(|_| rng.uniform() * 10.0).collect();
            let d = attention::variance_out_degrees(&var, base, n_right);
            prop_assert!(
                d.iter().sum::<usize>() == n_left * base,
                "budget {} != {}",
                d.iter().sum::<usize>(),
                n_left * base
            );
            prop_assert!(d.iter().all(|&x| x >= 1 && x <= n_right), "degree bounds");
            let p = attention::generate_with_out_degrees(
                JunctionShape { n_left, n_right },
                &d,
                &mut rng,
            );
            p.audit()?;
            prop_assert!(
                p.disconnected_left() == 0,
                "attention must not disconnect inputs"
            );
            Ok(())
        },
    );
}

#[test]
fn density_sets_match_appendix_a() {
    for_all(
        "density set",
        29,
        128,
        |r| JunctionShape {
            n_left: 1 + r.below(200),
            n_right: 1 + r.below(200),
        },
        |&shape| {
            let set = shape.density_set();
            prop_assert!(
                set.len() == gcd(shape.n_left, shape.n_right),
                "cardinality != gcd"
            );
            for &rho in &set {
                let d_out = (rho * shape.n_right as f64).round() as usize;
                prop_assert!(
                    (shape.n_left * d_out) % shape.n_right == 0,
                    "rho {rho} gives fractional d_in"
                );
            }
            prop_assert!((set.last().unwrap() - 1.0).abs() < 1e-12, "max density != 1");
            Ok(())
        },
    );
}

#[test]
fn whole_net_generation_consistency() {
    for_all(
        "net pattern",
        31,
        32,
        |r| {
            let l = 2 + r.below(3);
            let mut layers = vec![8 * (1 + r.below(6))];
            for _ in 0..l {
                layers.push(4 * (1 + r.below(8)));
            }
            (layers, r.next_u64())
        },
        |case| {
            let (layers, seed) = case;
            let netc = NetConfig::new(layers.clone());
            let mut rng = Rng::new(*seed);
            let dout = DoutConfig(
                (0..netc.n_junctions())
                    .map(|i| netc.junction(i).min_dout())
                    .collect(),
            );
            netc.validate_dout(&dout)?;
            for method in Method::ALL {
                let p = generate(method, &netc, &dout, None, &mut rng);
                let expect: usize = netc.edges(&dout).iter().sum();
                prop_assert!(
                    p.junctions.iter().map(|j| j.n_edges()).sum::<usize>() == expect,
                    "{}: edge total",
                    method.name()
                );
                prop_assert!(
                    (p.rho_net() - netc.rho_net(&dout)).abs() < 1e-9,
                    "{}: rho mismatch",
                    method.name()
                );
            }
            Ok(())
        },
    );
}
