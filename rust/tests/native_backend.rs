//! Integration tests for the pure-Rust `runtime::NativeEngine` backend:
//! forward / train_step parity against the `nn` reference trainer on a
//! small clash-free network, mask-invariant training end to end, parallel
//! kernel consistency, and (behind the `pjrt` feature) parity between the
//! PJRT artifact path and the native path. These run unconditionally —
//! the native backend needs no artifact files.

use pds::nn::adam::{Adam, AdamConfig};
use pds::nn::dense::DenseNet;
use pds::nn::sparse::SparseNet;
use pds::runtime::{Engine, Value};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::parallel;
use pds::util::rng::Rng;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_pattern(engine: &Engine, dout: &[usize], seed: u64) -> NetPattern {
    let layers = engine.manifest.configs["tiny"].layers.clone();
    let net = NetConfig::new(layers);
    let mut rng = Rng::new(seed);
    generate(
        Method::ClashFree,
        &net,
        &DoutConfig(dout.to_vec()),
        None,
        &mut rng,
    )
}

/// Two fused native train steps == two reference masked-dense steps with
/// the reference Adam (identical init, t = 1 then t = 2).
#[test]
fn native_train_step_matches_reference_trainer() {
    let engine = Engine::native(DIR).unwrap();
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let pattern = tiny_pattern(&engine, &[8, 4], 5);
    let mut session =
        pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 1e-3, 1e-3, 6).unwrap();

    // mirror initial params into the reference dense net
    let mut dnet = DenseNet::init_he(&layers, 0.1, &mut Rng::new(0));
    for i in 0..dnet.n_junctions() {
        dnet.w[i] = session.param(i, false).as_f32().unwrap().to_vec();
        dnet.b[i] = session.param(i, true).as_f32().unwrap().to_vec();
    }
    dnet.set_masks(pattern.junctions.iter().map(|p| p.mask()).collect());
    let mut opt = Adam::new(
        AdamConfig {
            lr: 1e-3,
            ..Default::default()
        },
        &dnet
            .w
            .iter()
            .zip(&dnet.b)
            .map(|(w, b)| (w.len(), b.len()))
            .collect::<Vec<_>>(),
    );

    let mut rng = Rng::new(7);
    for step in 0..2 {
        let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..batch)
            .map(|_| rng.below(layers[layers.len() - 1]) as i32)
            .collect();
        let out = session.step(&x, &y).unwrap();
        let native = dnet.step(&x, &y, batch, 1e-3, None);
        assert_eq!(out.correct, native.correct, "step {step}");
        assert!(
            (out.loss - native.loss).abs() < 1e-5 * (1.0 + native.loss.abs()),
            "step {step} loss {} vs {}",
            out.loss,
            native.loss
        );
        opt.step(&mut dnet.w, &mut dnet.b, &native.grads.gw, &native.grads.gb);
        for i in 0..dnet.n_junctions() {
            let got_w = session.param(i, false).as_f32().unwrap();
            for (idx, (g, w)) in got_w.iter().zip(&dnet.w[i]).enumerate() {
                assert!(
                    (g - w).abs() < 1e-5 * (1.0 + w.abs()),
                    "step {step} junction {i} w[{idx}]: {g} vs {w}"
                );
            }
            let got_b = session.param(i, true).as_f32().unwrap();
            for (idx, (g, b)) in got_b.iter().zip(&dnet.b[i]).enumerate() {
                assert!(
                    (g - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "step {step} junction {i} b[{idx}]: {g} vs {b}"
                );
            }
        }
    }
    assert_eq!(session.step_count(), 2);
}

/// Session logits through the native `forward` program == the reference
/// masked-dense logits on mirrored parameters.
#[test]
fn native_forward_matches_reference_trainer() {
    let engine = Engine::native(DIR).unwrap();
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let pattern = tiny_pattern(&engine, &[4, 2], 9);
    let session =
        pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 1e-3, 0.0, 11).unwrap();
    let mut dnet = DenseNet::init_he(&layers, 0.1, &mut Rng::new(1));
    for i in 0..dnet.n_junctions() {
        dnet.w[i] = session.param(i, false).as_f32().unwrap().to_vec();
        dnet.b[i] = session.param(i, true).as_f32().unwrap().to_vec();
    }
    dnet.set_masks(pattern.junctions.iter().map(|p| p.mask()).collect());
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    let got = session.logits(&x).unwrap();
    let want = dnet.logits(&x, batch);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

/// The compacted gather_forward program == the masked-dense forward
/// program on the same pattern and weights.
#[test]
fn native_gather_forward_matches_masked_forward() {
    let engine = Engine::native(DIR).unwrap();
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let dout: Vec<usize> = entry.gather_dout.clone().unwrap();
    let net = NetConfig::new(layers.clone());
    let mut rng = Rng::new(9);
    let pattern = generate(Method::ClashFree, &net, &DoutConfig(dout), None, &mut rng);

    let forward = engine.load("tiny", "forward").unwrap();
    let gather = engine.load("tiny", "gather_forward").unwrap();
    let mut dense_inputs: Vec<Value> = Vec::new();
    let mut wcs: Vec<Value> = Vec::new();
    let mut idxs: Vec<Value> = Vec::new();
    let mut biases: Vec<Value> = Vec::new();
    for (i, p) in pattern.junctions.iter().enumerate() {
        let (nl, nr) = (layers[i], layers[i + 1]);
        let w: Vec<f32> = (0..nr * nl).map(|_| rng.normal()).collect();
        let mask = p.mask();
        let masked: Vec<f32> = w.iter().zip(&mask).map(|(w, m)| w * m).collect();
        let b: Vec<f32> = (0..nr).map(|_| rng.normal()).collect();
        let (idx, din) = p.compact_indices().unwrap();
        wcs.push(Value::F32(p.compact_weights(&masked), vec![nr, din]));
        idxs.push(Value::I32(idx, vec![nr, din]));
        biases.push(Value::F32(b.clone(), vec![nr]));
        dense_inputs.push(Value::F32(masked, vec![nr, nl]));
        dense_inputs.push(Value::F32(b, vec![nr]));
    }
    for p in &pattern.junctions {
        dense_inputs.push(Value::F32(
            p.mask(),
            vec![p.shape.n_right, p.shape.n_left],
        ));
    }
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    dense_inputs.push(Value::F32(x.clone(), vec![batch, layers[0]]));
    let want = forward.run(&dense_inputs).unwrap();

    let mut gather_inputs = wcs;
    gather_inputs.extend(idxs);
    gather_inputs.extend(biases);
    gather_inputs.push(Value::F32(x, vec![batch, layers[0]]));
    let got = gather.run(&gather_inputs).unwrap();

    for (g, w) in got[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(want[0].as_f32().unwrap())
    {
        assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

/// Full training runs on the native backend: loss falls, accuracy beats
/// chance, and the pre-defined sparsity contract (excluded weights stay
/// exactly zero) holds after many Adam steps.
#[test]
fn native_session_trains_and_keeps_mask_invariant() {
    let engine = Engine::native(DIR).unwrap();
    let pattern = tiny_pattern(&engine, &[8, 4], 1);
    let mut session =
        pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 5e-3, 1e-4, 2).unwrap();
    let spec = pds::data::Spec {
        name: "native-e2e",
        features: session.layers[0],
        classes: *session.layers.last().unwrap(),
        latent_dim: 8,
        shaping: pds::data::Shaping::Continuous,
        separation: 3.0,
        noise: 0.3,
    };
    let splits = spec.splits(128, 0, 64, 3);
    let mut rng = Rng::new(4);
    let (first_loss, _) = session.epoch(&splits.train, &mut rng).unwrap();
    for _ in 0..6 {
        session.epoch(&splits.train, &mut rng).unwrap();
    }
    let (last_loss, train_acc) = session.epoch(&splits.train, &mut rng).unwrap();
    assert!(
        last_loss < first_loss,
        "loss did not fall: {first_loss} -> {last_loss}"
    );
    assert!(train_acc > 0.3, "train acc {train_acc}");
    session.check_mask_invariant().unwrap();
    let acc = session.evaluate(&splits.test).unwrap();
    assert!(acc > 0.3, "test acc {acc}");
}

/// Sparse CSR kernels agree between the forced single-thread path and the
/// forced multi-thread path (FF/BP bitwise — rows are chunk-independent —
/// and the gradient reduction within tolerance).
#[test]
fn sparse_kernels_match_under_forced_parallelism() {
    let netc = NetConfig::new(vec![256, 128, 8]);
    let dout = DoutConfig(vec![32, 4]);
    let mut rng = Rng::new(21);
    let pattern = generate(Method::Structured, &netc, &dout, None, &mut rng);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
    let layer = &snet.junctions[0];
    let batch = 64;
    let x: Vec<f32> = (0..batch * 256).map(|_| rng.normal()).collect();
    let delta: Vec<f32> = (0..batch * 128).map(|_| rng.normal()).collect();

    let run = |threads: usize| {
        parallel::set_threads(threads);
        let mut ff = vec![0f32; batch * 128];
        layer.forward(&x, batch, &mut ff);
        let mut bp = vec![0f32; batch * 256];
        layer.backprop(&delta, batch, &mut bp);
        let mut gwc = vec![0f32; layer.wc.len()];
        let mut gb = vec![0f32; 128];
        layer.grads(&x, &delta, batch, 1e-4, &mut gwc, &mut gb);
        parallel::set_threads(0);
        (ff, bp, gwc, gb)
    };
    let (ff1, bp1, gwc1, gb1) = run(1);
    let (ff4, bp4, gwc4, gb4) = run(4);
    assert_eq!(ff1, ff4, "forward rows are chunk-independent");
    assert_eq!(bp1, bp4, "backprop rows are chunk-independent");
    for (a, b) in gwc1.iter().zip(&gwc4) {
        assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "gwc {a} vs {b}");
    }
    for (a, b) in gb1.iter().zip(&gb4) {
        assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "gb {a} vs {b}");
    }
}

/// PJRT parity (requires `--features pjrt` and built artifacts; skips
/// with a notice otherwise): the artifact forward program must match the
/// native backend on identical inputs.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_forward_matches_native_backend() {
    let pjrt = match Engine::pjrt(DIR) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("skipping PJRT parity: {err:#}");
            return;
        }
    };
    let native = Engine::native(DIR).unwrap();
    let entry = &native.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let l = layers.len() - 1;
    let mut rng = Rng::new(13);
    let mut inputs: Vec<Value> = Vec::new();
    for i in 0..l {
        let (nl, nr) = (layers[i], layers[i + 1]);
        let w: Vec<f32> = (0..nr * nl).map(|_| rng.normal() * 0.3).collect();
        inputs.push(Value::F32(w, vec![nr, nl]));
        inputs.push(Value::F32(vec![0.1; nr], vec![nr]));
    }
    for i in 0..l {
        let (nl, nr) = (layers[i], layers[i + 1]);
        let m: Vec<f32> = (0..nr * nl)
            .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
            .collect();
        inputs.push(Value::F32(m, vec![nr, nl]));
    }
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    inputs.push(Value::F32(x, vec![batch, layers[0]]));

    let want = native
        .load("tiny", "forward")
        .unwrap()
        .run(&inputs)
        .unwrap();
    let got = pjrt.load("tiny", "forward").unwrap().run(&inputs).unwrap();
    for (g, w) in got[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(want[0].as_f32().unwrap())
    {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
    }
}
