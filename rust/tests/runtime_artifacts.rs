//! Runtime integration tests through the default `Engine::new` path.
//!
//! With the default feature set these exercise the native backend (no
//! artifact files needed — the built-in configs are served). With
//! `--features pjrt` and built artifacts (`make artifacts`) the same
//! tests run against the compiled PJRT executables; they skip with a
//! notice only if that engine fails to come up.

use pds::data::Spec;
use pds::runtime::{Engine, Value};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match Engine::new(dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime tests: {err:#}");
            None
        }
    }
}

fn tiny_pattern(engine: &Engine, density_dout: &[usize], seed: u64) -> NetPattern {
    let layers = engine.manifest.configs["tiny"].layers.clone();
    let net = NetConfig::new(layers);
    let mut rng = Rng::new(seed);
    generate(
        Method::ClashFree,
        &net,
        &DoutConfig(density_dout.to_vec()),
        None,
        &mut rng,
    )
}

#[test]
fn forward_artifact_matches_native_dense() {
    let Some(engine) = engine() else { return };
    let prog = engine.load("tiny", "forward").unwrap();
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let mut rng = Rng::new(7);

    // identical weights into the artifact and the native dense net
    let mut dnet = pds::nn::dense::DenseNet::init_he(&layers, 0.1, &mut rng);
    let mut inputs: Vec<Value> = Vec::new();
    for i in 0..dnet.n_junctions() {
        let (nl, nr) = (layers[i], layers[i + 1]);
        inputs.push(Value::F32(dnet.w[i].clone(), vec![nr, nl]));
        inputs.push(Value::F32(dnet.b[i].clone(), vec![nr]));
    }
    let masks: Vec<Vec<f32>> = (0..dnet.n_junctions())
        .map(|i| {
            let (nl, nr) = (layers[i], layers[i + 1]);
            (0..nl * nr)
                .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    for (i, m) in masks.iter().enumerate() {
        let (nl, nr) = (layers[i], layers[i + 1]);
        inputs.push(Value::F32(m.clone(), vec![nr, nl]));
    }
    dnet.set_masks(masks);
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    inputs.push(Value::F32(x.clone(), vec![batch, layers[0]]));

    let out = prog.run(&inputs).unwrap();
    let got = out[0].as_f32().unwrap();
    let want = dnet.logits(&x, batch);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

#[test]
fn train_artifact_reduces_loss_and_keeps_masks() {
    let Some(engine) = engine() else { return };
    let pattern = tiny_pattern(&engine, &[8, 4], 1);
    let mut session =
        pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 5e-3, 1e-4, 2).unwrap();
    let spec = Spec {
        name: "tiny-data",
        features: 32,
        classes: 8,
        latent_dim: 8,
        shaping: pds::data::Shaping::Continuous,
        separation: 3.0,
        noise: 0.3,
    };
    let splits = spec.splits(128, 0, 64, 3);
    let mut rng = Rng::new(4);
    let (first_loss, _) = session.epoch(&splits.train, &mut rng).unwrap();
    for _ in 0..6 {
        session.epoch(&splits.train, &mut rng).unwrap();
    }
    let (last_loss, train_acc) = session.epoch(&splits.train, &mut rng).unwrap();
    assert!(
        last_loss < first_loss,
        "loss did not fall: {first_loss} -> {last_loss}"
    );
    assert!(train_acc > 0.3, "train acc {train_acc}");
    session.check_mask_invariant().unwrap();
    let acc = session.evaluate(&splits.test).unwrap();
    assert!(acc > 0.3, "test acc {acc}");
}

#[test]
fn train_artifact_matches_native_trainer_step() {
    // One fused PJRT step == one native masked-dense step (same init).
    let Some(engine) = engine() else { return };
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let pattern = tiny_pattern(&engine, &[8, 4], 5);
    let mut session =
        pds::coordinator::TrainSession::new(&engine, "tiny", &pattern, 1e-3, 1e-3, 6).unwrap();

    // mirror initial params into a native dense net
    let mut dnet = pds::nn::dense::DenseNet::init_he(&layers, 0.1, &mut Rng::new(0));
    for i in 0..dnet.n_junctions() {
        dnet.w[i] = session.param(i, false).as_f32().unwrap().to_vec();
        dnet.b[i] = session.param(i, true).as_f32().unwrap().to_vec();
    }
    dnet.set_masks(pattern.junctions.iter().map(|p| p.mask()).collect());

    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..batch)
        .map(|_| rng.below(layers[layers.len() - 1]) as i32)
        .collect();

    let out = session.step(&x, &y).unwrap();
    let native = dnet.step(&x, &y, batch, 1e-3, None);
    assert_eq!(out.correct, native.correct);
    assert!(
        (out.loss - native.loss).abs() < 1e-4 * (1.0 + native.loss.abs()),
        "loss {} vs {}",
        out.loss,
        native.loss
    );
    // apply the same Adam step natively and compare updated weights
    let mut opt = pds::nn::adam::Adam::new(
        pds::nn::adam::AdamConfig {
            lr: 1e-3,
            ..Default::default()
        },
        &dnet
            .w
            .iter()
            .zip(&dnet.b)
            .map(|(w, b)| (w.len(), b.len()))
            .collect::<Vec<_>>(),
    );
    opt.step(&mut dnet.w, &mut dnet.b, &native.grads.gw, &native.grads.gb);
    for i in 0..dnet.n_junctions() {
        let got = session.param(i, false).as_f32().unwrap();
        for (idx, (g, w)) in got.iter().zip(&dnet.w[i]).enumerate() {
            assert!(
                (g - w).abs() < 5e-4 * (1.0 + w.abs()),
                "junction {i} w[{idx}]: {g} vs {w}"
            );
        }
    }
}

#[test]
fn gather_forward_matches_masked_forward() {
    // compacted structured-sparse inference == masked dense inference
    let Some(engine) = engine() else { return };
    let entry = &engine.manifest.configs["tiny"];
    let (layers, batch) = (entry.layers.clone(), entry.batch);
    let dout: Vec<usize> = entry.gather_dout.clone().unwrap();
    let net = NetConfig::new(layers.clone());
    let mut rng = Rng::new(9);
    let pattern = generate(Method::ClashFree, &net, &DoutConfig(dout), None, &mut rng);

    let forward = engine.load("tiny", "forward").unwrap();
    let gather = engine.load("tiny", "gather_forward").unwrap();
    let mut dense_inputs: Vec<Value> = Vec::new();
    let mut wcs: Vec<Value> = Vec::new();
    let mut idxs: Vec<Value> = Vec::new();
    let mut biases: Vec<Value> = Vec::new();
    for (i, p) in pattern.junctions.iter().enumerate() {
        let (nl, nr) = (layers[i], layers[i + 1]);
        let w: Vec<f32> = (0..nr * nl).map(|_| rng.normal()).collect();
        let mask = p.mask();
        let masked: Vec<f32> = w.iter().zip(&mask).map(|(w, m)| w * m).collect();
        let b: Vec<f32> = (0..nr).map(|_| rng.normal()).collect();
        let (idx, din) = p.compact_indices().unwrap();
        wcs.push(Value::F32(p.compact_weights(&masked), vec![nr, din]));
        idxs.push(Value::I32(idx, vec![nr, din]));
        biases.push(Value::F32(b.clone(), vec![nr]));
        dense_inputs.push(Value::F32(masked, vec![nr, nl]));
        dense_inputs.push(Value::F32(b, vec![nr]));
    }
    for p in &pattern.junctions {
        dense_inputs.push(Value::F32(
            p.mask(),
            vec![p.shape.n_right, p.shape.n_left],
        ));
    }
    let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
    dense_inputs.push(Value::F32(x.clone(), vec![batch, layers[0]]));
    let want = forward.run(&dense_inputs).unwrap();

    let mut gather_inputs = wcs;
    gather_inputs.extend(idxs);
    gather_inputs.extend(biases);
    gather_inputs.push(Value::F32(x, vec![batch, layers[0]]));
    let got = gather.run(&gather_inputs).unwrap();

    for (g, w) in got[0]
        .as_f32()
        .unwrap()
        .iter()
        .zip(want[0].as_f32().unwrap())
    {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

#[test]
fn inference_server_serves_batched_requests() {
    let Some(engine) = engine() else { return };
    let pattern = tiny_pattern(&engine, &[8, 4], 11);
    drop(engine);
    let server = pds::coordinator::InferenceServer::start(
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
        "tiny",
        &pattern,
        None,
        pds::coordinator::ServerConfig {
            max_wait: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let n_clients = 4;
    let per_client = 25;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            let mut classes = Vec::new();
            for _ in 0..per_client {
                let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
                let pred = client.classify(x).unwrap();
                assert!(pred.class < 8);
                assert!(pred.batch_occupancy >= 1);
                classes.push(pred.class);
            }
            classes
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let reqs = server
        .metrics()
        .requests
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(reqs, (n_clients * per_client) as u64);
    let batches = server
        .metrics()
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches as usize <= n_clients * per_client);
    server.shutdown().unwrap();
}
