//! Seeded mutation harness for `pds analyze`: proves the analyzer is
//! *non-vacuous*. The positive half pins every builtin config to a
//! clean report (including `mnist_fc4` at full pipeline depth); the
//! negative half injects known-bad structure — clashing schedules,
//! inadmissible out-degrees, overflowing quant formats, malformed
//! manifests — and asserts each one is rejected with the expected typed
//! finding. CI runs this next to the `pds analyze` invocation itself,
//! so a regression that silently turns a pass into a no-op fails the
//! build even though the clean run still looks clean.

use pds::analysis::{analyze_config, analyze_manifest, AnalyzeOptions, Severity};
use pds::nn::fixed::QFormat;
use pds::runtime::Manifest;
use pds::sparsity::clash_free::{schedule_spec, AddrGen, Flavor};
use pds::util::rng::Rng;

fn assert_code(findings: &[pds::analysis::Finding], code: &str, severity: Severity) {
    assert!(
        findings
            .iter()
            .any(|f| f.code == code && f.severity == severity),
        "expected a {severity:?} '{code}' finding, got:\n{}",
        findings
            .iter()
            .map(|f| format!("  {f}\n"))
            .collect::<String>()
    );
}

#[test]
fn every_builtin_config_analyzes_clean() {
    let report = analyze_manifest(&Manifest::builtin(), &AnalyzeOptions::default());
    assert!(!report.has_errors(), "builtin must be clean:\n{report}");
    for name in ["tiny", "mnist_fc2", "mnist_fc4", "timit"] {
        assert!(
            report
                .findings
                .iter()
                .any(|f| f.config == name && f.code == "proved"),
            "{name}: missing clash proof"
        );
        assert!(
            report
                .findings
                .iter()
                .any(|f| f.config == name && f.code == "certified-range"),
            "{name}: missing certified range"
        );
    }
}

#[test]
fn mnist_fc4_proves_clean_at_full_pipeline_depth() {
    let manifest = Manifest::builtin();
    let entry = &manifest.configs["mnist_fc4"];
    let opts = AnalyzeOptions {
        depth: Some(18),
        ..AnalyzeOptions::default()
    };
    let report = analyze_config("mnist_fc4", entry, &opts);
    assert!(!report.has_errors(), "{report}");
    assert_code(&report.findings, "proved", Severity::Info);
}

#[test]
fn injected_schedule_clash_is_rejected_with_counterexample() {
    // a valid Type-1 draw, then one corrupted address-generator word:
    // two lanes mapped to the same left-bank memory
    let mut rng = Rng::new(0x1812);
    let mut spec = schedule_spec(32, 4, 2, Flavor::Type1 { dither: false }, &mut rng);
    spec.sweeps[0].sigma[0] = spec.sweeps[0].sigma[1];
    let err = spec.prove_clash_free().expect_err("clash must be caught");
    assert!(err.cycle().is_some() || err.memory().is_some(), "{err}");
    // the brute-force replay agrees
    assert!(spec.materialize().verify_clash_free().is_err());
    // and an Explicit column that repeats an address is equally fatal
    let mut spec = schedule_spec(32, 4, 2, Flavor::Type3 { dither: true }, &mut rng);
    if let AddrGen::Explicit { cols } = &mut spec.sweeps[0].addr {
        cols[0][0] = cols[0][1];
    } else {
        panic!("Type3 must draw explicit columns");
    }
    assert!(spec.prove_clash_free().is_err());
    assert!(spec.materialize().verify_clash_free().is_err());
}

#[test]
fn inadmissible_out_degrees_are_rejected() {
    let manifest = Manifest::builtin();
    let mut entry = manifest.configs["timit"].clone();
    // timit junction 0 is 39 -> 390: admissible d_out are multiples of
    // 390/gcd(39,390) = 10, so d_in = 39*5/390 is fractional and no
    // clash-free junction exists
    entry.gather_dout = Some(vec![5, 9]);
    let report = analyze_config("timit", &entry, &AnalyzeOptions::default());
    assert!(report.has_errors());
    assert_code(&report.findings, "bad-dout", Severity::Error);
}

#[test]
fn overflowing_quant_format_is_rejected_with_junction_and_fix() {
    // Q1.10 has 2 units of integer headroom; the mnist_fc2 first
    // junction accumulates 160 He-initialized edges, whose interval
    // bound exceeds that by an order of magnitude at |x| <= 1
    let manifest = Manifest::builtin();
    let entry = &manifest.configs["mnist_fc2"];
    let opts = AnalyzeOptions {
        quant: Some(QFormat::new(1, 10)),
        input_range: Some(1.0),
        ..AnalyzeOptions::default()
    };
    let report = analyze_config("mnist_fc2", entry, &opts);
    assert!(report.has_errors(), "{report}");
    let sat = report
        .findings
        .iter()
        .find(|f| f.code == "saturation")
        .expect("must flag saturation");
    assert_eq!(sat.severity, Severity::Error);
    assert!(sat.junction.is_some(), "must name the breaking junction");
    // the minimal fixing format is suggested alongside
    assert_code(&report.findings, "suggest-format", Severity::Warning);
}

#[test]
fn default_format_passes_where_the_narrow_one_fails() {
    // same config, same asserted proof obligation, adequate format:
    // differential evidence that the rejection above is the format's
    // fault, not the harness's
    let manifest = Manifest::builtin();
    let entry = &manifest.configs["mnist_fc2"];
    let opts = AnalyzeOptions::default();
    let report = analyze_config("mnist_fc2", entry, &opts);
    assert!(!report.has_errors(), "{report}");
}

#[test]
fn multi_context_analysis_proves_clean_and_dilates_staleness() {
    // the C-tenant interleave of every builtin config must prove clean,
    // with the per-context staleness law emitted as a success finding
    let manifest = Manifest::builtin();
    let entry = &manifest.configs["mnist_fc4"];
    let opts = AnalyzeOptions {
        contexts: 4,
        ..AnalyzeOptions::default()
    };
    let report = analyze_config("mnist_fc4", entry, &opts);
    assert!(!report.has_errors(), "{report}");
    assert_code(&report.findings, "proved", Severity::Info);
    assert_code(&report.findings, "proved-contexts", Severity::Info);
    // single-context analysis must NOT grow the extra finding — the
    // default report surface is pinned by CI
    let base = analyze_config("mnist_fc4", entry, &AnalyzeOptions::default());
    assert!(
        !base.findings.iter().any(|f| f.code == "proved-contexts"),
        "contexts=1 must keep the single-tenant report shape"
    );
}

#[test]
fn mutated_context_routing_is_rejected_with_the_offending_context() {
    use pds::analysis::clash::prove_contexts_with;
    use pds::hw::pipeline::Pipeline;

    let l = 3usize;
    let contexts = 4usize;
    let taus = 60i64;
    let pipe = Pipeline::new(l);

    // clean round-robin fetch: no finding
    assert!(
        prove_contexts_with("m", l, taus, contexts, |n| Some(
            pipe.context_of(n, contexts)
        ))
        .is_none(),
        "clean fetch must prove"
    );

    // mutation: context 2's fetches alias onto bank 0
    let f = prove_contexts_with("m", l, taus, contexts, |n| {
        let c = pipe.context_of(n, contexts);
        Some(if c == 2 { 0 } else { c })
    })
    .expect("aliased fetch must be caught");
    assert_eq!(f.code, "context-alias");
    assert_eq!(f.severity, Severity::Error);
    assert_eq!(f.context, Some(2), "finding must name the offending context");

    // mutation: context 1's fetches are dropped entirely
    let f = prove_contexts_with("m", l, taus, contexts, |n| {
        let c = pipe.context_of(n, contexts);
        (c != 1).then_some(c)
    })
    .expect("skipped fetch must be caught");
    assert_eq!(f.code, "context-skip");
    assert_eq!(f.severity, Severity::Error);
    assert_eq!(f.context, Some(1), "finding must name the starved context");

    // mutation: a fetch routed past the bank count
    let f = prove_contexts_with("m", l, taus, contexts, |_| Some(contexts))
        .expect("out-of-range fetch must be caught");
    assert_eq!(f.code, "context-out-of-range");
    assert_eq!(f.context, Some(contexts));
}

#[test]
fn malformed_manifest_documents_are_rejected() {
    // not JSON at all
    assert!(Manifest::parse("{nope").is_err());
    // JSON but structurally not a manifest
    assert!(Manifest::parse(r#"{"configs": {"t": {"batch": 4}}}"#).is_err());
    // parseable but degenerate: the lint gate must refuse it
    let text = r#"{"configs": {"bad": {"layers": [8], "batch": 0, "programs": {}}}}"#;
    let m = Manifest::parse(text).expect("parses");
    let report = pds::analysis::quick_lint(&m);
    assert!(report.has_errors());
    assert_code(&report.findings, "bad-layers", Severity::Error);
    assert_code(&report.findings, "bad-batch", Severity::Error);
    // entries the parser silently drops are document-level errors
    let dropped = pds::analysis::lint::lint_text(
        r#"{"configs": {"t": {"layers": [32, 16], "batch": 4,
            "gather_dout": [4, -1], "programs": {}}}}"#,
    );
    assert!(dropped
        .iter()
        .any(|f| f.code == "bad-dout-entry" && f.severity == Severity::Error));
}

#[test]
fn injected_overlapping_packed_index_is_rejected_naming_the_layer() {
    use pds::nn::actsparse::{ActError, ActivationMask};

    // n = 8, z = 4: wave 0 packs actives of neurons 0..4, wave 1 of
    // 4..8, and bank(i) = i % 4 — a clean top-k mask packs without
    // overlap by construction
    let acts = [0.9f32, 0.1, 0.8, 0.2, 0.7, 0.3, 0.6, 0.4];
    let mask = ActivationMask::top_k(&acts, 8, 1, 4, 5);
    let mut rows = mask.pack(2, 4).expect("z | n packs");
    rows[0].verify(2, 8).expect("clean packing verifies");

    // mutation: smuggle neuron 4 into wave 0, colliding with neuron 0
    // on bank 0 — the exact corruption a broken packer would emit
    let smuggled = rows[0].waves[1][0];
    assert_eq!(smuggled, 4, "fixture: neuron 4 is wave 1's first active");
    rows[0].waves[0].push(smuggled);
    rows[0].waves[1].remove(0);
    match rows[0].verify(2, 8) {
        Err(ActError::Overlap { layer: 2, wave: 0, bank: 0 }) => {}
        other => panic!("expected Overlap naming layer 2 / wave 0 / bank 0, got {other:?}"),
    }

    // mutation: the same index in two waves is a Duplicate
    let mut rows = mask.pack(2, 4).expect("z | n packs");
    let dup = rows[0].waves[0][0];
    rows[0].waves[1].push(dup);
    match rows[0].verify(2, 8) {
        Err(ActError::Duplicate { layer: 2, index }) => assert_eq!(index, dup),
        other => panic!("expected Duplicate naming layer 2, got {other:?}"),
    }

    // mutation: an index past the layer width is OutOfRange
    let mut rows = mask.pack(2, 4).expect("z | n packs");
    rows[0].waves[0][0] = 8;
    match rows[0].verify(2, 8) {
        Err(ActError::OutOfRange { layer: 2, index: 8, n: 8 }) => {}
        other => panic!("expected OutOfRange naming layer 2, got {other:?}"),
    }

    // and a z that does not divide the width is refused up front
    match mask.pack(2, 3) {
        Err(ActError::NotDividing { layer: 2, z: 3, n: 8 }) => {}
        other => panic!("expected NotDividing naming layer 2, got {other:?}"),
    }
}

fn masked_net_fixture() -> (pds::nn::sparse::SparseNet, Vec<f32>, usize) {
    use pds::sparsity::config::{DoutConfig, NetConfig};
    use pds::sparsity::{generate, Method};

    let mut rng = Rng::new(0xAC7);
    let pattern = generate(
        Method::ClashFree,
        &NetConfig::new(vec![8, 8, 4]),
        &DoutConfig(vec![4, 2]),
        None,
        &mut rng,
    );
    let net = pds::nn::sparse::SparseNet::init_he(&pattern, 0.1, &mut rng);
    let batch = 2usize;
    let x: Vec<f32> = (0..batch * 8).map(|_| rng.uniform() * 2.0 - 1.0).collect();
    (net, x, batch)
}

#[test]
fn mask_dropping_a_pattern_required_neuron_is_rejected() {
    use pds::nn::actsparse::{ActError, ActivationMask};

    let (net, x, batch) = masked_net_fixture();
    // drop every in-edge of right neuron 0 of junction 1 (the mask
    // covers the hidden layer, i.e. junction 1's left side) — the
    // pattern requires that neuron, so the net would silently compute
    // its bias alone
    let hidden = net.junctions[1].n_left;
    let mut mask = ActivationMask::all_ones(hidden, batch, 9);
    let (lo, hi) = (
        net.junctions[1].offsets[0] as usize,
        net.junctions[1].offsets[1] as usize,
    );
    for r in 0..batch {
        for &k in &net.junctions[1].idx[lo..hi] {
            mask.active[r * hidden + k as usize] = false;
        }
    }
    match net.logits_masked(&x, batch, &[mask], 9) {
        Err(ActError::Uncovered { layer: 1, neuron: 0 }) => {}
        other => panic!("expected Uncovered naming layer 1 / neuron 0, got {other:?}"),
    }
}

#[test]
fn stale_mask_reused_across_batches_is_rejected() {
    use pds::nn::actsparse::{ActError, ActivationMask};

    let (net, x, batch) = masked_net_fixture();
    let hidden = net.junctions[1].n_left;
    // mask built for batch stamp 1, reused while executing stamp 2 —
    // silent reuse would freeze the selection on old activations
    let mask = ActivationMask::all_ones(hidden, batch, 1);
    match net.logits_masked(&x, batch, &[mask], 2) {
        Err(ActError::Stale { layer: 1, have: 1, want: 2 }) => {}
        other => panic!("expected Stale naming layer 1, got {other:?}"),
    }
    // the same mask at its own stamp passes: differential evidence the
    // rejection is the staleness, not the harness
    let mask = ActivationMask::all_ones(hidden, batch, 1);
    net.logits_masked(&x, batch, &[mask], 1)
        .expect("fresh all-ones mask must pass");
}

#[test]
fn degenerate_act_specs_are_rejected_by_the_analyzer() {
    use pds::nn::actsparse::ActSpec;

    // topk k=0 zeroes every hidden activation: a config-level error
    let manifest = Manifest::builtin();
    let entry = manifest.configs["tiny"].clone().with_act(ActSpec::top_k(0));
    let report = analyze_config("tiny", &entry, &AnalyzeOptions::default());
    assert!(report.has_errors(), "{report}");
    assert_code(&report.findings, "bad-act", Severity::Error);

    // a sane spec adds only the info finding — and the no-ActSpec
    // builtin report (pinned clean above) must not grow act findings
    let entry = manifest.configs["tiny"].clone().with_act(ActSpec::top_k(4));
    let report = analyze_config("tiny", &entry, &AnalyzeOptions::default());
    assert!(!report.has_errors(), "{report}");
    assert_code(&report.findings, "act-spec", Severity::Info);
}

#[test]
fn load_gate_refuses_a_lint_broken_manifest_file() {
    let dir = std::env::temp_dir().join(format!("pds_analyzer_mut_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"configs": {"bad": {"layers": [8], "batch": 0, "programs": {}}}}"#,
    )
    .unwrap();
    let err = Manifest::load_or_builtin(&dir).expect_err("gate must refuse");
    let msg = format!("{err:#}");
    assert!(msg.contains("static lint"), "unexpected error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}
