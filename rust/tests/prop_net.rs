//! Property tests for the network wire codec (`net::wire`):
//! encode/decode round-trips for every frame type, and the strict
//! decoder never panics — it returns a typed error — on truncated,
//! bit-flipped, or oversized input.
//!
//! Seeds come from `PDS_PROP_SEED` when set (CI pins it for
//! reproducibility); failures print the per-case seed via
//! `util::prop::for_all`.

use pds::net::wire::{Frame, MetricsSnapshot, ModelInfo, WireError, HEADER_LEN, MAX_PAYLOAD, VERSION};
use pds::net::ErrorCode;
use pds::obs::TraceEcho;
use pds::util::prop::for_all;
use pds::util::rng::Rng;

/// Root seed: `PDS_PROP_SEED` when set (CI pins it), a fixed default
/// otherwise — property runs are always reproducible from the log.
fn prop_seed() -> u64 {
    std::env::var("PDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1812_07E7)
}

/// Random ASCII identifier (wire strings are UTF-8; ASCII keeps the
/// generated cases readable in failure logs).
fn arb_string(r: &mut Rng, max_len: usize) -> String {
    const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz_0123456789";
    let len = r.below(max_len + 1);
    (0..len).map(|_| ALPHA[r.below(ALPHA.len())] as char).collect()
}

/// Finite f32s only: the codec round-trips raw bits exactly (NaN
/// included), but `Frame`'s derived `PartialEq` can't witness NaN == NaN,
/// so equality-based properties stick to finite values.
fn arb_features(r: &mut Rng, max_len: usize) -> Vec<f32> {
    let len = r.below(max_len + 1);
    (0..len).map(|_| r.normal() * 100.0).collect()
}

fn arb_code(r: &mut Rng) -> ErrorCode {
    match r.below(5) {
        0 => ErrorCode::Busy,
        1 => ErrorCode::Stopped,
        2 => ErrorCode::BadRequest,
        3 => ErrorCode::UnknownModel,
        _ => ErrorCode::Internal,
    }
}

/// Optional trace ID for a v4 `Request` (absent half the time, like
/// real traffic with sampling on).
fn arb_req_trace(r: &mut Rng) -> Option<u64> {
    (r.below(2) == 1).then(|| r.next_u64())
}

/// Optional per-stage timing echo for a v4 `Response`.
fn arb_echo(r: &mut Rng) -> Option<TraceEcho> {
    (r.below(2) == 1).then(|| TraceEcho {
        trace_id: r.next_u64(),
        queue_us: r.next_u64() as u32,
        batch_us: r.next_u64() as u32,
        execute_us: r.next_u64() as u32,
    })
}

/// One random frame, covering every variant.
fn arb_frame(r: &mut Rng) -> Frame {
    match r.below(8) {
        0 => Frame::Request {
            id: r.next_u64(),
            model: arb_string(r, 16),
            context: r.below(16) as u32,
            features: arb_features(r, 64),
            trace: arb_req_trace(r),
        },
        1 => Frame::Response {
            id: r.next_u64(),
            class: r.below(1 << 16) as u32,
            latency_us: r.next_u64() >> 20,
            batch_occupancy: r.below(512) as u32,
            worker: r.below(64) as u32,
            trace: arb_echo(r),
        },
        2 => Frame::Error {
            id: r.next_u64(),
            code: arb_code(r),
            message: arb_string(r, 48),
        },
        3 => Frame::HealthRequest,
        4 => Frame::HealthReply {
            draining: r.below(2) == 1,
            active_connections: r.below(256) as u32,
            models: (0..r.below(4))
                .map(|_| ModelInfo {
                    name: arb_string(r, 12),
                    features: r.below(4096) as u32,
                    classes: r.below(64) as u32,
                    batch: (1 + r.below(512)) as u32,
                    contexts: (1 + r.below(16)) as u32,
                })
                .collect(),
        },
        5 => Frame::MetricsRequest {
            model: arb_string(r, 16),
        },
        6 => Frame::MetricsReply(MetricsSnapshot {
            model: arb_string(r, 16),
            contexts: 1 + (r.below(16) as u64),
            requests: r.next_u64() >> 16,
            rejected: r.next_u64() >> 16,
            batches: r.next_u64() >> 16,
            padded_rows: r.next_u64() >> 16,
            stolen: r.next_u64() >> 16,
            quant_saturations: r.next_u64() >> 16,
            p50_us: r.next_u64() >> 32,
            p95_us: r.next_u64() >> 32,
            p99_us: r.next_u64() >> 32,
            mean_occupancy: r.uniform64() * 256.0,
            net_flushes: r.next_u64() >> 16,
            net_coalesced: r.next_u64() >> 16,
            net_accept_errors: r.next_u64() >> 16,
            net_shed_connections: r.next_u64() >> 16,
        }),
        _ => Frame::Shutdown,
    }
}

#[test]
fn encode_decode_roundtrip_every_frame_type() {
    for_all(
        "decode(encode(frame)) == frame, consuming every byte",
        prop_seed(),
        512,
        arb_frame,
        |frame| {
            let bytes = frame.encode();
            match Frame::decode(&bytes) {
                Ok((back, used)) => {
                    if &back != frame {
                        return Err(format!("decoded {back:?} != original"));
                    }
                    if used != bytes.len() {
                        return Err(format!("consumed {used} of {} bytes", bytes.len()));
                    }
                    Ok(())
                }
                Err(e) => Err(format!("decode failed: {e}")),
            }
        },
    );
}

#[test]
fn decoder_rejects_every_truncation_without_panic() {
    for_all(
        "every strict prefix of a valid frame decodes to Truncated",
        prop_seed() ^ 1,
        128,
        arb_frame,
        |frame| {
            let bytes = frame.encode();
            for cut in 0..bytes.len() {
                match Frame::decode(&bytes[..cut]) {
                    Err(WireError::Truncated) => {}
                    Ok(_) => {
                        return Err(format!(
                            "prefix of {cut}/{} bytes decoded successfully",
                            bytes.len()
                        ))
                    }
                    // a truncation that cuts inside the header cannot
                    // misreport as anything else; the only legal error
                    // is Truncated
                    Err(e) => {
                        return Err(format!(
                            "prefix of {cut}/{} bytes: expected Truncated, got {e}",
                            bytes.len()
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn decoder_never_panics_on_bit_flips() {
    for_all(
        "decode never panics on a bit-flipped frame",
        prop_seed() ^ 2,
        256,
        |r| {
            let frame = arb_frame(r);
            let mut bytes = frame.encode();
            // up to 4 independent single-bit flips anywhere in the frame
            for _ in 0..(1 + r.below(4)) {
                let byte = r.below(bytes.len());
                let bit = r.below(8);
                bytes[byte] ^= 1 << bit;
            }
            bytes
        },
        |bytes| {
            // any outcome is fine except a panic or an over-read; a flip
            // confined to payload values can still decode to a
            // different valid frame
            match Frame::decode(bytes) {
                Ok((_, used)) if used > bytes.len() => {
                    Err(format!("consumed {used} > {} bytes", bytes.len()))
                }
                _ => Ok(()),
            }
        },
    );
}

#[test]
fn decoder_rejects_oversized_headers_without_allocating() {
    for_all(
        "declared payload beyond MAX_PAYLOAD is rejected from the header alone",
        prop_seed() ^ 3,
        128,
        |r| {
            // hand-build a header announcing an oversized payload; the
            // buffer deliberately contains no payload at all, so any
            // attempt to read past the header would error differently
            let declared = MAX_PAYLOAD + 1 + r.below(1 << 20);
            let mut h = Vec::with_capacity(HEADER_LEN);
            h.extend_from_slice(b"PD");
            h.push(VERSION); // current version
            h.push((1 + r.below(8)) as u8);
            h.extend_from_slice(&(declared as u32).to_le_bytes());
            (h, declared)
        },
        |(h, declared)| match Frame::decode(h) {
            Err(WireError::Oversized(n)) if n == *declared => Ok(()),
            other => Err(format!("expected Oversized({declared}), got {other:?}")),
        },
    );
}

#[test]
fn decoder_rejects_unknown_versions_and_types() {
    for_all(
        "unknown version or frame type is rejected by name",
        prop_seed() ^ 4,
        128,
        |r| {
            let bytes = arb_frame(r).encode();
            let bad_version = r.below(2) == 0;
            // VERSION+1 .. can never collide with the current version
            (bytes, bad_version, VERSION + 1 + r.below(250) as u8)
        },
        |(bytes, bad_version, bad)| {
            let mut b = bytes.clone();
            if *bad_version {
                b[2] = *bad;
                match Frame::decode(&b) {
                    Err(WireError::UnknownVersion(v)) if v == *bad => Ok(()),
                    other => Err(format!("expected UnknownVersion, got {other:?}")),
                }
            } else {
                // type tags 9..=255 are unassigned in the current protocol
                let tag = (*bad).max(9);
                b[3] = tag;
                match Frame::decode(&b) {
                    Err(WireError::UnknownType(t)) if t == tag => Ok(()),
                    other => Err(format!("expected UnknownType({tag}), got {other:?}")),
                }
            }
        },
    );
}

/// The v3 protocol (no trace fields) must be rejected by version, never
/// mis-decoded: a v4 `Request`/`Response` body under a v3 header could
/// silently misparse the trailing trace bytes if the decoder guessed.
#[test]
fn v3_stamped_frames_are_rejected_by_version_not_misdecoded() {
    for_all(
        "any frame re-stamped with version 3 decodes to UnknownVersion(3)",
        prop_seed() ^ 6,
        256,
        arb_frame,
        |frame| {
            let mut bytes = frame.encode();
            bytes[2] = 3; // the pre-trace protocol version
            match Frame::decode(&bytes) {
                Err(WireError::UnknownVersion(3)) => Ok(()),
                other => Err(format!("expected UnknownVersion(3), got {other:?}")),
            }
        },
    );
}

/// The v4 trace fields specifically: a traced `Request` and its traced
/// `Response` round-trip bit for bit, including every `TraceEcho`
/// duration at the u32 extremes.
#[test]
fn v4_trace_fields_roundtrip_exactly() {
    for_all(
        "traced Request/Response pairs round-trip, consuming every byte",
        prop_seed() ^ 7,
        256,
        |r| {
            let edge = |r: &mut Rng| match r.below(4) {
                0 => 0u32,
                1 => u32::MAX,
                _ => r.next_u64() as u32,
            };
            let req = Frame::Request {
                id: r.next_u64(),
                model: arb_string(r, 16),
                context: r.below(16) as u32,
                features: arb_features(r, 32),
                trace: Some(r.next_u64()),
            };
            let resp = Frame::Response {
                id: r.next_u64(),
                class: r.below(64) as u32,
                latency_us: r.next_u64() >> 20,
                batch_occupancy: r.below(512) as u32,
                worker: r.below(64) as u32,
                trace: Some(TraceEcho {
                    trace_id: r.next_u64(),
                    queue_us: edge(r),
                    batch_us: edge(r),
                    execute_us: edge(r),
                }),
            };
            vec![req, resp]
        },
        |frames| {
            for f in frames {
                let bytes = f.encode();
                match Frame::decode(&bytes) {
                    Ok((back, used)) if &back == f && used == bytes.len() => {}
                    Ok((back, _)) => return Err(format!("decoded {back:?} != original")),
                    Err(e) => return Err(format!("decode failed: {e}")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn back_to_back_frames_decode_in_sequence() {
    for_all(
        "a concatenated stream of frames decodes frame by frame",
        prop_seed() ^ 5,
        64,
        |r| (0..1 + r.below(8)).map(|_| arb_frame(r)).collect::<Vec<_>>(),
        |frames| {
            let mut stream = Vec::new();
            for f in frames {
                stream.extend_from_slice(&f.encode());
            }
            let mut pos = 0usize;
            for (i, f) in frames.iter().enumerate() {
                match Frame::decode(&stream[pos..]) {
                    Ok((back, used)) => {
                        if &back != f {
                            return Err(format!("frame {i} decoded differently"));
                        }
                        pos += used;
                    }
                    Err(e) => return Err(format!("frame {i}: {e}")),
                }
            }
            if pos != stream.len() {
                return Err(format!("{} trailing bytes", stream.len() - pos));
            }
            Ok(())
        },
    );
}
