//! Tenant-isolation property battery for the context-switched
//! multi-tenant pipeline (`nn::pipeline::MultiPipelinedTrainer` over
//! `hw::context::ContextBank`):
//!
//! - **Isolation (f32).** Training `C` contexts interleaved through one
//!   junction schedule is *bit-identical*, per context, to `C`
//!   independent single-tenant runs at the same effective stride —
//!   across randomized context counts, admission orders, and pipeline
//!   depths.
//! - **Isolation (Qm.n).** The quantized image of each tenant's trained
//!   network (weights, biases, quantized logits) is likewise identical
//!   between the interleaved and solo runs.
//! - **Degenerate case.** One context at depth 1 *is* the sequential
//!   trainer, bit for bit.
//! - **Non-vacuity.** Injected context-bank defects (aliasing two
//!   tenants onto one bank, skipping a tenant's fetches) are caught by
//!   the per-context audit with a typed error naming the offending
//!   context — and visibly break the isolation property, proving the
//!   parity assertions above can actually fail.
//!
//! Seeds come from `PDS_PROP_SEED` when set (CI pins it); failures
//! print the per-case seed via `util::prop::for_all`.

use pds::data::Spec;
use pds::hw::context::{ContextError, ContextFault};
use pds::nn::fixed::{FixedSparseNet, QFormat};
use pds::nn::pipeline::{
    context_seed, MultiPipelinedTrainer, PipelineConfig, PipelinedTrainer,
};
use pds::nn::sparse::SparseNet;
use pds::nn::trainer::{self, Network, TrainConfig};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

/// Root seed: `PDS_PROP_SEED` when set (CI pins it), a fixed default
/// otherwise — property runs are always reproducible from the log.
fn prop_seed() -> u64 {
    std::env::var("PDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1812_C0DE)
}

fn pattern_for(layers: &[usize], dout: &[usize], seed: u64) -> NetPattern {
    let netc = NetConfig::new(layers.to_vec());
    let mut rng = Rng::new(seed);
    generate(
        Method::Structured,
        &netc,
        &DoutConfig(dout.to_vec()),
        None,
        &mut rng,
    )
}

fn toy_splits(
    features: usize,
    classes: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (pds::data::Dataset, pds::data::Dataset) {
    let spec = Spec {
        name: "ctx-test",
        features,
        classes,
        latent_dim: (features / 3).max(4),
        shaping: pds::data::Shaping::Continuous,
        separation: 3.0,
        noise: 0.4,
    };
    let s = spec.splits(n_train, 0, n_test, seed);
    (s.train, s.test)
}

/// One randomized multi-tenant scenario: how many tenants share the
/// schedule, in which admission order, at which pipeline depth.
#[derive(Debug)]
struct Scenario {
    contexts: usize,
    admission: Vec<usize>,
    depth: usize,
    seed: u64,
}

fn arb_scenario(r: &mut Rng) -> Scenario {
    let contexts = 2 + r.below(3); // 2..=4 tenants
    let mut admission: Vec<usize> = (0..contexts).collect();
    r.shuffle(&mut admission);
    let depth = r.below(3); // 0 = full schedule, 1, 2
    Scenario {
        contexts,
        admission,
        depth,
        seed: r.next_u64() >> 1,
    }
}

const LAYERS: [usize; 3] = [12, 10, 6];

fn cfg_for(sc: &Scenario) -> PipelineConfig {
    PipelineConfig {
        epochs: 2,
        batch: 16,
        depth: sc.depth,
        l2: 1e-4,
        seed: sc.seed,
        ..Default::default()
    }
}

/// Build the interleaved multi-tenant trainer for a scenario.
fn multi_for(sc: &Scenario, pattern: &NetPattern) -> Result<MultiPipelinedTrainer, String> {
    MultiPipelinedTrainer::from_pattern(&LAYERS, pattern, &cfg_for(sc), sc.contexts)
        .map_err(|e| format!("multi build: {e}"))?
        .with_admission(sc.admission.clone())
        .map_err(|e| format!("admission: {e}"))
}

/// Build and train tenant `c`'s solo twin: the same per-context seed at
/// the same effective stride, alone on the schedule.
fn solo_twin(
    sc: &Scenario,
    pattern: &NetPattern,
    stride: usize,
    c: usize,
    train_ds: &pds::data::Dataset,
    test_ds: &pds::data::Dataset,
) -> Result<(PipelinedTrainer, pds::nn::trainer::History), String> {
    let mut tcfg = cfg_for(sc);
    tcfg.seed = context_seed(tcfg.seed, c);
    let mut solo = PipelinedTrainer::from_pattern_with_stride(&LAYERS, pattern, &tcfg, stride)
        .map_err(|e| format!("solo build ctx {c}: {e}"))?;
    let hist = solo
        .train(train_ds, test_ds)
        .map_err(|e| format!("solo train ctx {c}: {e}"))?;
    Ok((solo, hist))
}

/// Bit-compare two nets junction by junction.
fn nets_bit_identical(a: &SparseNet, b: &SparseNet) -> Result<(), String> {
    for (j, (aj, bj)) in a.junctions.iter().zip(&b.junctions).enumerate() {
        for (e, (x, y)) in aj.wc.iter().zip(&bj.wc).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("junction {j} weight {e}: {x} vs {y}"));
            }
        }
        for (n, (x, y)) in aj.bias.iter().zip(&bj.bias).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("junction {j} bias {n}: {x} vs {y}"));
            }
        }
    }
    Ok(())
}

#[test]
fn interleaved_training_is_bit_identical_to_solo_runs() {
    let pattern = pattern_for(&LAYERS, &[5, 3], 3);
    let (train_ds, test_ds) = toy_splits(12, 6, 96, 36, 7);
    for_all(
        "C interleaved tenants == C solo runs, bit for bit, any admission order",
        prop_seed(),
        6,
        arb_scenario,
        |sc| {
            let mut multi = multi_for(sc, &pattern)?;
            let hists = multi
                .train(&train_ds, &test_ds)
                .map_err(|e| format!("multi train: {e}"))?;
            multi
                .audit_contexts()
                .map_err(|e| format!("context audit: {e}"))?;
            multi
                .audit_banked()
                .map_err(|e| format!("banked audit: {e}"))?;
            for c in 0..sc.contexts {
                let (solo, solo_hist) =
                    solo_twin(sc, &pattern, multi.stride(), c, &train_ds, &test_ds)?;
                // epoch histories agree to the bit
                if solo_hist.epochs.len() != hists[c].epochs.len() {
                    return Err(format!("ctx {c}: epoch count diverged"));
                }
                for (a, b) in solo_hist.epochs.iter().zip(&hists[c].epochs) {
                    if a.train_loss.to_bits() != b.train_loss.to_bits() {
                        return Err(format!(
                            "ctx {c} epoch {}: loss {} vs {}",
                            a.epoch, a.train_loss, b.train_loss
                        ));
                    }
                    if a.train_acc != b.train_acc || a.test_acc != b.test_acc {
                        return Err(format!("ctx {c} epoch {}: accuracy diverged", a.epoch));
                    }
                }
                // ...and so do all trained parameters
                nets_bit_identical(solo.net(), multi.net(c))
                    .map_err(|e| format!("ctx {c}: {e}"))?;
                // the per-context staleness closed form holds in the
                // interleave exactly as it does solo
                for i in 1..=LAYERS.len() - 1 {
                    if multi.expected_staleness(c, i) != solo.expected_staleness(i) {
                        return Err(format!("ctx {c} junction {i}: staleness law diverged"));
                    }
                    if multi.measured_staleness(c, i) != solo.measured_staleness(i) {
                        return Err(format!(
                            "ctx {c} junction {i}: measured staleness diverged"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_tenant_images_are_identical_to_solo_runs() {
    let pattern = pattern_for(&LAYERS, &[5, 3], 3);
    let (train_ds, test_ds) = toy_splits(12, 6, 96, 36, 7);
    let fmt = QFormat::new(5, 10);
    for_all(
        "Qm.n image of each interleaved tenant == its solo run's image",
        prop_seed() ^ 0x71,
        3,
        arb_scenario,
        |sc| {
            let mut multi = multi_for(sc, &pattern)?;
            multi
                .train(&train_ds, &test_ds)
                .map_err(|e| format!("multi train: {e}"))?;
            // one shared probe batch, quantized once
            let idxs: Vec<usize> = (0..test_ds.n.min(16)).collect();
            let (x, _) = test_ds.gather(&idxs);
            for c in 0..sc.contexts {
                let (solo, _) =
                    solo_twin(sc, &pattern, multi.stride(), c, &train_ds, &test_ds)?;
                let qm = FixedSparseNet::from_f32(multi.net(c), fmt);
                let qs = FixedSparseNet::from_f32(solo.net(), fmt);
                for (j, (aj, bj)) in qm.junctions.iter().zip(&qs.junctions).enumerate() {
                    if aj.wq != bj.wq {
                        return Err(format!("ctx {c} junction {j}: quantized weights"));
                    }
                    if aj.bq != bj.bq {
                        return Err(format!("ctx {c} junction {j}: quantized biases"));
                    }
                }
                // identical words must produce identical quantized logits
                let (lm, sm) = qm.logits(&x, idxs.len());
                let (ls, ss) = qs.logits(&x, idxs.len());
                if sm != ss {
                    return Err(format!("ctx {c}: saturation counts diverged"));
                }
                for (k, (a, b)) in lm.iter().zip(&ls).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("ctx {c} logit {k}: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// One context at depth 1 collapses the whole multi-tenant machinery to
/// the sequential trainer — bit for bit, through the context bank.
#[test]
fn single_context_depth_1_is_the_sequential_trainer() {
    let pattern = pattern_for(&LAYERS, &[5, 3], 5);
    let (train_ds, test_ds) = toy_splits(12, 6, 96, 36, 11);
    let seed = 5u64;

    let mut init_rng = Rng::new(seed);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut init_rng);
    let mut seq_net = Network::Sparse(snet);
    let h_seq = trainer::train(
        &mut seq_net,
        &train_ds,
        &test_ds,
        &TrainConfig {
            epochs: 3,
            batch: 16,
            l2: 1e-4,
            seed,
            ..Default::default()
        },
    );

    let mut multi = MultiPipelinedTrainer::from_pattern(
        &LAYERS,
        &pattern,
        &PipelineConfig {
            epochs: 3,
            batch: 16,
            depth: 1,
            l2: 1e-4,
            seed,
            ..Default::default()
        },
        1,
    )
    .unwrap();
    assert_eq!(multi.contexts(), 1);
    let hists = multi.train(&train_ds, &test_ds).unwrap();
    multi.audit_contexts().unwrap();

    assert_eq!(h_seq.epochs.len(), hists[0].epochs.len());
    for (a, b) in h_seq.epochs.iter().zip(&hists[0].epochs) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {}: {} vs {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc", a.epoch);
        assert_eq!(a.test_acc, b.test_acc, "epoch {} test acc", a.epoch);
    }
    let seq_snet = match &seq_net {
        Network::Sparse(n) => n,
        _ => unreachable!(),
    };
    nets_bit_identical(seq_snet, multi.net(0)).unwrap();
}

/// Mutation: alias tenant 1's state fetches onto tenant 0's bank. The
/// per-context audit must fail with a typed error naming context 1, and
/// the isolation property must visibly break — proving the parity
/// assertions above are not vacuous.
#[test]
fn aliased_context_bank_is_caught_and_breaks_isolation() {
    let pattern = pattern_for(&LAYERS, &[5, 3], 3);
    let (train_ds, test_ds) = toy_splits(12, 6, 96, 36, 7);
    let sc = Scenario {
        contexts: 3,
        admission: vec![0, 1, 2],
        depth: 0,
        seed: 21,
    };
    let mut multi = multi_for(&sc, &pattern).unwrap();
    multi.inject_fault(ContextFault::Alias { from: 1, to: 0 });
    multi.train(&train_ds, &test_ds).unwrap();

    // the audit names the offending tenant
    match multi.audit_contexts() {
        Err(e @ ContextError::Aliased {
            requested: 1,
            effective: 0,
        }) => assert_eq!(e.context(), Some(1)),
        other => panic!("expected Aliased{{1 -> 0}}, got {other:?}"),
    }

    // ...and the isolation property actually fails: tenant 1's bank was
    // never trained, so its weights cannot match the solo run's
    let (solo, _) = solo_twin(&sc, &pattern, multi.stride(), 1, &train_ds, &test_ds).unwrap();
    assert!(
        nets_bit_identical(solo.net(), multi.net(1)).is_err(),
        "aliased tenant still matched its solo twin — the parity check is vacuous"
    );
    // the untouched tenant 2 keeps running on its own bank: a defect on
    // one tenant must not silently spill into the audit of another
    let (solo2, _) = solo_twin(&sc, &pattern, multi.stride(), 2, &train_ds, &test_ds).unwrap();
    nets_bit_identical(solo2.net(), multi.net(2)).unwrap();
}

/// Mutation: drop tenant 1's state fetches entirely. The audit must
/// report the starved context by name.
#[test]
fn skipped_context_fetch_is_caught() {
    let pattern = pattern_for(&LAYERS, &[5, 3], 3);
    let (train_ds, test_ds) = toy_splits(12, 6, 96, 36, 7);
    let sc = Scenario {
        contexts: 2,
        admission: vec![1, 0],
        depth: 1,
        seed: 23,
    };
    let mut multi = multi_for(&sc, &pattern).unwrap();
    multi.inject_fault(ContextFault::Skip { context: 1 });
    multi.train(&train_ds, &test_ds).unwrap();
    match multi.audit_contexts() {
        Err(e @ ContextError::Skipped { context: 1 }) => assert_eq!(e.context(), Some(1)),
        other => panic!("expected Skipped{{1}}, got {other:?}"),
    }
    // the starved tenant's weights never moved off their initialization
    let mut tcfg = cfg_for(&sc);
    tcfg.seed = context_seed(tcfg.seed, 1);
    let fresh =
        PipelinedTrainer::from_pattern_with_stride(&LAYERS, &pattern, &tcfg, multi.stride())
            .unwrap();
    nets_bit_identical(fresh.net(), multi.net(1)).unwrap();
}
