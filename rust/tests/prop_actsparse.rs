//! Property battery for run-time activation sparsity (`nn::actsparse`)
//! composed with pre-defined weight sparsity:
//!
//! - **Selection invariants.** Top-k keeps exactly `min(k, n)` slots per
//!   row and is deterministic; thresholding keeps exactly the
//!   `|a| >= t` slots and is monotone in `t` (raising the threshold
//!   never activates a neuron).
//! - **All-ones parity (f32, bit-for-bit).** With an all-active mask,
//!   the masked FF/BP/UP kernels reproduce the weight-sparse-only CSR
//!   kernels *bit for bit* — the masked loops keep the exact edge
//!   iteration order, so f32 summation order is unchanged.
//! - **All-ones parity (Qm.n, exact).** Same statement for the Q5.10
//!   twins, including the saturation counts.
//! - **Packed-layout non-overlap.** On randomized z-regular configs the
//!   complementary-sparsity packing puts every active index in exactly
//!   one wave with no bank claimed twice — `PackedRow::verify` proves
//!   it, and the packing loses no active slot.
//! - **Quantized sparse-sparse parity.** With *identical explicit
//!   masks* on both chains, the Q5.10 masked forward tracks the f32
//!   masked forward within `fixed::forward_error_bound`.
//!
//! Seeds come from `PDS_PROP_SEED` when set (CI pins it to 1812);
//! failures print the per-case seed via `util::prop::for_all`.

use pds::nn::actsparse::{ActSpec, ActivationMask};
use pds::nn::fixed::{self, relu_raw, FixedSparseLayer, QFormat};
use pds::nn::sparse::{SparseLayer, SparseNet};
use pds::prop_assert;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

/// Root seed: `PDS_PROP_SEED` when set (CI pins it), a fixed default
/// otherwise — property runs are always reproducible from the log.
fn prop_seed() -> u64 {
    std::env::var("PDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1812_AC7)
}

fn pattern_for(layers: &[usize], dout: &[usize], seed: u64) -> NetPattern {
    let netc = NetConfig::new(layers.to_vec());
    let mut rng = Rng::new(seed);
    generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(dout.to_vec()),
        None,
        &mut rng,
    )
}

/// Random activations in a batch buffer, roughly centered, with some
/// exact zeros so tie/zero handling is exercised.
fn random_acts(rng: &mut Rng, n: usize, batch: usize) -> Vec<f32> {
    (0..n * batch)
        .map(|_| {
            if rng.uniform() < 0.1 {
                0.0
            } else {
                rng.uniform() * 2.0 - 1.0
            }
        })
        .collect()
}

#[derive(Debug)]
struct SelCase {
    n: usize,
    batch: usize,
    k: usize,
    t: f32,
    acts: Vec<f32>,
}

#[test]
fn topk_keeps_exactly_k_per_row_and_is_deterministic() {
    for_all(
        "topk selection",
        prop_seed(),
        128,
        |rng| {
            let n = 2 + rng.below(22);
            let batch = 1 + rng.below(4);
            SelCase {
                n,
                batch,
                k: 1 + rng.below(n + 4), // sometimes k > n
                t: 0.0,
                acts: random_acts(rng, n, batch),
            }
        },
        |c| {
            let m = ActivationMask::top_k(&c.acts, c.n, c.batch, c.k, 7);
            for r in 0..c.batch {
                let kept = m.row(r).iter().filter(|&&a| a).count();
                prop_assert!(
                    kept == c.k.min(c.n),
                    "row {r}: kept {kept}, want min(k={}, n={})",
                    c.k,
                    c.n
                );
                // every kept magnitude >= every dropped magnitude
                let row_acts = &c.acts[r * c.n..(r + 1) * c.n];
                let min_kept = m
                    .row(r)
                    .iter()
                    .zip(row_acts)
                    .filter(|(&a, _)| a)
                    .map(|(_, v)| v.abs())
                    .fold(f32::INFINITY, f32::min);
                let max_dropped = m
                    .row(r)
                    .iter()
                    .zip(row_acts)
                    .filter(|(&a, _)| !a)
                    .map(|(_, v)| v.abs())
                    .fold(0f32, f32::max);
                prop_assert!(
                    min_kept >= max_dropped,
                    "row {r}: dropped a magnitude ({max_dropped}) above a kept one ({min_kept})"
                );
            }
            let again = ActivationMask::top_k(&c.acts, c.n, c.batch, c.k, 7);
            prop_assert!(m == again, "top-k selection must be deterministic");
            Ok(())
        },
    );
}

#[test]
fn threshold_is_exact_and_monotone() {
    for_all(
        "threshold selection",
        prop_seed() ^ 1,
        128,
        |rng| {
            let n = 2 + rng.below(22);
            let batch = 1 + rng.below(4);
            SelCase {
                n,
                batch,
                k: 0,
                t: rng.uniform(),
                acts: random_acts(rng, n, batch),
            }
        },
        |c| {
            let m = ActivationMask::threshold(&c.acts, c.n, c.batch, c.t, 3);
            for (i, (&a, &v)) in m.active.iter().zip(&c.acts).enumerate() {
                prop_assert!(
                    a == (v.abs() >= c.t),
                    "slot {i}: active={a} but |{v}| vs t={}",
                    c.t
                );
            }
            // monotone: a higher threshold never activates a new slot
            let higher = ActivationMask::threshold(&c.acts, c.n, c.batch, c.t + 0.25, 3);
            for (i, (&lo, &hi)) in m.active.iter().zip(&higher.active).enumerate() {
                prop_assert!(lo || !hi, "slot {i}: active at t+0.25 but not at t");
            }
            let again = ActivationMask::threshold(&c.acts, c.n, c.batch, c.t, 3);
            prop_assert!(m == again, "threshold selection must be deterministic");
            Ok(())
        },
    );
}

#[derive(Debug)]
struct LayerCase {
    nl: usize,
    nr: usize,
    dout: usize,
    seed: u64,
}

fn layer_case(rng: &mut Rng) -> LayerCase {
    // z-regular-friendly shapes: dout * nl divisible by nr
    let nr = [4usize, 6, 8][rng.below(3)];
    let nl = nr * (2 + rng.below(4));
    LayerCase {
        nl,
        nr,
        dout: 2 + rng.below(3),
        seed: rng.next_u64(),
    }
}

/// Build one junction + batch data for a layer-level parity case.
fn layer_fixture(c: &LayerCase) -> (SparseLayer, Vec<f32>, Vec<f32>, usize) {
    let p = pattern_for(&[c.nl, c.nr], &[c.dout], c.seed);
    let mut rng = Rng::new(c.seed ^ 0xF1);
    let layer = SparseLayer::init_he(&p.junctions[0], 0.1, &mut rng);
    let batch = 1 + (c.seed % 3) as usize;
    let a: Vec<f32> = (0..batch * c.nl).map(|_| rng.uniform() * 2.0 - 1.0).collect();
    let delta: Vec<f32> = (0..batch * c.nr).map(|_| rng.uniform() - 0.5).collect();
    (layer, a, delta, batch)
}

#[test]
fn all_ones_mask_ff_bp_up_parity_is_bit_for_bit_f32() {
    for_all(
        "all-ones f32 parity",
        prop_seed() ^ 2,
        64,
        layer_case,
        |c| {
            let (layer, a, delta, batch) = layer_fixture(c);
            let ones = vec![true; batch * c.nl];

            let mut h0 = vec![0f32; batch * c.nr];
            let mut h1 = vec![0f32; batch * c.nr];
            layer.forward(&a, batch, &mut h0);
            layer.forward_masked(&a, batch, &ones, &mut h1);
            for (i, (x, y)) in h0.iter().zip(&h1).enumerate() {
                prop_assert!(x.to_bits() == y.to_bits(), "FF slot {i}: {x} != {y}");
            }

            let mut d0 = vec![0f32; batch * c.nl];
            let mut d1 = vec![0f32; batch * c.nl];
            layer.backprop(&delta, batch, &mut d0);
            layer.backprop_masked(&delta, batch, &ones, &mut d1);
            for (i, (x, y)) in d0.iter().zip(&d1).enumerate() {
                prop_assert!(x.to_bits() == y.to_bits(), "BP slot {i}: {x} != {y}");
            }

            let (mut gw0, mut gb0) = (vec![0f32; layer.wc.len()], vec![0f32; c.nr]);
            let (mut gw1, mut gb1) = (vec![0f32; layer.wc.len()], vec![0f32; c.nr]);
            layer.grads(&a, &delta, batch, 1e-4, &mut gw0, &mut gb0);
            layer.grads_masked(&a, &delta, batch, &ones, 1e-4, &mut gw1, &mut gb1);
            for (i, (x, y)) in gw0.iter().zip(&gw1).enumerate() {
                prop_assert!(x.to_bits() == y.to_bits(), "UP weight grad {i}: {x} != {y}");
            }
            for (i, (x, y)) in gb0.iter().zip(&gb1).enumerate() {
                prop_assert!(x.to_bits() == y.to_bits(), "UP bias grad {i}: {x} != {y}");
            }
            Ok(())
        },
    );
}

#[test]
fn all_ones_mask_ff_bp_up_parity_is_exact_quantized() {
    let fmt = QFormat::default();
    for_all(
        "all-ones Qm.n parity",
        prop_seed() ^ 3,
        64,
        layer_case,
        |c| {
            let (layer, a, delta, batch) = layer_fixture(c);
            let q = FixedSparseLayer::from_f32(&layer, fmt);
            let ones = vec![true; batch * c.nl];
            let aq = fmt.quantize_slice(&a);
            let dq = fmt.quantize_slice(&delta);

            let mut h0 = vec![0i32; batch * c.nr];
            let mut h1 = vec![0i32; batch * c.nr];
            let s0 = q.forward(&aq, batch, &mut h0);
            let s1 = q.forward_masked(&aq, batch, &ones, &mut h1);
            prop_assert!(h0 == h1, "FF raw words diverge");
            prop_assert!(s0 == s1, "FF saturation counts diverge: {s0} vs {s1}");

            let mut d0 = vec![0i32; batch * c.nl];
            let mut d1 = vec![0i32; batch * c.nl];
            let s0 = q.backprop(&dq, batch, &mut d0);
            let s1 = q.backprop_masked(&dq, batch, &ones, &mut d1);
            prop_assert!(d0 == d1, "BP raw words diverge");
            prop_assert!(s0 == s1, "BP saturation counts diverge: {s0} vs {s1}");

            let (mut gw0, mut gb0) = (vec![0i32; q.wq.len()], vec![0i32; c.nr]);
            let (mut gw1, mut gb1) = (vec![0i32; q.wq.len()], vec![0i32; c.nr]);
            let s0 = q.grads(&aq, &dq, batch, &mut gw0, &mut gb0);
            let s1 = q.grads_masked(&aq, &dq, batch, &ones, &mut gw1, &mut gb1);
            prop_assert!(gw0 == gw1 && gb0 == gb1, "UP raw grads diverge");
            prop_assert!(s0 == s1, "UP saturation counts diverge: {s0} vs {s1}");
            Ok(())
        },
    );
}

#[derive(Debug)]
struct PackCase {
    z: usize,
    waves: usize,
    batch: usize,
    k: usize,
    seed: u64,
}

#[test]
fn packed_layout_is_non_overlapping_on_z_regular_configs() {
    for_all(
        "packed non-overlap",
        prop_seed() ^ 4,
        128,
        |rng| {
            let z = 2 + rng.below(7);
            let waves = 1 + rng.below(5);
            let n = z * waves;
            PackCase {
                z,
                waves,
                batch: 1 + rng.below(3),
                k: 1 + rng.below(n),
                seed: rng.next_u64(),
            }
        },
        |c| {
            let n = c.z * c.waves;
            let mut rng = Rng::new(c.seed);
            let acts = random_acts(&mut rng, n, c.batch);
            let mask = ActivationMask::top_k(&acts, n, c.batch, c.k, 11);
            let rows = mask
                .pack(1, c.z)
                .map_err(|e| format!("z-regular pack must succeed: {e}"))?;
            prop_assert!(rows.len() == c.batch, "one packed row per batch row");
            for (r, row) in rows.iter().enumerate() {
                row.verify(1, n)
                    .map_err(|e| format!("row {r}: packed layout violation: {e}"))?;
                prop_assert!(
                    row.active_count() == mask.row(r).iter().filter(|&&a| a).count(),
                    "row {r}: packing lost active slots"
                );
                prop_assert!(
                    row.fetch_waves() <= c.waves,
                    "row {r}: more fetch waves than the z-regular bound"
                );
            }
            // non-dividing z is a typed refusal, not a silent misfit
            prop_assert!(
                mask.pack(1, n + 1).is_err(),
                "a z that does not divide n must be refused"
            );
            Ok(())
        },
    );
}

#[derive(Debug)]
struct NetCase {
    layers: Vec<usize>,
    dout: Vec<usize>,
    batch: usize,
    seed: u64,
}

fn net_case(rng: &mut Rng) -> NetCase {
    let mid = 8 + 4 * rng.below(3);
    NetCase {
        layers: vec![12, mid, 4],
        dout: vec![4, 2],
        batch: 1 + rng.below(3),
        seed: rng.next_u64(),
    }
}

#[test]
fn all_ones_net_masks_match_unmasked_logits_bit_for_bit() {
    for_all(
        "all-ones net parity",
        prop_seed() ^ 5,
        48,
        net_case,
        |c| {
            let p = pattern_for(&c.layers, &c.dout, c.seed);
            let mut rng = Rng::new(c.seed ^ 0xA11);
            let net = SparseNet::init_he(&p, 0.1, &mut rng);
            let x: Vec<f32> = (0..c.batch * c.layers[0])
                .map(|_| rng.uniform() * 2.0 - 1.0)
                .collect();
            let masks: Vec<ActivationMask> = c.layers[1..c.layers.len() - 1]
                .iter()
                .map(|&n| ActivationMask::all_ones(n, c.batch, 42))
                .collect();
            let masked = net
                .logits_masked(&x, c.batch, &masks, 42)
                .map_err(|e| format!("all-ones masks must pass verification: {e}"))?;
            let plain = net.logits(&x, c.batch);
            for (i, (m, p)) in masked.iter().zip(&plain).enumerate() {
                prop_assert!(m.to_bits() == p.to_bits(), "logit {i}: {m} != {p}");
            }
            // the same spec through logits_act: top-k at full width is
            // all-ones too, and the stats must say so
            let (acted, stats) = net.logits_act(&x, c.batch, &ActSpec::top_k(usize::MAX));
            prop_assert!(
                (stats.density() - 1.0).abs() < f64::EPSILON,
                "saturating top-k must report full density"
            );
            for (i, (a, p)) in acted.iter().zip(&plain).enumerate() {
                prop_assert!(a.to_bits() == p.to_bits(), "act logit {i}: {a} != {p}");
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_masked_forward_stays_within_error_bound() {
    let fmt = QFormat::default();
    for_all(
        "quantized sparse-sparse parity",
        prop_seed() ^ 6,
        48,
        net_case,
        |c| {
            let p = pattern_for(&c.layers, &c.dout, c.seed);
            let mut rng = Rng::new(c.seed ^ 0x0B0);
            let net = SparseNet::init_he(&p, 0.1, &mut rng);
            let qnet: Vec<FixedSparseLayer> = net
                .junctions
                .iter()
                .map(|j| FixedSparseLayer::from_f32(j, fmt))
                .collect();
            let x: Vec<f32> = (0..c.batch * c.layers[0])
                .map(|_| rng.uniform() * 2.0 - 1.0)
                .collect();
            let spec = ActSpec::top_k(1 + (c.seed % 6) as usize);
            let l = net.junctions.len();

            // f32 chain, collecting the masks it selects and the
            // per-junction input magnitude of the *masked* chain — the
            // masked activations can exceed the unmasked ones (dropping
            // negative contributions undoes cancellation), so the error
            // recursion must be fed the masked chain's own a_max
            let mut masks = Vec::new();
            let mut amaxes = Vec::with_capacity(l);
            let mut a = x.clone();
            for (i, junction) in net.junctions.iter().enumerate() {
                amaxes.push(a.iter().fold(0f32, |m, v| m.max(v.abs())) as f64);
                let mut h = vec![0f32; c.batch * junction.n_right];
                if i == 0 {
                    junction.forward(&a, c.batch, &mut h);
                } else {
                    let m = spec.mask(&a, junction.n_left, c.batch, 0);
                    junction.forward_masked(&a, c.batch, &m.active, &mut h);
                    masks.push(m);
                }
                if i != l - 1 {
                    pds::nn::relu(&mut h);
                }
                a = h;
            }
            let f32_logits = a;

            // quantized chain under the *same* explicit masks
            let mut sat = 0usize;
            let mut aq = fmt.quantize_slice(&x);
            for (i, junction) in qnet.iter().enumerate() {
                let mut h = vec![0i32; c.batch * junction.n_right];
                sat += if i == 0 {
                    junction.forward(&aq, c.batch, &mut h)
                } else {
                    junction.forward_masked(&aq, c.batch, &masks[i - 1].active, &mut h)
                };
                if i != l - 1 {
                    relu_raw(&mut h);
                }
                aq = h;
            }
            if sat > 0 {
                // the error bound's derivation assumes no saturation;
                // He-init nets on [-1, 1] inputs essentially never clip
                // in Q5.10, so skipping the rare case keeps the
                // property sound without weakening it
                return Ok(());
            }
            let q_logits = fmt.dequantize_slice(&aq);

            // same recursion as fixed::forward_error_bound, but with
            // a_max measured on the masked chain it actually bounds;
            // take the max with the public bound so the property also
            // exercises that surface
            let u = f64::from(fmt.ulp());
            let mut err = 0.5 * u;
            for (junction, &amax) in net.junctions.iter().zip(&amaxes) {
                let wmax = junction.wc.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
                let din_max = (0..junction.n_right)
                    .map(|j| (junction.offsets[j + 1] - junction.offsets[j]) as usize)
                    .max()
                    .unwrap_or(0) as f64;
                err = din_max * (wmax * err + (amax + err) * 0.5 * u) + u;
            }
            let bound = (err.mul_add(1.001, 1e-5) as f32)
                .max(fixed::forward_error_bound(&net, &x, c.batch, fmt));
            for (i, (f, q)) in f32_logits.iter().zip(&q_logits).enumerate() {
                prop_assert!(
                    (f - q).abs() <= bound,
                    "logit {i}: |{f} - {q}| = {} exceeds the forward error bound {bound}",
                    (f - q).abs()
                );
            }
            Ok(())
        },
    );
}
