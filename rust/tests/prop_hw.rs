//! Property tests over the hardware simulator: the cycle-accurate
//! junction unit must agree with the masked-dense reference math for
//! every randomized configuration, the pipeline schedule must audit
//! clean, and z-config validation must accept exactly the admissible
//! configurations.

use pds::hw::junction::{Act, JunctionUnit};
use pds::hw::pipeline::Pipeline;
use pds::hw::storage::training_storage;
use pds::hw::zconfig;
use pds::prop_assert;
use pds::sparsity::clash_free::{schedule, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

struct Case {
    shape: JunctionShape,
    d_in: usize,
    d_out: usize,
    z: usize,
    seed: u64,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "({}x{}, d_out {}, z {}, seed {:#x})",
            self.shape.n_left, self.shape.n_right, self.d_out, self.z, self.seed
        )
    }
}

fn hw_case(r: &mut Rng) -> Case {
    // n_left = z * depth; n_right divides n_left * d_out
    let z = 1 + r.below(10);
    let depth = 1 + r.below(8);
    let n_left = z * depth;
    // pick d_in first, then n_right from divisors of n_left*d_in... simpler:
    // pick n_right and d_out admissible
    loop {
        let n_right = 1 + r.below(30);
        let shape = JunctionShape { n_left, n_right };
        let step = shape.min_dout();
        if step > n_right {
            continue;
        }
        let d_out = step * (1 + r.below(n_right / step));
        let d_in = n_left * d_out / n_right;
        return Case {
            shape,
            d_in,
            d_out,
            z,
            seed: r.next_u64(),
        };
    }
}

fn build_unit(c: &Case) -> (JunctionUnit, Vec<f32>) {
    let mut rng = Rng::new(c.seed);
    let sched = schedule(
        c.shape.n_left,
        c.z,
        c.d_out,
        Flavor::Type1 { dither: false },
        &mut rng,
    );
    let z_next = JunctionUnit::required_z_next(c.shape.n_right * c.d_in, c.z, c.d_in);
    let mut unit = JunctionUnit::new(c.shape, c.d_in, sched, z_next);
    let dense: Vec<f32> = (0..c.shape.n_right * c.shape.n_left)
        .map(|_| rng.normal())
        .collect();
    unit.load_weights_dense(&dense);
    (unit, dense)
}

#[test]
fn hw_ff_matches_masked_dense_for_random_junctions() {
    for_all(
        "hw FF == reference",
        41,
        40,
        hw_case,
        |c| {
            let (mut unit, dense) = build_unit(c);
            let pattern = unit.pattern();
            pattern.audit()?;
            let mask = pattern.mask();
            let mut rng = Rng::new(c.seed ^ 1);
            let a: Vec<f32> = (0..c.shape.n_left).map(|_| rng.normal()).collect();
            let bias: Vec<f32> = (0..c.shape.n_right).map(|_| rng.normal()).collect();
            let out = unit
                .feedforward(&a, &bias, Act::Relu)
                .map_err(|e| e.to_string())?;
            prop_assert!(out.stats.cycles == unit.junction_cycle, "cycle count");
            let bound = JunctionUnit::required_z_next(c.shape.n_right * c.d_in, c.z, c.d_in);
            prop_assert!(
                out.stats.max_rights_per_cycle <= bound,
                "right-bank bound violated: {} > {}",
                out.stats.max_rights_per_cycle,
                bound
            );
            for j in 0..c.shape.n_right {
                let want: f32 = (0..c.shape.n_left)
                    .map(|k| {
                        mask[j * c.shape.n_left + k] * dense[j * c.shape.n_left + k] * a[k]
                    })
                    .sum::<f32>()
                    + bias[j];
                prop_assert!(
                    (out.h[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "h[{j}] = {} want {want}",
                    out.h[j]
                );
            }
            Ok(())
        },
    );
}

#[test]
fn hw_bp_and_up_match_reference_for_random_junctions() {
    for_all(
        "hw BP/UP == reference",
        43,
        30,
        hw_case,
        |c| {
            let (mut unit, dense) = build_unit(c);
            let pattern = unit.pattern();
            let mask = pattern.mask();
            let nl = c.shape.n_left;
            let mut rng = Rng::new(c.seed ^ 2);
            let dr: Vec<f32> = (0..c.shape.n_right).map(|_| rng.normal()).collect();
            let adot: Vec<f32> = (0..nl)
                .map(|_| if rng.uniform() > 0.5 { 1.0 } else { 0.0 })
                .collect();
            let (dl, _) = unit.backprop(&dr, &adot).map_err(|e| e.to_string())?;
            for k in 0..nl {
                let want: f32 = (0..c.shape.n_right)
                    .map(|j| mask[j * nl + k] * dense[j * nl + k] * dr[j])
                    .sum::<f32>()
                    * adot[k];
                prop_assert!(
                    (dl[k] - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "dl[{k}] = {} want {want}",
                    dl[k]
                );
            }
            // UP
            let a_old: Vec<f32> = (0..nl).map(|_| rng.normal()).collect();
            let mut bias = vec![0f32; c.shape.n_right];
            unit.update(&a_old, &dr, &mut bias, 0.05)
                .map_err(|e| e.to_string())?;
            let got = unit.dump_weights_dense();
            for j in 0..c.shape.n_right {
                prop_assert!(
                    (bias[j] + 0.05 * dr[j]).abs() < 1e-5,
                    "bias update wrong at {j}"
                );
                for k in 0..nl {
                    let idx = j * nl + k;
                    let want = mask[idx] * (dense[idx] - 0.05 * dr[j] * a_old[k]);
                    prop_assert!(
                        (got[idx] - want).abs() < 1e-4 * (1.0 + want.abs()),
                        "w[{j},{k}] = {} want {want}",
                        got[idx]
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pipeline_schedule_audits_for_all_depths() {
    for_all(
        "pipeline audit",
        47,
        16,
        |r| 1 + r.below(8),
        |&l| {
            let p = Pipeline::new(l);
            p.audit(300)?;
            prop_assert!(p.steady_state_ops() == 3 * l - 1, "ops");
            for i in 1..=l {
                prop_assert!(
                    p.measured_staleness(i, 300) == Some(p.staleness(i)),
                    "staleness at junction {i}"
                );
                prop_assert!(
                    p.queue_banks(i) == 2 * (l - (i - 1)) + 1,
                    "queue banks at junction {i}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn storage_model_consistency() {
    for_all(
        "storage totals",
        53,
        48,
        |r| {
            let l = 2 + r.below(3);
            let mut layers = vec![10 * (1 + r.below(20))];
            for _ in 0..l {
                layers.push(10 * (1 + r.below(10)));
            }
            layers
        },
        |layers| {
            let netc = NetConfig::new(layers.clone());
            let fc = training_storage(&netc, &netc.fc_dout());
            // FC weight storage is exactly sum N_{i-1} N_i
            let dense: usize = (0..netc.n_junctions())
                .map(|i| layers[i] * layers[i + 1])
                .sum();
            prop_assert!(fc.weights == dense, "FC weights");
            // sparse storage at min density is strictly smaller but the
            // layer-parameter banks are identical
            let dout = DoutConfig(
                (0..netc.n_junctions())
                    .map(|i| netc.junction(i).min_dout())
                    .collect(),
            );
            let sp = training_storage(&netc, &dout);
            prop_assert!(sp.activations == fc.activations, "a banks differ");
            prop_assert!(sp.deltas == fc.deltas, "delta banks differ");
            prop_assert!(sp.weights <= fc.weights, "sparse weights bigger than FC");
            Ok(())
        },
    );
}

#[test]
fn zconfig_derive_is_always_valid() {
    for_all(
        "derive z_net",
        59,
        48,
        |r| {
            let netc = NetConfig::new(vec![
                8 * (1 + r.below(20)),
                4 * (1 + r.below(20)),
                2 * (1 + r.below(10)),
            ]);
            let dout = DoutConfig(
                (0..2)
                    .map(|i| {
                        let j = netc.junction(i);
                        j.min_dout() * (1 + r.below((j.n_right / j.min_dout()).max(1)).min(3))
                    })
                    .collect(),
            );
            (netc, dout, r.next_u64())
        },
        |(netc, dout, _)| {
            if netc.validate_dout(dout).is_err() {
                return Ok(());
            }
            // derive with z0 = every divisor of |W_0| that divides N_0 too
            let edges0 = netc.edges(dout)[0];
            let mut found = 0;
            for z0 in 1..=edges0.min(64) {
                if edges0 % z0 != 0 {
                    continue;
                }
                if let Ok(cfg) = zconfig::derive(netc, dout, z0) {
                    found += 1;
                    prop_assert!(
                        zconfig::validate(netc, dout, &cfg.z).is_ok(),
                        "derive produced invalid config"
                    );
                    prop_assert!(cfg.balanced, "derive must balance cycles");
                }
            }
            // perfectly balanced z_nets need not exist for arbitrary
            // (N_net, d_out) — the paper's own Table II configs are only
            // approximately balanced — so `found == 0` is acceptable.
            let _ = found;
            Ok(())
        },
    );
}
