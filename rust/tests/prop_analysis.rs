//! Property tests for the static verifier (`analysis`): the clash
//! prover's symbolic verdict must coincide with the brute-force
//! `verify_clash_free` replay on randomized schedules — both on valid
//! generator draws and under injected corruptions — and the range
//! analysis' certified input bound must never be violated by concrete
//! quantized forward passes.
//!
//! Seeds come from `PDS_PROP_SEED` when set (CI pins it for
//! reproducibility); failures print the per-case seed via
//! `util::prop::for_all`.

use pds::analysis::range::{certified_raw_bound, propagate, value_bound};
use pds::nn::fixed::{relu_raw, FixedSparseNet, QFormat};
use pds::nn::sparse::SparseNet;
use pds::prop_assert;
use pds::sparsity::clash_free::{self, AddrGen, Flavor};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

/// Root seed: `PDS_PROP_SEED` when set (CI pins it), a fixed default
/// otherwise — property runs are always reproducible from the log.
fn prop_seed() -> u64 {
    std::env::var("PDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1812_0116)
}

fn flavor_of(ix: usize) -> Flavor {
    match ix {
        0 => Flavor::Type1 { dither: false },
        1 => Flavor::Type1 { dither: true },
        2 => Flavor::Type2 { dither: false },
        3 => Flavor::Type2 { dither: true },
        4 => Flavor::Type3 { dither: false },
        _ => Flavor::Type3 { dither: true },
    }
}

/// Random admissible schedule-spec parameters. `z >= 2` and `depth >= 2`
/// so every corruption below has room to act.
fn spec_case(r: &mut Rng) -> (usize, usize, usize, usize, u64) {
    let z = 2 + r.below(8);
    let depth = 2 + r.below(10);
    let d_out = 1 + r.below(5);
    let flavor_ix = r.below(6);
    (z, depth, d_out, flavor_ix, r.next_u64())
}

#[test]
fn prover_verdict_matches_replay_on_valid_schedules() {
    for_all(
        "prover == replay on generator output",
        prop_seed(),
        96,
        spec_case,
        |&(z, depth, d_out, flavor_ix, seed)| {
            let spec = clash_free::schedule_spec(
                z * depth,
                z,
                d_out,
                flavor_of(flavor_ix),
                &mut Rng::new(seed),
            );
            let proved = spec.prove_clash_free();
            let replayed = spec.materialize().verify_clash_free();
            prop_assert!(proved.is_ok(), "prover rejected a generator draw: {proved:?}");
            prop_assert!(replayed.is_ok(), "replay rejected a generator draw: {replayed:?}");
            Ok(())
        },
    );
}

#[test]
fn prover_verdict_matches_replay_under_corruption() {
    for_all(
        "prover == replay under injected corruption",
        prop_seed() ^ 0x5eed,
        96,
        spec_case,
        |&(z, depth, d_out, flavor_ix, seed)| {
            let mut rng = Rng::new(seed);
            let mut spec = clash_free::schedule_spec(
                z * depth,
                z,
                d_out,
                flavor_of(flavor_ix),
                &mut rng,
            );
            let s = rng.below(spec.sweeps.len());
            let lane = rng.below(z);
            // 0: duplicate a sigma entry (memory clash in every cycle)
            // 1: out-of-range sigma entry
            // 2: mutate the address generator — for Affine sweeps the
            //    seed vector is *irrelevant* to clash-freedom (any phi is
            //    a cyclic rotation), so both sides must still accept; for
            //    Explicit sweeps a repeated column entry skips/repeats a
            //    neuron, so both sides must reject
            let kind = rng.below(3);
            let must_reject = match kind {
                0 => {
                    spec.sweeps[s].sigma[lane] = spec.sweeps[s].sigma[(lane + 1) % z];
                    true
                }
                1 => {
                    spec.sweeps[s].sigma[lane] = z + rng.below(4);
                    true
                }
                _ => match &mut spec.sweeps[s].addr {
                    AddrGen::Affine { phi } => {
                        // any seed, including >= depth, stays clash-free
                        phi[lane] = rng.below(4 * depth);
                        false
                    }
                    AddrGen::Explicit { cols } => {
                        cols[lane][0] = cols[lane][1];
                        true
                    }
                },
            };
            let proved = spec.prove_clash_free();
            let replayed = spec.materialize().verify_clash_free();
            prop_assert!(
                proved.is_ok() == replayed.is_ok(),
                "verdicts diverge: prover {proved:?}, replay {replayed:?}"
            );
            if must_reject {
                prop_assert!(proved.is_err(), "corruption survived the prover");
            } else {
                prop_assert!(proved.is_ok(), "benign mutation rejected: {proved:?}");
            }
            Ok(())
        },
    );
}

/// Random small net + format for range-soundness cases.
fn range_case(r: &mut Rng) -> (Vec<usize>, QFormat, u64) {
    let layers = vec![8 * (1 + r.below(4)), 4 * (1 + r.below(4)), 2 * (1 + r.below(3))];
    let m = 2 + r.below(5) as u32;
    let n = 4 + r.below(8) as u32;
    (layers, QFormat::new(m, n), r.next_u64())
}

#[test]
fn certified_range_is_never_violated_by_concrete_execution() {
    for_all(
        "range certificate soundness",
        prop_seed() ^ 0xface,
        48,
        range_case,
        |case| {
            let (layers, fmt, seed) = case;
            let netc = NetConfig::new(layers.clone());
            let dout = DoutConfig(
                (0..netc.n_junctions())
                    .map(|i| netc.junction(i).min_dout())
                    .collect(),
            );
            netc.validate_dout(&dout).map_err(|e| e.to_string())?;
            let mut rng = Rng::new(*seed);
            let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
            let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
            let qnet = FixedSparseNet::from_f32(&snet, *fmt);
            if qnet.clipped_params() > 0 {
                return Ok(()); // param-clip is the analyzer's verdict, not this property's
            }
            let Some(b) = certified_raw_bound(&qnet) else {
                // no safe range: the parameters alone must already saturate
                prop_assert!(!propagate(&qnet, 0, 0).sound(), "None but b=0 sound");
                return Ok(());
            };
            // the certified value bound quantizes back inside the raw bound
            let v = value_bound(*fmt, b);
            prop_assert!(fmt.quantize(v) <= b, "value bound escapes raw bound");

            // concrete quantized execution within the certified range:
            // zero saturations, and every junction output inside the
            // derived interval
            let check = propagate(&qnet, -b, b);
            prop_assert!(check.sound(), "certified bound not sound");
            let batch = 4usize;
            let mut a: Vec<i32> = (0..batch * layers[0])
                .map(|_| rng.below(2 * b as usize + 1) as i32 - b)
                .collect();
            let last = qnet.junctions.len() - 1;
            for (ji, j) in qnet.junctions.iter().enumerate() {
                let mut h = vec![0i32; batch * j.n_right];
                let sats = j.forward(&a, batch, &mut h);
                prop_assert!(sats == 0, "junction {ji} saturated inside certified range");
                let lb = &check.layers[ji];
                for &vq in &h {
                    prop_assert!(
                        (vq as i128) >= lb.out_lo && (vq as i128) <= lb.out_hi,
                        "junction {ji}: output {vq} outside derived [{}, {}]",
                        lb.out_lo,
                        lb.out_hi
                    );
                }
                if ji != last {
                    relu_raw(&mut h);
                }
                a = h;
            }
            Ok(())
        },
    );
}
