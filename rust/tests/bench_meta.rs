//! Bench-metadata sanity: `BENCH_train.json` and `BENCH_serve.json` at
//! the repo root must parse and carry the schema the benches write —
//! including the `recorded` flag — so placeholder drift (a bench
//! renaming a field, or a stale placeholder losing sync with the
//! recorder) is caught by `cargo test` instead of review.
//!
//! Contract: every timing/throughput field must be *present*; it may be
//! `null` only while the file's `recorded` flag is `false`. Once a file
//! claims `recorded: true`, nulls in required numeric fields fail.
//!
//! The same discipline applies to the `pds analyze --json` report: its
//! schema is CI-consumed, so [`analyzer_report_schema`] pins it here.

use pds::util::json::Json;

fn load(name: &str) -> Json {
    let path = format!("{}/../{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("{name} is not valid JSON: {e}"))
}

/// The `recorded` flag must exist and be a bool.
fn recorded_flag(doc: &Json, what: &str) -> bool {
    match doc.get("recorded") {
        Some(Json::Bool(b)) => *b,
        other => panic!("{what}: 'recorded' must be a bool, got {other:?}"),
    }
}

/// A required field: present always, numeric when `recorded`.
fn check_field(obj: &Json, key: &str, recorded: bool, what: &str) {
    match obj.get(key) {
        None => panic!("{what}: missing required field '{key}'"),
        Some(Json::Null) if recorded => {
            panic!("{what}: '{key}' is null but the file claims recorded=true")
        }
        Some(Json::Null) | Some(Json::Num(_)) => {}
        Some(other) => panic!("{what}: '{key}' must be a number or null, got {other:?}"),
    }
}

#[test]
fn bench_train_schema() {
    let doc = load("BENCH_train.json");
    assert_eq!(
        doc.get("bench").and_then(|v| v.as_str()),
        Some("train_pipeline"),
        "bench tag"
    );
    let recorded = recorded_flag(&doc, "BENCH_train.json");
    check_field(&doc, "kernel_threads_total", recorded, "BENCH_train.json");
    check_field(&doc, "max_speedup", recorded, "BENCH_train.json");
    assert!(
        doc.get("target_speedup").and_then(|v| v.as_f64()).is_some(),
        "target_speedup must be a number"
    );
    let cases = doc
        .get("cases")
        .and_then(|v| v.as_arr())
        .expect("cases array");
    assert!(!cases.is_empty(), "cases must not be empty");
    for (i, case) in cases.iter().enumerate() {
        let what = format!("BENCH_train.json case {i}");
        assert!(
            case.get("name").and_then(|v| v.as_str()).is_some(),
            "{what}: name"
        );
        let layers = case
            .get("layers")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{what}: layers"));
        assert!(layers.len() >= 2, "{what}: layers too short");
        for key in ["batch", "depth", "samples_per_epoch"] {
            assert!(
                case.get(key).and_then(|v| v.as_usize()).is_some(),
                "{what}: '{key}' must be a positive integer"
            );
        }
        for key in ["seq_epoch_ms", "pipe_epoch_ms", "speedup"] {
            check_field(case, key, recorded, &what);
        }
    }
}

#[test]
fn bench_serve_schema() {
    let doc = load("BENCH_serve.json");
    assert_eq!(
        doc.get("bench").and_then(|v| v.as_str()),
        Some("serve_load"),
        "bench tag"
    );
    let recorded = recorded_flag(&doc, "BENCH_serve.json");
    check_field(&doc, "kernel_threads_total", recorded, "BENCH_serve.json");
    // the speedup keys must be present but may legitimately be null
    // even when recorded (a single-scenario sweep has no baseline pair)
    for key in ["speedup_workers", "speedup_vs_single_worker"] {
        match doc.get(key) {
            Some(Json::Null) | Some(Json::Num(_)) => {}
            other => panic!("BENCH_serve.json: '{key}' must be number or null, got {other:?}"),
        }
    }
    let scenarios = doc
        .get("scenarios")
        .and_then(|v| v.as_arr())
        .expect("scenarios array");
    assert!(!scenarios.is_empty(), "scenarios must not be empty");
    for (i, sc) in scenarios.iter().enumerate() {
        let what = format!("BENCH_serve.json scenario {i}");
        assert!(
            sc.get("workers").and_then(|v| v.as_usize()).is_some(),
            "{what}: workers"
        );
        check_field(sc, "total_throughput_rps", recorded, &what);
        let models = sc
            .get("models")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{what}: models array"));
        for (j, m) in models.iter().enumerate() {
            let what = format!("{what} model {j}");
            assert!(m.get("model").and_then(|v| v.as_str()).is_some(), "{what}");
            for key in [
                "served",
                "rejected",
                "throughput_rps",
                "p50_us",
                "p95_us",
                "p99_us",
                "batches",
                "mean_occupancy",
                "stolen",
            ] {
                check_field(m, key, recorded, &what);
            }
        }
    }
}

#[test]
fn bench_serve_net_section_schema() {
    let doc = load("BENCH_serve.json");
    let net = doc
        .get("net")
        .expect("net section (written by `cargo bench --bench net_load`)");
    let recorded = recorded_flag(net, "net");
    check_field(net, "kernel_threads_total", recorded, "net");
    // the batch window is a configuration constant, not a measurement:
    // always a concrete number
    assert!(
        net.get("batch_window_us").and_then(|v| v.as_f64()).is_some(),
        "net.batch_window_us must be a number"
    );
    // the headline coalescing number may be null only while unrecorded
    check_field(net, "mean_coalesced_batch", recorded, "net");
    let scenarios = net
        .get("scenarios")
        .and_then(|v| v.as_arr())
        .expect("net.scenarios array");
    assert!(!scenarios.is_empty(), "net.scenarios must not be empty");
    for (i, sc) in scenarios.iter().enumerate() {
        let what = format!("net scenario {i}");
        for key in ["clients", "pipeline"] {
            assert!(
                sc.get(key).and_then(|v| v.as_usize()).is_some(),
                "{what}: '{key}' must be a positive integer"
            );
        }
        check_field(sc, "total_throughput_rps", recorded, &what);
        check_field(sc, "mean_coalesced_batch", recorded, &what);
        let models = sc
            .get("models")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{what}: models array"));
        for (j, m) in models.iter().enumerate() {
            let what = format!("{what} model {j}");
            assert!(m.get("model").and_then(|v| v.as_str()).is_some(), "{what}");
            for key in [
                "served",
                "busy_retries",
                "throughput_rps",
                "p50_us",
                "p95_us",
                "p99_us",
                "net_flushes",
                "net_coalesced",
                "mean_coalesced",
            ] {
                check_field(m, key, recorded, &what);
            }
        }
    }
    // the reactor scale-out soak: present always (placeholder nulls
    // until `cargo bench --bench net_load` records it); once the net
    // section claims recorded, every soak measurement must be concrete
    let soak = net
        .get("soak")
        .expect("net.soak subsection (written by `cargo bench --bench net_load`)");
    assert!(
        soak.get("model").and_then(|v| v.as_str()).is_some(),
        "net.soak: 'model' must be a string"
    );
    for key in [
        "connections",
        "served",
        "busy_retries",
        "dropped_connections",
        "shed_connections",
        "accept_errors",
        "wall_s",
        "throughput_rps",
        "p50_us",
        "p99_us",
        "p999_us",
        "shed_rate",
    ] {
        check_field(soak, key, recorded, "net.soak");
    }
    // acceptance discipline: once the net section claims recorded, the
    // achieved mean coalesced batch size must demonstrate coalescing
    // and the soak population must be at reactor scale
    if recorded {
        let mean = net
            .get("mean_coalesced_batch")
            .and_then(|v| v.as_f64())
            .expect("recorded net section has a numeric mean_coalesced_batch");
        assert!(
            mean > 1.0,
            "recorded mean coalesced batch size must exceed 1 (got {mean})"
        );
        let conns = soak
            .get("connections")
            .and_then(|v| v.as_f64())
            .expect("recorded soak has a numeric connection count");
        assert!(
            conns >= 256.0,
            "recorded soak must hold a reactor-scale population (got {conns})"
        );
    }
}

/// The `pds analyze --json` report is a machine-readable CI surface:
/// pin its schema (top-level keys, per-finding keys, value types, count
/// consistency) against the real builtin-manifest report, and check it
/// round-trips through the in-tree JSON layer.
#[test]
fn analyzer_report_schema() {
    use pds::analysis::{analyze_manifest, AnalyzeOptions};
    use pds::runtime::Manifest;

    let report = analyze_manifest(&Manifest::builtin(), &AnalyzeOptions::default());
    let doc = report.to_json();

    assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(1));
    let status = doc
        .get("status")
        .and_then(|v| v.as_str())
        .expect("status string");
    assert!(
        status == "pass" || status == "fail",
        "status must be pass|fail, got '{status}'"
    );
    let errors = doc.get("errors").and_then(|v| v.as_usize()).expect("errors");
    let warnings = doc
        .get("warnings")
        .and_then(|v| v.as_usize())
        .expect("warnings");
    let infos = doc.get("infos").and_then(|v| v.as_usize()).expect("infos");
    assert_eq!(status == "fail", errors > 0, "status must track errors");
    let findings = doc
        .get("findings")
        .and_then(|v| v.as_arr())
        .expect("findings array");
    assert_eq!(
        findings.len(),
        errors + warnings + infos,
        "severity counts must partition the findings"
    );
    assert!(!findings.is_empty(), "builtin analysis emits proof findings");
    for (i, f) in findings.iter().enumerate() {
        let what = format!("finding {i}");
        for key in ["pass", "code", "severity", "config", "message"] {
            assert!(
                f.get(key).and_then(|v| v.as_str()).is_some(),
                "{what}: '{key}' must be a string"
            );
        }
        let sev = f.get("severity").and_then(|v| v.as_str()).unwrap();
        assert!(
            ["error", "warning", "info"].contains(&sev),
            "{what}: bad severity '{sev}'"
        );
        let pass = f.get("pass").and_then(|v| v.as_str()).unwrap();
        assert!(
            ["clash", "range", "lint"].contains(&pass),
            "{what}: unknown pass '{pass}'"
        );
        // counterexample coordinates are optional, but typed when present
        for key in ["junction", "cycle", "bank", "context"] {
            if let Some(v) = f.get(key) {
                assert!(
                    v.as_usize().is_some(),
                    "{what}: '{key}' must be a non-negative integer"
                );
            }
        }
    }
    // stable round-trip through the hand-rolled JSON layer
    let reparsed = Json::parse(&doc.to_string()).expect("report serializes to valid JSON");
    assert_eq!(reparsed, doc, "report must round-trip");
}

/// The `actsparse` sections (written by `cargo bench --bench actsparse`
/// into both BENCH files): the kernel sweep must carry a non-empty
/// density axis per config, every speedup/timing field must exist (and
/// be numeric once `recorded: true`), and the train section must pair
/// each config's dense and masked step times.
#[test]
fn bench_actsparse_sections_schema() {
    // serving/kernel half, merged into BENCH_serve.json
    let doc = load("BENCH_serve.json");
    let a = doc
        .get("actsparse")
        .expect("actsparse section (written by `cargo bench --bench actsparse`)");
    let recorded = recorded_flag(a, "actsparse");
    let fmt = a
        .get("format")
        .and_then(|v| v.as_str())
        .expect("actsparse.format");
    assert!(
        pds::nn::fixed::QFormat::parse(fmt).is_some(),
        "actsparse.format '{fmt}' is not a Qm.n format"
    );
    check_field(a, "kernel_threads_total", recorded, "actsparse");
    let kernel = match a.get("kernel") {
        Some(Json::Obj(m)) => m,
        other => panic!("actsparse.kernel must be a per-config object, got {other:?}"),
    };
    assert!(kernel.len() >= 2, "kernel sweep must cover >= 2 Table-II configs");
    for (config, section) in kernel {
        let what = format!("actsparse.kernel.{config}");
        let layers = section
            .get("layers")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{what}: layers"));
        assert!(layers.len() >= 2, "{what}: layers too short");
        assert!(
            section.get("batch").and_then(|v| v.as_usize()).is_some(),
            "{what}: batch"
        );
        for key in ["f32_base_ms", "quant_base_ms"] {
            check_field(section, key, recorded, &what);
        }
        let densities = section
            .get("densities")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{what}: densities axis"));
        assert!(
            densities.len() >= 2,
            "{what}: the density axis needs at least two points"
        );
        for (i, point) in densities.iter().enumerate() {
            let what = format!("{what} density point {i}");
            assert!(
                point.get("fraction").and_then(|v| v.as_str()).is_some(),
                "{what}: fraction label"
            );
            assert!(
                point.get("k").and_then(|v| v.as_usize()).is_some(),
                "{what}: k"
            );
            for key in [
                "density",
                "quant_density",
                "f32_ms",
                "f32_speedup",
                "quant_ms",
                "quant_speedup",
                "argmax_agreement",
            ] {
                check_field(point, key, recorded, &what);
            }
        }
    }
    let serve = a
        .get("serve")
        .and_then(|v| v.as_arr())
        .expect("actsparse.serve array");
    assert!(!serve.is_empty(), "actsparse.serve must not be empty");
    let mut with_act = false;
    let mut without_act = false;
    for (i, sc) in serve.iter().enumerate() {
        let what = format!("actsparse.serve scenario {i}");
        assert!(
            sc.get("scenario").and_then(|v| v.as_str()).is_some(),
            "{what}: scenario label"
        );
        for key in ["quant", "act"] {
            match sc.get(key) {
                Some(Json::Bool(b)) => {
                    if key == "act" {
                        with_act |= *b;
                        without_act |= !*b;
                    }
                }
                other => panic!("{what}: '{key}' must be a bool, got {other:?}"),
            }
        }
        for key in ["rps", "density"] {
            check_field(sc, key, recorded, &what);
        }
    }
    assert!(
        with_act && without_act,
        "actsparse.serve must pair masked and unmasked scenarios"
    );

    // train half, merged into BENCH_train.json
    let doc = load("BENCH_train.json");
    let a = doc
        .get("actsparse")
        .expect("actsparse section (written by `cargo bench --bench actsparse`)");
    let recorded = recorded_flag(a, "BENCH_train.json actsparse");
    let train = match a.get("train") {
        Some(Json::Obj(m)) => m,
        other => panic!("actsparse.train must be a per-config object, got {other:?}"),
    };
    assert!(train.len() >= 2, "train sweep must cover >= 2 configs");
    for (config, section) in train {
        let what = format!("actsparse.train.{config}");
        assert!(
            section.get("k").and_then(|v| v.as_usize()).is_some(),
            "{what}: k"
        );
        for key in ["dense_ms", "act_ms", "act_speedup", "dense_loss", "act_loss"] {
            check_field(section, key, recorded, &what);
        }
    }
}

/// The `obs_overhead` section (written by `cargo bench --bench
/// serve_load`): the observability layer's disabled-path cost per
/// request, bounded against the measured request latency. The bound is
/// a constant of the acceptance criteria (< 2% on the serve hot path),
/// so it must always be concrete — and once the section is recorded,
/// the measured overhead must actually sit under it.
#[test]
fn bench_serve_obs_overhead_schema() {
    let doc = load("BENCH_serve.json");
    let o = doc
        .get("obs_overhead")
        .expect("obs_overhead section (written by `cargo bench --bench serve_load`)");
    let recorded = recorded_flag(o, "obs_overhead");
    for key in ["disabled_path_ns_per_request", "request_us", "overhead_pct"] {
        check_field(o, key, recorded, "obs_overhead");
    }
    let bound = o
        .get("bound_pct")
        .and_then(|v| v.as_f64())
        .expect("obs_overhead.bound_pct must always be a concrete number");
    assert_eq!(bound, 2.0, "the acceptance bound is 2% of the serve hot path");
    if recorded {
        let pct = o
            .get("overhead_pct")
            .and_then(|v| v.as_f64())
            .expect("recorded obs_overhead has a numeric overhead_pct");
        assert!(
            pct < bound,
            "recorded disabled-path overhead {pct}% breaches the {bound}% bound"
        );
    }
}

/// The `profile` section of BENCH_train.json (written by `cargo bench
/// --bench train_pipeline`): per-junction, per-stage wall time plus the
/// paper's modelled clock cost for one profiled epoch. The junction
/// axis may be empty only while the section is a placeholder.
#[test]
fn bench_train_profile_schema() {
    let doc = load("BENCH_train.json");
    let p = doc
        .get("profile")
        .expect("profile section (written by `cargo bench --bench train_pipeline`)");
    let recorded = recorded_flag(p, "profile");
    assert!(
        p.get("case").and_then(|v| v.as_str()).is_some(),
        "profile.case must name the profiled bench case"
    );
    for key in ["total_wall_ms", "total_model_cycles"] {
        check_field(p, key, recorded, "profile");
    }
    let junctions = p
        .get("junctions")
        .and_then(|v| v.as_arr())
        .expect("profile.junctions array");
    if recorded {
        assert!(
            !junctions.is_empty(),
            "a recorded profile must cover at least one junction"
        );
    }
    for (i, j) in junctions.iter().enumerate() {
        let what = format!("profile junction {i}");
        for key in ["junction", "cycles_per_op"] {
            assert!(
                j.get(key).and_then(|v| v.as_usize()).is_some(),
                "{what}: '{key}' must be a non-negative integer"
            );
        }
        for stage in ["ff", "bp", "up"] {
            let s = j
                .get(stage)
                .unwrap_or_else(|| panic!("{what}: missing stage '{stage}'"));
            for key in ["ops", "wall_ms", "model_cycles"] {
                check_field(s, key, recorded, &format!("{what}.{stage}"));
            }
        }
    }
}

#[test]
fn bench_serve_quant_section_schema() {
    let doc = load("BENCH_serve.json");
    let q = doc
        .get("quant_exec")
        .expect("quant_exec section (written by `cargo bench --bench quant_exec`)");
    let recorded = recorded_flag(q, "quant_exec");
    // the format tag must always parse as Qm.n
    let fmt = q
        .get("format")
        .and_then(|v| v.as_str())
        .expect("quant_exec.format");
    assert!(
        pds::nn::fixed::QFormat::parse(fmt).is_some(),
        "quant_exec.format '{fmt}' is not a Qm.n format"
    );
    let kernel = q.get("kernel").expect("quant_exec.kernel");
    for key in ["batch", "f32_ms", "quant_ms", "quant_speedup", "saturations"] {
        check_field(kernel, key, recorded, "quant_exec.kernel");
    }
    let serve = q.get("serve").expect("quant_exec.serve");
    for key in ["workers", "f32_rps", "quant_rps", "quant_speedup"] {
        check_field(serve, key, recorded, "quant_exec.serve");
    }
}
