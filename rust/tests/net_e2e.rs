//! End-to-end tests of the networked serving layer over real loopback
//! sockets: socket inference must be bit-identical to in-process
//! `Client::classify` on the same model (f32 and quantized), the
//! micro-batcher must coalesce pipelined socket traffic into engine
//! batches, shutdown must drain in-flight socket requests, the
//! connection cap must shed with `Busy`, and garbage bytes must get a
//! strict error + close. The multi-tenant half: per-context socket
//! round-trips must match the in-process path bank for bank, invalid
//! context indices are shed with `BadRequest`, health advertises the
//! hosted context count, and drain covers in-flight groups spread
//! across contexts.

use std::sync::Arc;
use std::time::Duration;

use pds::coordinator::loadgen::{self, SocketLoadSpec};
use pds::coordinator::{InferenceService, ServerConfig};
use pds::net::{NetClient, NetClientError, NetServer, NetServerConfig, ReactorTuning};
use pds::util::rng::Rng;

fn dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

/// Service + TCP front-end over one `tiny` model.
fn start_pair(
    seed: u64,
    quant: bool,
    cfg: NetServerConfig,
) -> (Arc<InferenceService>, NetServer) {
    let mut spec = loadgen::model_spec(dir(), "tiny", 0.25, seed).unwrap();
    if quant {
        spec = spec.with_quant(pds::nn::fixed::QFormat::default());
    }
    let svc = Arc::new(
        InferenceService::start(
            dir(),
            vec![spec],
            ServerConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_depth: 64,
                tune_kernel_threads: false,
            },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
    (svc, server)
}

/// Tear down: network drain first, then the engine workers.
fn stop_pair(svc: Arc<InferenceService>, server: NetServer) {
    let returned = server.shutdown().unwrap();
    drop(returned);
    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown().unwrap(),
        Err(_) => panic!("service still referenced after network drain"),
    }
}

/// The acceptance property: the socket path is a transport, not a
/// different execution path — on the *same* running service, every
/// prediction through TCP equals the in-process one bit for bit.
fn assert_socket_matches_in_process(quant: bool, seed: u64) {
    let (svc, server) = start_pair(seed, quant, NetServerConfig::default());
    let local = svc.client("tiny").unwrap();
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    let health = net.health().unwrap();
    assert_eq!(health.models.len(), 1);
    assert_eq!(health.models[0].features as usize, local.features());
    assert_eq!(health.models[0].classes as usize, local.classes());
    assert!(!health.draining);
    let mut rng = Rng::new(seed ^ 0xE2E);
    for i in 0..48 {
        let x: Vec<f32> = (0..local.features())
            .map(|_| rng.uniform() * 2.0 - 1.0)
            .collect();
        let p_local = local.classify(x.clone()).unwrap();
        let p_net = net.classify("tiny", x).unwrap();
        assert_eq!(
            p_net.class, p_local.class,
            "sample {i}: socket and in-process classes diverge (quant={quant})"
        );
        assert!(p_net.class < local.classes());
    }
    stop_pair(svc, server);
}

#[test]
fn socket_inference_is_bit_identical_to_in_process_f32() {
    assert_socket_matches_in_process(false, 31);
}

#[test]
fn socket_inference_is_bit_identical_to_in_process_quantized() {
    assert_socket_matches_in_process(true, 32);
}

/// A pipelined group written in one burst must be coalesced by the
/// micro-batcher (one flush, not eight) and reach the engine as a
/// multi-row batch (mean occupancy > 1), with counters observable over
/// the wire.
#[test]
fn micro_batcher_coalesces_pipelined_socket_traffic() {
    let (svc, server) = start_pair(
        33,
        false,
        NetServerConfig {
            max_connections: 8,
            // wide window: the whole pipelined group lands inside it
            batch_window: Duration::from_millis(100),
            ..Default::default()
        },
    );
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    let features = svc.client("tiny").unwrap().features();
    let mut rng = Rng::new(34);
    let group: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..features).map(|_| rng.normal()).collect())
        .collect();
    let preds = net.classify_pipelined("tiny", &group).unwrap();
    assert_eq!(preds.len(), 8);
    let snap = net.metrics("tiny").unwrap();
    assert_eq!(snap.requests, 8, "engine must have served all 8");
    assert_eq!(snap.net_coalesced, 8);
    assert!(
        snap.net_flushes <= 2,
        "a burst inside one window must not flush per-request ({} flushes)",
        snap.net_flushes
    );
    assert!(
        snap.mean_coalesced() > 1.0,
        "mean coalesced batch size must exceed 1 (got {:.2})",
        snap.mean_coalesced()
    );
    assert!(
        snap.mean_occupancy > 1.0,
        "coalesced group must reach the engine as a multi-row batch \
         (mean occupancy {:.2})",
        snap.mean_occupancy
    );
    // the per-prediction occupancy agrees with the engine-side metric
    assert!(preds.iter().any(|p| p.batch_occupancy > 1));
    stop_pair(svc, server);
}

/// The socket load generator (closed loop, concurrent connections,
/// pipelined groups) must demonstrate coalescing end to end — this is
/// the same code path `benches/net_load.rs` records into
/// `BENCH_serve.json`.
#[test]
fn socket_load_generator_reports_coalescing() {
    let (svc, server) = start_pair(35, false, NetServerConfig::default());
    let models = vec!["tiny".to_string()];
    let spec = SocketLoadSpec {
        clients: 4,
        requests: 24,
        pipeline: 6,
        contexts: 1,
    };
    let reports = loadgen::run_socket_load(server.local_addr(), &models, &spec, 36).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.served, (spec.clients * spec.requests) as u64);
    assert!(r.throughput > 0.0);
    assert!(r.p50 <= r.p95 && r.p95 <= r.p99);
    assert!(
        r.mean_coalesced > 1.0,
        "concurrent pipelined clients must coalesce (mean {:.2})",
        r.mean_coalesced
    );
    stop_pair(svc, server);
}

/// Shutdown must drain in-flight socket requests: a pipelined group
/// parked in the batch window when the server shuts down still gets
/// every response.
#[test]
fn server_shutdown_drains_in_flight_socket_requests() {
    let (svc, server) = start_pair(
        37,
        false,
        NetServerConfig {
            max_connections: 8,
            // minutes-long window: only the shutdown drain can flush
            batch_window: Duration::from_secs(120),
            ..Default::default()
        },
    );
    let addr = server.local_addr();
    let features = svc.client("tiny").unwrap().features();
    let worker = std::thread::spawn(move || {
        let mut net = NetClient::connect(addr).unwrap();
        let group: Vec<Vec<f32>> = (0..4).map(|_| vec![0.25; features]).collect();
        net.classify_pipelined("tiny", &group)
    });
    // let the group land in the batcher's (never-expiring) window
    std::thread::sleep(Duration::from_millis(150));
    let t0 = std::time::Instant::now();
    stop_pair(svc, server);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drain must not wait out the batch window"
    );
    let preds = worker
        .join()
        .unwrap()
        .expect("in-flight socket requests must be answered, not dropped");
    assert_eq!(preds.len(), 4);
}

/// Beyond the connection cap, a new peer is shed with one explicit
/// `Busy` error frame instead of hanging or being silently dropped.
#[test]
fn connection_cap_sheds_with_busy() {
    let (svc, server) = start_pair(
        38,
        false,
        NetServerConfig {
            max_connections: 1,
            batch_window: Duration::ZERO,
            ..Default::default()
        },
    );
    let features = svc.client("tiny").unwrap().features();
    let mut first = NetClient::connect(server.local_addr()).unwrap();
    // a served request proves the first connection's handler is live
    // (and therefore counted) before the second peer arrives
    first.classify("tiny", vec![0.5; features]).unwrap();
    let mut second = NetClient::connect(server.local_addr()).unwrap();
    // a cap shed is a connection-level Busy (non-retryable Remote, the
    // server closes the socket right after), distinct from per-request
    // Busy backpressure
    match second.classify("tiny", vec![0.5; features]) {
        Err(NetClientError::Remote { code: pds::net::ErrorCode::Busy, .. }) => {}
        other => panic!("expected a Busy connection shed, got {other:?}"),
    }
    // the first connection must be unaffected
    first.classify("tiny", vec![-0.5; features]).unwrap();
    stop_pair(svc, server);
}

/// Garbage bytes get a strict `Error` frame and a close — the server
/// never tries to resynchronize a corrupted stream.
#[test]
fn garbage_bytes_get_error_frame_and_close() {
    use std::io::Write;
    let (svc, server) = start_pair(39, false, NetServerConfig::default());
    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();
    raw.write_all(b"definitely not a PD frame......").unwrap();
    raw.flush().unwrap();
    match pds::net::wire::read_frame(&mut raw).unwrap() {
        Some(pds::net::Frame::Error { id, code, .. }) => {
            assert_eq!(id, 0, "connection-level error");
            assert_eq!(code, pds::net::ErrorCode::BadRequest);
        }
        other => panic!("expected a BadRequest error frame, got {other:?}"),
    }
    // then EOF: the server closed the connection
    assert!(matches!(pds::net::wire::read_frame(&mut raw), Ok(None)));
    assert_eq!(
        server
            .metrics()
            .wire_errors
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    stop_pair(svc, server);
}

/// Service + TCP front-end over one `tiny` model hosting `contexts`
/// tenant banks.
fn start_multi_pair(
    seed: u64,
    contexts: usize,
    cfg: NetServerConfig,
) -> (Arc<InferenceService>, NetServer) {
    let spec = loadgen::model_spec(dir(), "tiny", 0.25, seed)
        .unwrap()
        .with_contexts(contexts);
    let svc = Arc::new(
        InferenceService::start(
            dir(),
            vec![spec],
            ServerConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_depth: 64,
                tune_kernel_threads: false,
            },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
    (svc, server)
}

/// Per-context socket round-trip: health advertises the hosted context
/// count, `classify_ctx` over TCP answers exactly like the in-process
/// client on the same bank, and a context index past the bank count is
/// refused with `BadRequest` — after which the connection still serves.
#[test]
fn socket_routing_matches_in_process_per_context() {
    let contexts = 3usize;
    let (svc, server) = start_multi_pair(41, contexts, NetServerConfig::default());
    let local = svc.client("tiny").unwrap();
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    let health = net.health().unwrap();
    assert_eq!(health.models.len(), 1);
    assert_eq!(
        health.models[0].contexts as usize, contexts,
        "health must advertise the hosted context count"
    );
    let mut rng = Rng::new(0x41_E2E);
    for i in 0..24 {
        let ctx = i % contexts;
        let x: Vec<f32> = (0..local.features())
            .map(|_| rng.uniform() * 2.0 - 1.0)
            .collect();
        let p_local = local.classify_ctx(x.clone(), ctx).unwrap();
        let p_net = net.classify_ctx("tiny", ctx as u32, x).unwrap();
        assert_eq!(
            p_net.class, p_local.class,
            "sample {i} (context {ctx}): socket diverged from in-process"
        );
    }
    // one past the last bank: typed rejection, not a silent remap
    match net.classify_ctx("tiny", contexts as u32, vec![0.0; local.features()]) {
        Err(NetClientError::Remote { code, message }) => {
            assert_eq!(code, pds::net::ErrorCode::BadRequest);
            assert!(
                message.contains("context"),
                "rejection must name the context: {message}"
            );
        }
        other => panic!("expected a BadRequest context rejection, got {other:?}"),
    }
    // the connection survives the rejection
    net.classify_ctx("tiny", 0, vec![0.0; local.features()]).unwrap();
    stop_pair(svc, server);
}

/// The socket load generator's context axis: with requests spread
/// round-robin over 4 tenants through one socket front-end, every
/// request is served and the report records the context spread — the
/// code path `benches/net_load.rs` records into `BENCH_serve.json`.
#[test]
fn socket_load_generator_spreads_across_contexts() {
    let (svc, server) = start_multi_pair(42, 4, NetServerConfig::default());
    let models = vec!["tiny".to_string()];
    let spec = SocketLoadSpec {
        clients: 4,
        requests: 24,
        pipeline: 6,
        contexts: 4,
    };
    let reports = loadgen::run_socket_load(server.local_addr(), &models, &spec, 43).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.served, (spec.clients * spec.requests) as u64);
    assert_eq!(r.contexts, 4, "report must record the context spread");
    assert!(r.p50 <= r.p95 && r.p95 <= r.p99);
    stop_pair(svc, server);
}

/// Drain with in-flight requests spread across contexts: two pipelined
/// groups parked in a never-expiring batch window, each targeting a
/// different tenant bank, must both be answered in full by the
/// shutdown drain.
#[test]
fn server_shutdown_drains_in_flight_across_contexts() {
    let (svc, server) = start_multi_pair(
        44,
        2,
        NetServerConfig {
            max_connections: 8,
            batch_window: Duration::from_secs(120),
            ..Default::default()
        },
    );
    let addr = server.local_addr();
    let features = svc.client("tiny").unwrap().features();
    let workers: Vec<_> = (0..2u32)
        .map(|ctx| {
            std::thread::spawn(move || {
                let mut net = NetClient::connect(addr).unwrap();
                let group: Vec<Vec<f32>> = (0..4).map(|_| vec![0.25; features]).collect();
                net.classify_pipelined_ctx("tiny", ctx, &group)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(150));
    let t0 = std::time::Instant::now();
    stop_pair(svc, server);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drain must not wait out the batch window"
    );
    for (ctx, w) in workers.into_iter().enumerate() {
        let preds = w
            .join()
            .unwrap()
            .unwrap_or_else(|e| panic!("context {ctx}: in-flight group dropped: {e}"));
        assert_eq!(preds.len(), 4);
    }
}

/// Gap-coverage over a real socket: quantized + multi-context +
/// pipelined (non-blocking on the wire) traffic, with and without
/// activation sparsity, must answer exactly like the in-process client
/// on the same bank — the transport must stay execution-neutral when
/// the worker runs the sparse-sparse kernels.
#[test]
fn socket_quant_multi_context_act_matches_in_process() {
    let contexts = 3usize;
    let fmt = pds::nn::fixed::QFormat::default();
    let act = pds::nn::actsparse::ActSpec::top_k(4);
    for (quant, aspec) in [
        (None, Some(act)),
        (Some(fmt), None),
        (Some(fmt), Some(act)),
    ] {
        let spec = loadgen::model_spec(dir(), "tiny", 0.25, 45)
            .unwrap()
            .with_contexts(contexts);
        let spec = match quant {
            Some(f) => spec.with_quant(f),
            None => spec,
        };
        let spec = match aspec {
            Some(a) => spec.with_act(a),
            None => spec,
        };
        let svc = Arc::new(
            InferenceService::start(
                dir(),
                vec![spec],
                ServerConfig {
                    max_wait: Duration::from_millis(1),
                    workers: 1,
                    queue_depth: 64,
                    tune_kernel_threads: false,
                },
            )
            .unwrap(),
        );
        let server =
            NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetServerConfig::default())
                .unwrap();
        let local = svc.client("tiny").unwrap();
        let mut net = NetClient::connect(server.local_addr()).unwrap();
        let mut rng = Rng::new(0xAC7_E2E);
        for ctx in 0..contexts {
            let group: Vec<Vec<f32>> = (0..6)
                .map(|_| {
                    (0..local.features())
                        .map(|_| rng.uniform() * 2.0 - 1.0)
                        .collect()
                })
                .collect();
            let preds = net
                .classify_pipelined_ctx("tiny", ctx as u32, &group)
                .unwrap();
            for (x, p) in group.iter().zip(&preds) {
                let p_local = local.classify_ctx(x.clone(), ctx).unwrap();
                assert_eq!(
                    p.class, p_local.class,
                    "context {ctx} (quant {quant:?}, act {aspec:?}): socket diverged \
                     from in-process"
                );
            }
        }
        if aspec.is_some() {
            let density = svc.metrics("tiny").unwrap().act_density();
            assert!(
                density > 0.0 && density < 1.0,
                "socket-served requests must feed the density gauge (got {density})"
            );
        }
        stop_pair(svc, server);
    }
}

/// Slow-loris: a peer that starts a frame and then stalls must be cut
/// off at the configured frame timeout with a `BadRequest` error frame
/// and a close — while an unrelated connection on the same reactor
/// keeps serving before, during, and after the cutoff.
#[test]
fn partial_frame_times_out_without_stalling_other_connections() {
    use std::io::Write;
    let mut spec = loadgen::model_spec(dir(), "tiny", 0.25, 46).unwrap();
    spec = spec.with_contexts(1);
    let svc = Arc::new(
        InferenceService::start(
            dir(),
            vec![spec],
            ServerConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_depth: 64,
                tune_kernel_threads: false,
            },
        )
        .unwrap(),
    );
    let server = NetServer::start_tuned(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig::default(),
        ReactorTuning {
            frame_timeout: Duration::from_millis(200),
            ..ReactorTuning::default()
        },
    )
    .unwrap();
    let features = svc.client("tiny").unwrap().features();
    let mut healthy = NetClient::connect(server.local_addr()).unwrap();
    healthy.classify("tiny", vec![0.1; features]).unwrap();
    // dribble the first bytes of a valid Request frame, then stall
    let full = pds::net::Frame::Request {
        id: 7,
        model: "tiny".into(),
        context: 0,
        features: vec![0.5; features],
        trace: None,
    }
    .encode();
    let mut loris = std::net::TcpStream::connect(server.local_addr()).unwrap();
    loris.write_all(&full[..6]).unwrap();
    loris.flush().unwrap();
    // the healthy connection must keep serving while the stalled frame
    // ages toward its deadline
    healthy.classify("tiny", vec![-0.1; features]).unwrap();
    // the stalled peer gets a typed connection-level error, then EOF
    // (read_frame blocks, so this also bounds the cutoff to ~200ms)
    let t0 = std::time::Instant::now();
    match pds::net::wire::read_frame(&mut loris).unwrap() {
        Some(pds::net::Frame::Error { id, code, message }) => {
            assert_eq!(id, 0, "connection-level error");
            assert_eq!(code, pds::net::ErrorCode::BadRequest);
            assert!(
                message.contains("truncated"),
                "error must name the truncation: {message}"
            );
        }
        other => panic!("expected a BadRequest timeout frame, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "frame timeout must fire near its 200ms deadline"
    );
    assert!(matches!(pds::net::wire::read_frame(&mut loris), Ok(None)));
    assert_eq!(
        server
            .metrics()
            .wire_errors
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    // the reactor is unharmed: the healthy connection still serves
    healthy.classify("tiny", vec![0.3; features]).unwrap();
    stop_pair(svc, server);
}

/// Scale-out smoke at test size: one reactor thread multiplexes
/// hundreds of mostly-idle connections; a sampled subset classifies
/// correctly, the peak gauge records the population, and the drain is
/// clean with every connection still open.
#[test]
fn one_reactor_thread_serves_hundreds_of_idle_connections() {
    const IDLE: usize = 256;
    let (svc, server) = start_pair(47, false, NetServerConfig::default());
    let features = svc.client("tiny").unwrap().features();
    let mut conns: Vec<NetClient> = (0..IDLE)
        .map(|_| NetClient::connect(server.local_addr()).unwrap())
        .collect();
    // every 16th connection does real work; the rest just sit there
    for (i, c) in conns.iter_mut().enumerate().step_by(16) {
        let p = c.classify("tiny", vec![0.01 * i as f32; features]).unwrap();
        assert!(p.class < 8);
    }
    let m = server.metrics();
    assert!(
        m.peak_active.load(std::sync::atomic::Ordering::Relaxed) >= IDLE,
        "peak gauge must record the idle population"
    );
    assert_eq!(
        m.rejected_connections.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "default cap must admit all {IDLE} connections"
    );
    drop(conns);
    stop_pair(svc, server);
}

/// One connection's failure must not take down the server: a responder
/// that panics (injected straight into the model's batcher, as a
/// broken connection's delivery callback would) is absorbed and
/// counted, and socket clients keep being served.
#[test]
fn panicking_responder_does_not_take_down_the_server() {
    let (svc, server) = start_pair(48, false, NetServerConfig::default());
    let features = svc.client("tiny").unwrap().features();
    let handle = server.batcher("tiny").unwrap();
    handle.enqueue(pds::net::BatchItem {
        features: vec![0.2; features],
        context: 0,
        respond: Box::new(|_| panic!("injected responder failure")),
        trace: None,
    });
    // wait for the panic to be absorbed and counted
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let n = handle
            .metrics()
            .responder_panics
            .load(std::sync::atomic::Ordering::Relaxed);
        if n == 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "responder panic never surfaced in the metrics"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // the batcher and reactor both survived: fresh socket traffic serves
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    for _ in 0..4 {
        let p = net.classify("tiny", vec![0.4; features]).unwrap();
        assert!(p.class < 8);
    }
    stop_pair(svc, server);
}

/// Trace propagation end to end: with `--trace-sample 1` every request
/// is traced at the net front door, carried through the micro-batcher
/// and the engine shard, and closed on the worker — the client gets the
/// queue/batch/execute echo and the sink holds the full span tree
/// (net -> batcher -> engine.wait -> engine.exec) under one trace ID.
#[test]
fn sampled_request_produces_span_tree_and_echo() {
    let (svc, server) = start_pair(
        49,
        false,
        NetServerConfig {
            trace_sample: 1,
            ..Default::default()
        },
    );
    let features = svc.client("tiny").unwrap().features();
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    let p = net.classify("tiny", vec![0.5; features]).unwrap();
    let echo = p
        .trace
        .expect("every request is sampled at --trace-sample 1");
    // the reactor records the enclosing net span when the response
    // leaves, so it is in the sink before the client sees the reply
    let events = server.trace_sink().events();
    let ours: Vec<_> = events
        .iter()
        .filter(|e| e.trace_id == echo.trace_id)
        .collect();
    let mut names: Vec<&str> = ours.iter().map(|e| e.name).collect();
    names.sort_unstable();
    assert_eq!(
        names,
        vec!["batcher", "engine.exec", "engine.wait", "net"],
        "one span per stage under trace {}",
        echo.trace_id
    );
    let span = |n: &str| *ours.iter().find(|e| e.name == n).unwrap();
    let (netspan, batcher) = (span("net"), span("batcher"));
    let (wait, exec) = (span("engine.wait"), span("engine.exec"));
    // the tree nests: net opens first and closes last; the inner spans
    // run in pipeline order on their expected lanes
    assert!(netspan.start_us <= batcher.start_us);
    assert!(batcher.start_us <= wait.start_us && wait.start_us <= exec.start_us);
    assert!(
        exec.start_us + exec.dur_us <= netspan.start_us + netspan.dur_us,
        "engine.exec must close before the net span does"
    );
    assert_eq!(netspan.tid, 0, "net span rides the reactor lane");
    assert_eq!(exec.tid as usize, 1 + p.worker, "exec span rides the worker lane");
    // the echo agrees with the recorded spans
    assert_eq!(u64::from(echo.queue_us), batcher.dur_us);
    assert_eq!(u64::from(echo.execute_us), exec.dur_us);
    assert!(server.trace_sink().handles_created() >= 1);
    // the export is loadable Chrome trace_event JSON
    let doc = server.trace_sink().to_chrome_json();
    let parsed = pds::util::json::Json::parse(&doc.to_string()).unwrap();
    assert!(
        parsed.get("traceEvents").unwrap().as_arr().unwrap().len() >= 4,
        "chrome export must carry the span tree"
    );
    stop_pair(svc, server);
}

/// The unsampled path allocates nothing: with sampling off (the
/// default), a batch of requests leaves the trace sink empty and the
/// handle counter at zero — while a client-minted trace ID on the same
/// server still wins and produces a full trace.
#[test]
fn unsampled_requests_allocate_no_trace_handles() {
    let (svc, server) = start_pair(50, false, NetServerConfig::default());
    let features = svc.client("tiny").unwrap().features();
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    for i in 0..16 {
        let p = net.classify("tiny", vec![0.1 * i as f32; features]).unwrap();
        assert!(p.trace.is_none(), "unsampled requests must not echo a trace");
    }
    assert_eq!(
        server.trace_sink().handles_created(),
        0,
        "unsampled requests must never allocate a trace handle"
    );
    assert!(server.trace_sink().is_empty());
    // a client-supplied trace ID overrides the disabled sampler
    let p = net
        .classify_traced("tiny", 0, vec![0.5; features], 0xBEEF)
        .unwrap();
    let echo = p.trace.expect("client-minted trace must be honored");
    assert_eq!(echo.trace_id, 0xBEEF);
    assert_eq!(server.trace_sink().handles_created(), 1);
    assert_eq!(
        server
            .trace_sink()
            .events()
            .iter()
            .filter(|e| e.trace_id == 0xBEEF)
            .count(),
        4,
        "client-minted trace must record the full span tree"
    );
    stop_pair(svc, server);
}

/// A request for an unserved model errors by name; the connection
/// stays usable.
#[test]
fn unknown_model_is_rejected_by_name() {
    let (svc, server) = start_pair(40, false, NetServerConfig::default());
    let features = svc.client("tiny").unwrap().features();
    let mut net = NetClient::connect(server.local_addr()).unwrap();
    match net.classify("nope", vec![0.0; 4]) {
        Err(NetClientError::Remote { code, .. }) => {
            assert_eq!(code, pds::net::ErrorCode::UnknownModel);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // wrong feature dimension on a real model: BadRequest
    match net.classify("tiny", vec![0.0; features + 1]) {
        Err(NetClientError::Remote { code, .. }) => {
            assert_eq!(code, pds::net::ErrorCode::BadRequest);
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // and the connection still serves valid requests afterwards
    net.classify("tiny", vec![0.0; features]).unwrap();
    stop_pair(svc, server);
}
