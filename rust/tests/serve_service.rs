//! Integration tests for the multi-worker sharded inference service:
//! cross-model stress, bounded-queue backpressure totality, metrics
//! sanity (occupancy histogram vs request counters, latency quantiles),
//! and the multi-tenant context battery: many-contexts-per-worker
//! routing parity against single-tenant twin services, `Busy` shed and
//! drain with in-flight requests spread across contexts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::coordinator::{
    context_params, InferenceService, ModelSpec, ServeError, ServerConfig,
};
use pds::util::rng::Rng;

fn dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

/// Many concurrent clients across two models at once: every prediction
/// must come back with a sane class, nothing may be lost, and with the
/// queue bound above the in-flight client count nothing may be rejected.
#[test]
fn stress_two_models_many_clients() {
    let models = ["tiny", "timit"];
    let specs = models
        .iter()
        .map(|m| loadgen::model_spec(dir(), m, 0.25, 1).unwrap())
        .collect();
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 2,
            queue_depth: 64,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let clients = 4usize;
    let per_client = 30usize;
    std::thread::scope(|s| {
        for (mi, model) in models.iter().enumerate() {
            for c in 0..clients {
                let client = svc.client(model).unwrap();
                s.spawn(move || {
                    let mut rng = Rng::new((mi * 100 + c) as u64);
                    for _ in 0..per_client {
                        let x: Vec<f32> =
                            (0..client.features()).map(|_| rng.normal()).collect();
                        let pred = client.classify(x).unwrap();
                        assert!(pred.class < client.classes(), "class out of range");
                        assert!(pred.batch_occupancy >= 1);
                        assert!(pred.worker < 2);
                    }
                });
            }
        }
    });
    for model in models {
        let m = svc.metrics(model).unwrap();
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            (clients * per_client) as u64,
            "{model}: every request must be served exactly once"
        );
        // closed-loop in-flight (4) never exceeds one shard's bound (64)
        assert_eq!(m.rejected.load(Ordering::Relaxed), 0, "{model}");
    }
    svc.shutdown().unwrap();
}

/// Bounded queues shed load instead of blocking forever: with a depth-1
/// queue and a flood of clients, every `classify` call must return
/// (joining the scope proves totality), and the client-observed
/// outcomes must match the service's own counters exactly.
#[test]
fn bounded_queue_rejects_instead_of_blocking() {
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 2).unwrap()];
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 1,
            queue_depth: 1,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let served = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..16u64 {
            let client = svc.client("tiny").unwrap();
            let served = &served;
            let rejected = &rejected;
            s.spawn(move || {
                let mut rng = Rng::new(c);
                for _ in 0..10 {
                    let x: Vec<f32> = (0..client.features()).map(|_| rng.normal()).collect();
                    match client.classify(x) {
                        Ok(p) => {
                            assert!(p.class < client.classes());
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::Busy) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
            });
        }
    });
    let m = svc.metrics("tiny").unwrap();
    assert_eq!(
        served.load(Ordering::Relaxed) + rejected.load(Ordering::Relaxed),
        160,
        "every submission must resolve to served or rejected"
    );
    assert_eq!(m.requests.load(Ordering::Relaxed), served.load(Ordering::Relaxed));
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
    // the service must still be healthy after shedding load
    let client = svc.client("tiny").unwrap();
    loop {
        let x = vec![0.5f32; client.features()];
        match client.classify(x) {
            Ok(p) => {
                assert!(p.class < client.classes());
                break;
            }
            Err(ServeError::Busy) => std::thread::yield_now(),
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    svc.shutdown().unwrap();
}

/// The metrics registry must be self-consistent: the occupancy histogram
/// weighted by occupancy sums to the request count, its plain sum is the
/// batch count, the latency histogram saw every request, and quantiles
/// are monotone.
#[test]
fn metrics_occupancy_and_latency_are_consistent() {
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 4).unwrap()];
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 2,
            queue_depth: 64,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let models = vec!["tiny".to_string()];
    let load = LoadSpec {
        clients: 6,
        requests: 25,
        think_time: Duration::ZERO,
        burst: 1,
        contexts: 1,
    };
    let reports = loadgen::run_load(&svc, &models, &load, 9).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.served, (load.clients * load.requests) as u64);
    assert!(r.throughput > 0.0);
    assert!(r.p50 <= r.p95 && r.p95 <= r.p99);

    let m = svc.metrics("tiny").unwrap();
    let hist = m.occupancy_histogram();
    let weighted: u64 = hist.iter().enumerate().map(|(k, &c)| (k as u64 + 1) * c).sum();
    let flat: u64 = hist.iter().sum();
    assert_eq!(weighted, m.requests.load(Ordering::Relaxed), "occupancy-weighted sum");
    assert_eq!(flat, m.batches.load(Ordering::Relaxed), "histogram counts batches");
    assert_eq!(m.latency.count(), m.requests.load(Ordering::Relaxed));
    assert_eq!(r.batches, flat);
    // the report is a faithful snapshot of the registry
    assert_eq!(r.stolen, m.stolen.load(Ordering::Relaxed));
    svc.shutdown().unwrap();
}

/// Shutdown must *drain* in-flight requests, not drop them: every
/// request accepted before `shutdown()` is signalled gets a real
/// prediction, never a `Stopped` error and never a hang.
///
/// The setup parks requests in flight at shutdown time: one worker with
/// a long batch-fill wait (500 ms) collects the first request and then
/// holds its partial batch open, while the remaining requests sit
/// queued in the shard. `shutdown()` arrives mid-wait (after a short
/// sleep that lets every submission land), which must cut the batch
/// wait short, execute what is pending, drain the rest of the queue,
/// and only then let the worker exit.
#[test]
fn shutdown_drains_in_flight_requests() {
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 8).unwrap()];
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            // far longer than the test's shutdown delay: only the stop
            // signal can flush the partial batch
            max_wait: Duration::from_millis(500),
            workers: 1,
            queue_depth: 64,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let n = 8usize;
    let submitted = std::sync::Barrier::new(n + 1);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|c| {
                let client = svc.client("tiny").unwrap();
                let submitted = &submitted;
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    let x: Vec<f32> =
                        (0..client.features()).map(|_| rng.normal()).collect();
                    // non-blocking submit, then rendezvous so the main
                    // thread knows every request is accepted in-flight
                    // before it shuts down
                    let pending = client.submit(x).expect("queue far below capacity");
                    submitted.wait();
                    pending.wait()
                })
            })
            .collect();
        submitted.wait();
        // all n requests are now in flight (first one holds the worker's
        // partial batch open for its 500 ms fill wait); shut down early
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        svc.shutdown().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(450),
            "shutdown must cut the batch wait short, not sit it out"
        );
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, r) in results.iter().enumerate() {
        let pred = r.as_ref().unwrap_or_else(|e| {
            panic!("in-flight request {i} was dropped on shutdown: {e}")
        });
        assert!(pred.class < 8);
    }
}

/// Quantized serving: a model with a Qm.n format set serves through the
/// fixed-point kernels. Predictions must agree with an f32-served twin
/// of the *same* model (same pattern seed, same parameter init) on
/// bounded inputs — quantization error is far below the class-decision
/// margins at Q5.10 — and the saturation metric must stay zero.
#[test]
fn quantized_model_serves_and_matches_f32_twin() {
    let fmt = pds::nn::fixed::QFormat::default();
    let spec_f32 = loadgen::model_spec(dir(), "tiny", 0.25, 5).unwrap();
    let spec_q = loadgen::model_spec(dir(), "tiny", 0.25, 5).unwrap().with_quant(fmt);
    // two services so both specs can share the config name
    let svc_f = InferenceService::start(dir(), vec![spec_f32], ServerConfig::default()).unwrap();
    let svc_q = InferenceService::start(dir(), vec![spec_q], ServerConfig::default()).unwrap();
    let cf = svc_f.client("tiny").unwrap();
    let cq = svc_q.client("tiny").unwrap();
    let mut rng = Rng::new(6);
    let mut agree = 0usize;
    let n = 40usize;
    for _ in 0..n {
        let x: Vec<f32> = (0..cf.features()).map(|_| rng.uniform() * 2.0 - 1.0).collect();
        let pf = cf.classify(x.clone()).unwrap();
        let pq = cq.classify(x).unwrap();
        assert!(pq.class < cq.classes());
        if pf.class == pq.class {
            agree += 1;
        }
    }
    // identical models, milli-scale logit differences: argmax may flip
    // only on near-ties, which bounded random inputs make rare
    assert!(agree >= n - 4, "only {agree}/{n} predictions agree");
    let mq = svc_q.metrics("tiny").unwrap();
    assert_eq!(
        mq.quant_saturations.load(Ordering::Relaxed),
        0,
        "Q5.10 must have headroom for the tiny config"
    );
    assert_eq!(mq.requests.load(Ordering::Relaxed), n as u64);
    svc_f.shutdown().unwrap();
    svc_q.shutdown().unwrap();
}

/// Many-contexts-per-worker routing parity: a service hosting C tenant
/// contexts of one model must answer `classify_ctx(x, c)` exactly like
/// a dedicated single-tenant service built from context `c`'s parameter
/// bank. Each twin is constructed out-of-band with
/// `coordinator::context_params` — the same derivation the service uses
/// internally — so agreement proves the worker fetched the right bank,
/// and a cross-context disagreement proves the banks are distinct
/// (routing is not collapsing tenants onto one set of weights).
#[test]
fn multi_context_routing_matches_single_tenant_twins() {
    let contexts = 3usize;
    let spec = loadgen::model_spec(dir(), "tiny", 0.25, 5)
        .unwrap()
        .with_contexts(contexts);
    let pattern = spec.pattern.clone();
    let layers = pds::runtime::Manifest::probe(dir(), "tiny").unwrap().layers;
    let svc = InferenceService::start(dir(), vec![spec.clone()], ServerConfig::default()).unwrap();
    let client = svc.client("tiny").unwrap();
    assert_eq!(client.contexts(), contexts);

    // one shared probe set for every context, so per-context class
    // vectors are directly comparable
    let mut rng = Rng::new(0xC0_07E7);
    let probes: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..client.features()).map(|_| rng.normal()).collect())
        .collect();

    let mut classes_by_ctx: Vec<Vec<usize>> = Vec::new();
    for ctx in 0..contexts {
        let twin_spec = ModelSpec {
            params: Some(context_params(&layers, &pattern, None, ctx)),
            contexts: 1,
            ..spec.clone()
        };
        let twin =
            InferenceService::start(dir(), vec![twin_spec], ServerConfig::default()).unwrap();
        let tc = twin.client("tiny").unwrap();
        let mut classes = Vec::new();
        for x in &probes {
            let pm = client.classify_ctx(x.clone(), ctx).unwrap();
            let pt = tc.classify(x.clone()).unwrap();
            assert_eq!(
                pm.class, pt.class,
                "context {ctx}: multi-tenant answer diverged from its single-tenant twin"
            );
            assert_eq!(pm.context, ctx, "prediction must carry its own context");
            classes.push(pm.class);
        }
        twin.shutdown().unwrap();
        classes_by_ctx.push(classes);
    }
    assert!(
        classes_by_ctx.windows(2).any(|w| w[0] != w[1]),
        "independent per-context banks must not classify identically on every probe"
    );
    svc.shutdown().unwrap();
}

/// Bounded-queue shed with the load spread across tenant contexts:
/// every `classify_ctx` call must resolve to served-or-rejected (no
/// hang, no cross-context interference), every served prediction must
/// come back tagged with the context it was submitted under, and the
/// service counters must match the client-observed outcomes exactly.
#[test]
fn busy_shed_spreads_across_contexts() {
    let contexts = 4usize;
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 2)
        .unwrap()
        .with_contexts(contexts)];
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 1,
            queue_depth: 1,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let served = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..16u64 {
            let client = svc.client("tiny").unwrap();
            let served = &served;
            let rejected = &rejected;
            let ctx = (c as usize) % contexts;
            s.spawn(move || {
                let mut rng = Rng::new(c);
                for _ in 0..10 {
                    let x: Vec<f32> = (0..client.features()).map(|_| rng.normal()).collect();
                    match client.classify_ctx(x, ctx) {
                        Ok(p) => {
                            assert!(p.class < client.classes());
                            assert_eq!(p.context, ctx, "prediction routed to the wrong tenant");
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::Busy) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
            });
        }
    });
    let m = svc.metrics("tiny").unwrap();
    assert_eq!(
        served.load(Ordering::Relaxed) + rejected.load(Ordering::Relaxed),
        160,
        "every submission must resolve to served or rejected"
    );
    assert_eq!(m.requests.load(Ordering::Relaxed), served.load(Ordering::Relaxed));
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
    svc.shutdown().unwrap();
}

/// Shutdown drains in-flight requests that are spread across tenant
/// contexts: same parked-batch setup as
/// [`shutdown_drains_in_flight_requests`], but each request targets a
/// different context, so the final flush must group one partial batch
/// per context and still complete every prediction with its own
/// context tag.
#[test]
fn shutdown_drains_in_flight_across_contexts() {
    let contexts = 4usize;
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 8)
        .unwrap()
        .with_contexts(contexts)];
    let svc = InferenceService::start(
        dir(),
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(500),
            workers: 1,
            queue_depth: 64,
            tune_kernel_threads: false,
        },
    )
    .unwrap();
    let n = 8usize;
    let submitted = std::sync::Barrier::new(n + 1);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|c| {
                let client = svc.client("tiny").unwrap();
                let submitted = &submitted;
                let ctx = c % contexts;
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    let x: Vec<f32> =
                        (0..client.features()).map(|_| rng.normal()).collect();
                    let pending =
                        client.submit_ctx(x, ctx).expect("queue far below capacity");
                    submitted.wait();
                    (ctx, pending.wait())
                })
            })
            .collect();
        submitted.wait();
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        svc.shutdown().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(450),
            "shutdown must cut the batch wait short, not sit it out"
        );
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, (ctx, r)) in results.iter().enumerate() {
        let pred = r.as_ref().unwrap_or_else(|e| {
            panic!("in-flight request {i} (context {ctx}) was dropped on shutdown: {e}")
        });
        assert!(pred.class < 8);
        assert_eq!(pred.context, *ctx, "drained prediction lost its context");
    }
}

/// Gap-coverage battery: quantized execution + multi-tenant contexts +
/// non-blocking `Client::submit_ctx` routing, with and without
/// activation sparsity, against dedicated single-tenant twins.
///
/// Every prediction pipelined through the shared multi-context service
/// must match the twin built from that context's own parameter bank
/// (both sides run the identical kernel on the identical bank, so the
/// classes must agree on every probe — not just statistically). With an
/// ActSpec the achieved-density gauge must drop below 1.0; without one
/// it must stay at its all-dense default.
fn submit_parity_battery(
    quant: Option<pds::nn::fixed::QFormat>,
    act: Option<pds::nn::actsparse::ActSpec>,
) {
    let contexts = 3usize;
    let spec = loadgen::model_spec(dir(), "tiny", 0.25, 5)
        .unwrap()
        .with_contexts(contexts);
    let spec = match quant {
        Some(fmt) => spec.with_quant(fmt),
        None => spec,
    };
    let spec = match act {
        Some(a) => spec.with_act(a),
        None => spec,
    };
    let pattern = spec.pattern.clone();
    let layers = pds::runtime::Manifest::probe(dir(), "tiny").unwrap().layers;
    let svc = InferenceService::start(dir(), vec![spec.clone()], ServerConfig::default()).unwrap();
    let client = svc.client("tiny").unwrap();

    let mut rng = Rng::new(0xAC7);
    let probes: Vec<Vec<f32>> = (0..12)
        .map(|_| (0..client.features()).map(|_| rng.uniform() * 2.0 - 1.0).collect())
        .collect();

    for ctx in 0..contexts {
        let twin_spec = ModelSpec {
            params: Some(context_params(&layers, &pattern, None, ctx)),
            contexts: 1,
            ..spec.clone()
        };
        let twin =
            InferenceService::start(dir(), vec![twin_spec], ServerConfig::default()).unwrap();
        let tc = twin.client("tiny").unwrap();
        // non-blocking: pipeline every probe into the shared service
        // before collecting a single result
        let pending: Vec<_> = probes
            .iter()
            .map(|x| client.submit_ctx(x.clone(), ctx).expect("queue below capacity"))
            .collect();
        for (x, p) in probes.iter().zip(pending) {
            let pm = p.wait().unwrap();
            let pt = tc.classify(x.clone()).unwrap();
            assert_eq!(
                pm.class, pt.class,
                "context {ctx} (quant {quant:?}, act {act:?}): shared-service answer \
                 diverged from its single-tenant twin"
            );
            assert_eq!(pm.context, ctx, "prediction must carry its own context");
        }
        twin.shutdown().unwrap();
    }
    let m = svc.metrics("tiny").unwrap();
    let density = m.act_density();
    match act {
        Some(_) => assert!(
            density > 0.0 && density < 1.0,
            "activation sparsity must surface in the density gauge (got {density})"
        ),
        None => assert_eq!(density, 1.0, "no mask, no recorded sparsity"),
    }
    svc.shutdown().unwrap();
}

#[test]
fn f32_multi_context_submit_matches_twins_with_and_without_act() {
    submit_parity_battery(None, None);
    submit_parity_battery(None, Some(pds::nn::actsparse::ActSpec::top_k(4)));
}

#[test]
fn quantized_multi_context_submit_matches_twins_with_and_without_act() {
    let fmt = pds::nn::fixed::QFormat::default();
    submit_parity_battery(Some(fmt), None);
    submit_parity_battery(Some(fmt), Some(pds::nn::actsparse::ActSpec::top_k(4)));
}

/// A context index past the hosted bank count is a caller bug, refused
/// loudly at the submission boundary rather than silently wrapped onto
/// another tenant's bank.
#[test]
#[should_panic(expected = "out of range")]
fn out_of_range_context_is_refused() {
    let specs = vec![loadgen::model_spec(dir(), "tiny", 0.25, 3)
        .unwrap()
        .with_contexts(2)];
    let svc = InferenceService::start(dir(), specs, ServerConfig::default()).unwrap();
    let client = svc.client("tiny").unwrap();
    let x = vec![0.0f32; client.features()];
    let _ = client.classify_ctx(x, 2);
}
