//! Cross-module integration: the clash-free pattern → hardware simulator
//! → native trainer chain must be numerically consistent end to end, and
//! the hardware's SGD must train a junction exactly like host SGD.

use pds::hw::junction::{Act, JunctionUnit};
use pds::nn::sparse::SparseNet;
use pds::sparsity::clash_free::{schedule, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};
use pds::sparsity::pattern::NetPattern;
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

/// Build a 1-junction "network" on the hardware simulator and train it
/// with plain SGD against a host implementation on the same pattern —
/// weights must track exactly (the FF/BP/UP datapath is bit-faithful).
#[test]
fn hw_junction_sgd_tracks_host_sgd() {
    let shape = JunctionShape { n_left: 24, n_right: 12 };
    let (d_out, z) = (4, 8);
    let d_in = shape.n_left * d_out / shape.n_right;
    let mut rng = Rng::new(5);
    let sched = schedule(24, z, d_out, Flavor::Type1 { dither: false }, &mut rng);
    let mut unit = JunctionUnit::new(shape, d_in, sched, JunctionUnit::required_z_next(shape.n_right * d_in, z, d_in));
    let dense0: Vec<f32> = (0..12 * 24).map(|_| rng.normal() * 0.3).collect();
    unit.load_weights_dense(&dense0);
    let pattern = unit.pattern();
    let mask = pattern.mask();

    // host-side copy
    let mut w_host: Vec<f32> = dense0
        .iter()
        .zip(&mask)
        .map(|(w, m)| w * m)
        .collect();
    let mut b_hw = vec![0.1f32; 12];
    let mut b_host = vec![0.1f32; 12];
    let lr = 0.02;

    for step in 0..10 {
        let a: Vec<f32> = (0..24).map(|_| rng.normal()).collect();
        let target: Vec<f32> = (0..12).map(|_| rng.normal()).collect();

        // hardware FF
        let ff = unit.feedforward(&a, &b_hw, Act::Linear).unwrap();
        // delta = h - target (squared loss at the junction output)
        let delta: Vec<f32> = ff.h.iter().zip(&target).map(|(h, t)| h - t).collect();
        unit.update(&a, &delta, &mut b_hw, lr).unwrap();

        // host FF + SGD
        let mut h_host = vec![0f32; 12];
        for j in 0..12 {
            h_host[j] = b_host[j]
                + (0..24).map(|k| w_host[j * 24 + k] * a[k]).sum::<f32>();
        }
        for j in 0..12 {
            let d = h_host[j] - target[j];
            b_host[j] -= lr * d;
            for k in 0..24 {
                w_host[j * 24 + k] -= lr * d * a[k] * mask[j * 24 + k];
            }
        }
        // compare
        let w_hw = unit.dump_weights_dense();
        for idx in 0..w_hw.len() {
            assert!(
                (w_hw[idx] - w_host[idx]).abs() < 1e-3 * (1.0 + w_host[idx].abs()),
                "step {step} w[{idx}]: hw {} host {}",
                w_hw[idx],
                w_host[idx]
            );
        }
        for j in 0..12 {
            assert!((b_hw[j] - b_host[j]).abs() < 1e-4, "step {step} bias {j}");
        }
    }
}

/// The hardware simulator FF agrees with the CSR sparse net FF on the
/// identical pattern + weights (two independent implementations of the
/// same edge-based math).
#[test]
fn hw_ff_matches_sparse_net_logits() {
    let netc = NetConfig::new(vec![24, 12, 6]);
    let dout = DoutConfig(vec![4, 2]);
    let mut rng = Rng::new(8);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);

    let x: Vec<f32> = (0..24).map(|_| rng.normal()).collect();
    let want = snet.logits(&x, 1);

    // run the same two junctions on hardware units
    let mut a = x.clone();
    for (i, p) in pattern.junctions.iter().enumerate() {
        let shape = p.shape;
        let d_in = p.in_edges[0].len();
        let z = shape.n_left / 2;
        // rebuild a clash-free schedule that *realizes this exact pattern*:
        // use the stored pattern's compact indices as an explicit schedule
        let (idx, din2) = p.compact_indices().unwrap();
        assert_eq!(din2, d_in);
        let n_edges = p.n_edges();
        let cycles = n_edges / z;
        let mut sched_cycles = Vec::with_capacity(cycles);
        let mut ok = true;
        for t in 0..cycles {
            let mut lanes = Vec::with_capacity(z);
            let mut used = vec![false; z];
            for m in 0..z {
                let e = t * z + m;
                let neuron = idx[e] as usize;
                let (mem, addr) = (neuron % z, neuron / z);
                if used[mem] {
                    ok = false; // this pattern isn't clash-free at this z
                }
                used[mem] = true;
                lanes.push((mem, addr));
            }
            sched_cycles.push(lanes);
        }
        if !ok {
            // clash-free generate() guarantees clash-freedom at *its* z;
            // the replay z may differ. Fall back: verify via pattern match.
            let (w, _m) = snet.junctions[i].to_dense();
            let mut h = vec![0f32; shape.n_right];
            for j in 0..shape.n_right {
                h[j] = snet.junctions[i].bias[j]
                    + (0..shape.n_left)
                        .map(|k| w[j * shape.n_left + k] * a[k])
                        .sum::<f32>();
            }
            a = h.iter().map(|v| if i == 0 { v.max(0.0) } else { *v }).collect();
            continue;
        }
        let sched = pds::sparsity::clash_free::AccessSchedule {
            z,
            depth: shape.n_left / z,
            cycles: sched_cycles,
        };
        sched.verify_clash_free().unwrap();
        let mut unit = JunctionUnit::new(shape, d_in, sched, JunctionUnit::required_z_next(shape.n_right * d_in, z, d_in));
        let (w_dense, _) = snet.junctions[i].to_dense();
        unit.load_weights_dense(&w_dense);
        let act = if i == 0 { Act::Relu } else { Act::Linear };
        let out = unit.feedforward(&a, &snet.junctions[i].bias, act).unwrap();
        a = out.a;
    }
    for (g, w) in a.iter().zip(&want) {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

/// Pattern generated with an explicit z_net replays clash-free on units
/// built with that z_net, junction by junction, with balanced cycles.
#[test]
fn znet_pattern_unit_consistency() {
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    let znet = vec![160usize, 10];
    let zcfg = pds::hw::zconfig::validate(&netc, &dout, &znet).unwrap();
    assert!(zcfg.balanced);
    let din = netc.din(&dout);
    let mut rng = Rng::new(10);
    for i in 0..2 {
        let shape = netc.junction(i);
        let sched = schedule(
            shape.n_left,
            znet[i],
            dout.0[i],
            Flavor::Type1 { dither: false },
            &mut rng,
        );
        let z_next = if i + 1 < znet.len() {
            znet[i + 1]
        } else {
            JunctionUnit::required_z_next(shape.n_right * din[i], znet[i], din[i])
        };
        let mut unit = JunctionUnit::new(shape, din[i], sched, z_next);
        assert_eq!(unit.junction_cycle, zcfg.junction_cycle);
        let a: Vec<f32> = (0..shape.n_left).map(|_| rng.normal()).collect();
        let bias = vec![0.0f32; shape.n_right];
        let out = unit.feedforward(&a, &bias, Act::Relu).unwrap();
        assert_eq!(out.stats.cycles, zcfg.junction_cycle);
    }
}

/// Whole-net pattern masks load into the dense trainer and produce the
/// advertised density and parameter count.
#[test]
fn pattern_to_trainer_param_accounting() {
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    let mut rng = Rng::new(12);
    let pattern: NetPattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
    let net = pds::nn::trainer::Network::Sparse(snet);
    // Table I: 17000 weights + 110 biases
    assert_eq!(net.n_params(), 17_110);
    assert!((pattern.rho_net() - 0.2098).abs() < 1e-3);
}
