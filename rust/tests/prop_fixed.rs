//! Differential property tests for the fixed-point execution path
//! (`nn::fixed`): quantized forward agrees with f32 within the derivable
//! Qm.n error bound, quantize→dequantize round-trips within 1 ULP,
//! saturating ops never panic, the runtime's `forward_quantized` program
//! matches the f32 engine on every built-in config (`mnist_fc4`
//! included), and the batch kernels are bit-identical to the
//! cycle-accurate `hw::junction` quantized feedforward.
//!
//! Seeds come from `PDS_PROP_SEED` when set (CI pins it for
//! reproducibility); failures print the per-case seed via
//! `util::prop::for_all`.

use pds::nn::fixed::{forward_error_bound, FixedSparseLayer, FixedSparseNet, QFormat};
use pds::nn::sparse::{SparseLayer, SparseNet};
use pds::runtime::{Engine, Value};
use pds::sparsity::clash_free::{schedule, Flavor};
use pds::sparsity::config::{DoutConfig, JunctionShape, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::prop::for_all;
use pds::util::rng::Rng;

/// Root seed: `PDS_PROP_SEED` when set (CI pins it), a fixed default
/// otherwise — property runs are always reproducible from the log.
fn prop_seed() -> u64 {
    std::env::var("PDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1812_0116)
}

#[test]
fn roundtrip_error_within_one_ulp() {
    for_all(
        "quantize->dequantize within 1 ULP",
        prop_seed(),
        256,
        |r| {
            // m + n <= 20 keeps the f32 representation of the
            // round-trip result well below the format ULP, so the
            // 1-ULP assertion tests quantization, not f32 casts
            let m = 1 + r.below(8) as u32;
            let n = 2 + r.below(11) as u32;
            let fmt = QFormat::new(m, n);
            // value inside the representable range
            let x = (r.uniform() * 2.0 - 1.0) * fmt.max_value() * 0.999;
            (fmt, x)
        },
        |&(fmt, x)| {
            let back = fmt.dequantize(fmt.quantize(x));
            let err = (back - x).abs();
            if err <= fmt.ulp() {
                Ok(())
            } else {
                Err(format!("{fmt}: {x} -> {back}, err {err} > ulp {}", fmt.ulp()))
            }
        },
    );
}

#[test]
fn saturating_ops_never_panic_on_extremes() {
    let extremes = |fmt: QFormat| {
        vec![
            i32::MIN,
            i32::MAX,
            fmt.min_raw(),
            fmt.max_raw(),
            0,
            1,
            -1,
            fmt.max_raw() / 2,
        ]
    };
    for_all(
        "sat ops stay in range on extreme raw words",
        prop_seed() ^ 1,
        128,
        |r| {
            let fmt = QFormat::new(1 + r.below(10) as u32, 1 + r.below(16) as u32);
            let xs = extremes(fmt);
            let a = xs[r.below(xs.len())];
            let b = xs[r.below(xs.len())];
            (fmt, a, b)
        },
        |&(fmt, a, b)| {
            let (lo, hi) = (fmt.min_raw(), fmt.max_raw());
            for v in [fmt.sat_add(a, b), fmt.sat_mul(a, b)] {
                if v < lo || v > hi {
                    return Err(format!("{fmt}: result {v} outside [{lo}, {hi}]"));
                }
            }
            // quantize must absorb non-finite and huge inputs too
            for x in [f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1e30, -1e30] {
                let q = fmt.quantize(x);
                if q < lo || q > hi {
                    return Err(format!("{fmt}: quantize({x}) = {q} out of range"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn random_sparse_configs_forward_parity() {
    // Q8.12: generous integer headroom so randomly drawn nets (whose
    // He-init weights can be large at tiny fan-ins) never saturate —
    // saturation invalidates the error bound by design, and format
    // sizing for a concrete model is the builtin-config test's job
    let fmt = QFormat::new(8, 12);
    for_all(
        "quantized forward within the derived bound",
        prop_seed() ^ 2,
        24,
        |r| {
            let n0 = 6 + r.below(30);
            let n1 = 4 + r.below(20);
            let n2 = 2 + r.below(8);
            let d1 = 1 + r.below(n1.min(6));
            let d2 = 1 + r.below(n2.min(4));
            let batch = 1 + r.below(6);
            (vec![n0, n1, n2], vec![d1, d2], batch, r.next_u64())
        },
        |case| {
            let (layers, dout, batch, seed) = case;
            let (batch, seed) = (*batch, *seed);
            let netc = NetConfig::new(layers.clone());
            let mut rng = Rng::new(seed);
            let pattern = generate(
                Method::Random,
                &netc,
                &DoutConfig(dout.clone()),
                None,
                &mut rng,
            );
            let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
            let qnet = FixedSparseNet::from_f32(&snet, fmt);
            let x: Vec<f32> = (0..batch * layers[0])
                .map(|_| rng.uniform() * 2.0 - 1.0)
                .collect();
            let want = snet.logits(&x, batch);
            let (got, sats) = qnet.logits(&x, batch);
            if sats != 0 {
                return Err(format!("saturated {sats} outputs (format lacks headroom)"));
            }
            let bound = forward_error_bound(&snet, &x, batch, fmt);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if (g - w).abs() > bound {
                    return Err(format!("logit {i}: {g} vs {w}, |diff| > bound {bound}"));
                }
            }
            Ok(())
        },
    );
}

/// Acceptance criterion: for every built-in config (`mnist_fc4`
/// included) the engine's `forward_quantized` program matches the f32
/// `forward` program within the documented Qm.n error bound, with zero
/// saturations.
#[test]
fn engine_forward_quantized_matches_f32_on_all_builtin_configs() {
    let engine = Engine::native("/nonexistent/dir").unwrap();
    let configs: Vec<String> = engine.manifest.configs.keys().cloned().collect();
    assert!(configs.contains(&"mnist_fc4".to_string()));
    let mut rng = Rng::new(prop_seed() ^ 3);
    for config in &configs {
        let entry = &engine.manifest.configs[config];
        let (layers, batch) = (entry.layers.clone(), entry.batch);
        let fmt = entry.quant.expect("builtin configs carry a quant spec").format;
        let l = layers.len() - 1;
        // realistic sparse model: clash-free pattern at ~25% density
        let netc = NetConfig::new(layers.clone());
        let dout = DoutConfig(
            (0..netc.n_junctions())
                .map(|i| netc.junction(i).dout_for_density(0.25))
                .collect(),
        );
        let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);

        // dense inputs in the forward signature: w/b interleaved, masks, x
        let mut inputs: Vec<Value> = Vec::new();
        let mut junctions: Vec<SparseLayer> = Vec::new();
        for (i, p) in pattern.junctions.iter().enumerate() {
            let (nl, nr) = (layers[i], layers[i + 1]);
            let std = (2.0 / nl as f32).sqrt();
            let mask = p.mask();
            let w: Vec<f32> = mask.iter().map(|&m| rng.normal() * std * m).collect();
            let b = vec![0.1f32; nr];
            junctions.push(SparseLayer::from_pattern_dense(p, &w, &b));
            inputs.push(Value::F32(w, vec![nr, nl]));
            inputs.push(Value::F32(b, vec![nr]));
        }
        for (i, p) in pattern.junctions.iter().enumerate() {
            inputs.push(Value::F32(
                p.mask(),
                vec![layers[i + 1], layers[i]],
            ));
        }
        let x: Vec<f32> = (0..batch * layers[0])
            .map(|_| rng.uniform() * 2.0 - 1.0)
            .collect();
        inputs.push(Value::F32(x.clone(), vec![batch, layers[0]]));

        let fwd = engine.load(config, "forward").unwrap();
        let fq = engine.forward_quantized(config).unwrap();
        let want = fwd.run(&inputs).unwrap();
        let got = fq.run(&inputs).unwrap();
        let sats = got[1].scalar().unwrap();
        assert_eq!(sats, 0.0, "{config}: {sats} saturated outputs");

        // documented bound, computed on the compacted f32 twin
        let snet = SparseNet {
            layers: layers.clone(),
            junctions,
        };
        let bound = forward_error_bound(&snet, &x, batch, fmt);
        let want = want[0].as_f32().unwrap();
        let got = got[0].as_f32().unwrap();
        assert_eq!(got.len(), batch * layers[l]);
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= bound,
                "{config} logit {i}: {g} vs {w} (bound {bound})"
            );
        }
    }
}

/// The arithmetic contract: the batch kernel and the cycle-accurate
/// quantized junction produce bit-identical raw pre-activations.
#[test]
fn hw_junction_and_fixed_kernel_are_bit_identical() {
    let fmt = QFormat::default();
    for_all(
        "hw quantized FF == nn::fixed forward, bit for bit",
        prop_seed() ^ 4,
        12,
        |r| {
            // shapes with integral d_in and z | N_left (schedule contract)
            let shapes: [(usize, usize, usize, usize); 3] =
                [(12, 8, 2, 4), (24, 12, 3, 8), (40, 10, 2, 8)];
            let (nl, nr, dout, z) = shapes[r.below(shapes.len())];
            (nl, nr, dout, z, r.next_u64())
        },
        |&(nl, nr, dout, z, seed)| {
            use pds::hw::junction::{Act, JunctionUnit};
            let shape = JunctionShape {
                n_left: nl,
                n_right: nr,
            };
            let d_in = nl * dout / nr;
            let mut rng = Rng::new(seed);
            let sched = schedule(nl, z, dout, Flavor::Type1 { dither: false }, &mut rng);
            let z_next = JunctionUnit::required_z_next(nr * d_in, z, d_in);
            let mut unit = JunctionUnit::new(shape, d_in, sched, z_next);
            let dense: Vec<f32> = (0..nr * nl).map(|_| rng.normal() * 0.5).collect();
            unit.load_weights_dense(&dense);
            let a: Vec<f32> = (0..nl).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let bias: Vec<f32> = (0..nr).map(|_| rng.uniform() - 0.5).collect();

            let hw_out = unit
                .feedforward_quantized(&a, &bias, Act::Relu, fmt)
                .map_err(|e| format!("hw clash: {e}"))?;

            let pattern = unit.pattern();
            let layer = SparseLayer::from_pattern_dense(&pattern, &dense, &bias);
            let qlayer = FixedSparseLayer::from_f32(&layer, fmt);
            let mut input_clips = 0usize;
            let aq = fmt.quantize_slice_counted(&a, &mut input_clips);
            let mut h = vec![0i32; nr];
            let kernel_sats = qlayer.forward(&aq, 1, &mut h);

            // clip accounting must agree too: hw counts weight + bias +
            // input clips, the kernel side splits them across ingest
            if qlayer.clipped + input_clips != hw_out.clipped_words {
                return Err(format!(
                    "clip counts diverge: kernel {} vs hw {}",
                    qlayer.clipped + input_clips,
                    hw_out.clipped_words
                ));
            }
            if h != hw_out.h_raw {
                return Err(format!(
                    "raw words diverge: kernel {:?} vs hw {:?}",
                    &h[..nr.min(8)],
                    &hw_out.h_raw[..nr.min(8)]
                ));
            }
            if kernel_sats != hw_out.saturations {
                return Err(format!(
                    "saturation counts diverge: {kernel_sats} vs {}",
                    hw_out.saturations
                ));
            }
            Ok(())
        },
    );
}

/// The quantized weights of a trained-shape net replay clash-free
/// through the banked views in raw form (the fixed-word audit).
#[test]
fn quantized_weights_replay_through_banked_views() {
    use pds::hw::banked::BankedWeights;
    use pds::hw::zconfig::balanced_for_edges;
    let fmt = QFormat::default();
    let netc = NetConfig::new(vec![39, 390, 39]);
    let mut rng = Rng::new(prop_seed() ^ 5);
    let pattern = generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(vec![30, 3]),
        None,
        &mut rng,
    );
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
    let edges: Vec<usize> = snet.junctions.iter().map(|j| j.n_edges()).collect();
    let zcfg = balanced_for_edges(&edges, 90);
    for (junction, &zi) in snet.junctions.iter().zip(&zcfg.z) {
        BankedWeights::new(junction.n_edges(), zi)
            .audit_fixed(&fmt.quantize_slice(&junction.wc))
            .unwrap();
    }
}
