//! `pds` — pre-defined sparse neural networks with hardware acceleration.
//!
//! ```text
//! info                   list runtime configs and programs
//! analyze     [opts]     static verifier: clash-freedom prover, Qm.n
//!                        range analysis, manifest lint (nonzero exit
//!                        on error-level findings)
//! patterns    [opts]     generate + audit a connection pattern
//! storage     [opts]     Table-I storage model for a config
//! simulate    [opts]     cycle-accurate junction FF/BP/UP run
//! train       [opts]     train via the runtime backend (native by
//!                        default; PJRT with the `pjrt` feature);
//!                        --pipeline streams minibatches through the
//!                        Sec. III-A junction pipeline (native only)
//! serve       [opts]     multi-worker sharded inference service demo
//! serve-bench [opts]     serve load bench: multi-worker vs single-worker
//! exp <id>    [--quick]  paper experiment harnesses (see DESIGN.md)
//! ```
//!
//! (CLI parsing is hand-rolled: clap is unavailable in the offline build.)

use std::collections::BTreeMap;
use std::process::ExitCode;
use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::coordinator::{InferenceService, PipelinedTrainSession, ServerConfig};
use pds::net::{NetClient, NetServer, NetServerConfig, ReactorTuning};
use pds::nn::actsparse::ActSpec;
use pds::nn::fixed::{FixedSparseNet, QFormat};
use pds::nn::pipeline::PipelineConfig;
use pds::nn::sparse::SparseNet;
use pds::data::Spec;
use pds::exp::common::Scale;
use pds::hw::junction::{Act, JunctionUnit};
use pds::runtime::Engine;
use pds::sparsity::clash_free;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Parse `--key value` options + positionals.
fn parse_opts(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut opts = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, opts)
}

fn parse_list(s: &str) -> anyhow::Result<Vec<usize>> {
    s.split(',')
        .map(|t| t.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("{e}")))
        .collect()
}

fn artifacts_dir(opts: &BTreeMap<String, String>) -> String {
    opts.get("artifacts")
        .cloned()
        .unwrap_or_else(|| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

/// Parse an optional Qm.n option: absent -> `None`, a bare flag -> the
/// default format, a value -> that format (or an error).
fn parse_quant(opts: &BTreeMap<String, String>, key: &str) -> anyhow::Result<Option<QFormat>> {
    match opts.get(key).map(String::as_str) {
        None => Ok(None),
        Some("true") => Ok(Some(QFormat::default())),
        Some(s) => QFormat::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("--{key}: bad fixed-point format '{s}' (want Qm.n)")),
    }
}

/// Parse the activation-sparsity options: `--act-topk K` keeps the K
/// largest-magnitude hidden activations per sample, `--act-threshold T`
/// keeps magnitudes `>= T`. At most one may be given; the input layer
/// is never masked.
fn parse_act(opts: &BTreeMap<String, String>) -> anyhow::Result<Option<ActSpec>> {
    let topk = opts.get("act-topk");
    let thresh = opts.get("act-threshold");
    anyhow::ensure!(
        topk.is_none() || thresh.is_none(),
        "--act-topk and --act-threshold are mutually exclusive"
    );
    if let Some(s) = topk {
        let k: usize = s.parse().map_err(|e| anyhow::anyhow!("--act-topk: {e}"))?;
        anyhow::ensure!(
            k >= 1,
            "--act-topk must be at least 1 (k = 0 zeroes every hidden activation)"
        );
        return Ok(Some(ActSpec::top_k(k)));
    }
    if let Some(s) = thresh {
        let t: f32 = s.parse().map_err(|e| anyhow::anyhow!("--act-threshold: {e}"))?;
        anyhow::ensure!(
            t.is_finite() && t >= 0.0,
            "--act-threshold must be finite and non-negative"
        );
        return Ok(Some(ActSpec::threshold(t)));
    }
    Ok(None)
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_help();
        return Ok(());
    };
    let (pos, opts) = parse_opts(&args[1..]);
    match cmd.as_str() {
        "help" | "--help" | "-h" => print_help(),
        "info" => cmd_info(&opts)?,
        "analyze" => cmd_analyze(&opts)?,
        "patterns" => cmd_patterns(&opts)?,
        "storage" => cmd_storage(&opts)?,
        "simulate" => cmd_simulate(&opts)?,
        "train" => cmd_train(&opts)?,
        "serve" => cmd_serve(&opts)?,
        "client" => cmd_client(&opts)?,
        "serve-bench" => cmd_serve_bench(&opts)?,
        "exp" => {
            let id = pos.first().map(String::as_str).unwrap_or("all");
            let scale = if opts.contains_key("quick") {
                Scale::quick()
            } else {
                Scale::standard()
            };
            pds::exp::run(id, &scale).map_err(|e| anyhow::anyhow!(e))?;
        }
        other => anyhow::bail!("unknown command '{other}' (try `pds help`)"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "pds — Pre-Defined Sparse Neural Networks with Hardware Acceleration\n\
         \n\
         usage: pds <command> [--options]\n\
         \n\
         commands:\n\
           info                              list artifact configs\n\
           analyze   [--config NAME] [--manifest PATH] [--quant Qm.n]\n\
                     [--depth N] [--input-range R] [--seed N] [--json]\n\
                     [--act-topk K | --act-threshold T]  (lint the entries\n\
                      as if they declared that activation-sparsity spec)\n\
                     [--contexts C]  (prove the C-tenant interleave:\n\
                      per-context clash-freedom and the per-context\n\
                      staleness closed form)\n\
                     (static verifier: proves clash-freedom across the\n\
                      pipelined FF/BP/UP interleave, certifies the Qm.n\n\
                      saturation-free input range — or proves a given\n\
                      --input-range safe — and lints the manifest;\n\
                      nonzero exit on any error-level finding)\n\
           patterns  --layers 800,100,10 --dout 20,10 [--method clash-free|structured|random] [--z 200,10]\n\
           storage   --layers 800,100,10 --dout 20,10\n\
           simulate  --left 800 --right 100 --dout 20 --z 200\n\
           train     --config tiny [--dout 8,4] [--epochs 5] [--lr 1e-3] [--fc]\n\
                     [--pipeline] [--depth N] [--batch N] [--z0 N]\n\
                     [--profile]  (with --pipeline: per-stage FF/BP/UP\n\
                      profile — wall time + paper clock-cycle model per\n\
                      junction — printed after training)\n\
                     [--quant-eval [Qm.n]]\n\
                     [--act-topk K | --act-threshold T]  (train sparse-sparse:\n\
                      keep only the K largest / >= T hidden activations per\n\
                      sample; the input layer is never masked)\n\
                     (--pipeline streams minibatches through the Sec. III-A\n\
                      FF/BP/UP junction pipeline; --depth 1 = sequential,\n\
                      default = full 2L-deep schedule; native backend only.\n\
                      --quant-eval re-evaluates the trained net in Qm.n\n\
                      fixed point, default Q5.10)\n\
           serve     --models tiny,mnist_fc2 [--workers 2] [--queue-depth 256]\n\
                     [--clients 4] [--requests 200] [--wait-ms 2]\n\
                     [--contexts 1]  (tenant parameter banks per model;\n\
                      context 0 is the base model, higher contexts get\n\
                      per-tenant weights; load spreads round-robin)\n\
                     [--quant [Qm.n]]  (serve in fixed point, default Q5.10)\n\
                     [--act-topk K | --act-threshold T]  (sparse-sparse\n\
                      inference; composes with --quant; per-model metrics\n\
                      report the achieved activation density)\n\
                     [--listen ADDR [--batch-window USEC] [--max-conns N]\n\
                      [--frame-timeout-ms MS] [--trace-sample N]\n\
                      [--trace-out PATH]]\n\
                     (--trace-sample N traces 1 in N requests through\n\
                      net -> batcher -> engine; --trace-out writes the\n\
                      span log as Chrome trace_event JSON at shutdown —\n\
                      load it at chrome://tracing or ui.perfetto.dev)\n\
                     (--listen 127.0.0.1:0 starts the TCP front-end and\n\
                      serves until a client sends a shutdown frame;\n\
                      --batch-window is the micro-batcher's coalescing\n\
                      deadline in microseconds, default 1000; --max-conns\n\
                      bounds concurrent connections on the single reactor\n\
                      thread, default 1024; --frame-timeout-ms bounds how\n\
                      long a partial frame may dribble, default 5000)\n\
           client    --addr HOST:PORT [--model NAME] [--context 0]\n\
                     [--requests 16] [--pipeline 4] [--idle-conns 0]\n\
                     [--seed 0] [--trace] [--metrics-json] [--shutdown]\n\
                     (drives a `serve --listen` server over TCP;\n\
                      --trace sends one traced request first and prints\n\
                      the server's queue/batch/execute waterfall;\n\
                      --metrics-json dumps the post-run metrics scrape\n\
                      as one JSON line instead of prose;\n\
                      --idle-conns holds N extra idle connections open\n\
                      for the duration of the request loop;\n\
                      --shutdown asks the server to drain and exit)\n\
           serve-bench --models tiny,mnist_fc2 [--workers 4] [--clients 8]\n\
                     [--requests 200] [--wait-ms 2] [--queue-depth 256]\n\
                     [--think-us 0] [--burst 1] [--contexts 1] [--quant [Qm.n]]\n\
                     [--act-topk K | --act-threshold T] [--out BENCH_serve.json]\n\
           exp <fig1|table1|table2|fig6|fig7|fig8|fig9|fig10|fig11|fig12|table3|pipeline|all> [--quick]\n\
         \n\
         global: --artifacts <dir> (default: ./artifacts)"
    );
}

fn cmd_info(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let engine = Engine::new(artifacts_dir(opts))?;
    println!("runtime platform: {}", engine.platform());
    for (name, cfg) in &engine.manifest.configs {
        println!(
            "config {:<12} layers {:?} batch {}",
            name, cfg.layers, cfg.batch
        );
        for (tag, p) in &cfg.programs {
            println!(
                "  {:<16} {} ({} inputs, {} outputs)",
                tag,
                p.file,
                p.inputs.len(),
                p.outputs.len()
            );
        }
    }
    Ok(())
}

/// `pds analyze`: run the static verifier (clash-freedom prover, Qm.n
/// range analysis, manifest lint) over the builtin/artifact manifest or
/// an explicit `--manifest PATH`, one `--config` or all. Exits nonzero
/// on any error-level finding; `--json` prints the stable
/// machine-readable report instead of the human one.
fn cmd_analyze(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    use pds::analysis::{self, AnalysisReport, AnalyzeOptions, Finding, Severity};
    use pds::runtime::Manifest;

    let mut aopts = AnalyzeOptions {
        quant: parse_quant(opts, "quant")?,
        ..AnalyzeOptions::default()
    };
    if let Some(d) = opts.get("depth") {
        aopts.depth = Some(d.parse().map_err(|e| anyhow::anyhow!("--depth: {e}"))?);
    }
    if let Some(r) = opts.get("input-range") {
        aopts.input_range = Some(r.parse().map_err(|e| anyhow::anyhow!("--input-range: {e}"))?);
    }
    if let Some(s) = opts.get("seed") {
        aopts.seed = s.parse().map_err(|e| anyhow::anyhow!("--seed: {e}"))?;
    }
    if let Some(c) = opts.get("contexts") {
        aopts.contexts = c.parse().map_err(|e| anyhow::anyhow!("--contexts: {e}"))?;
        anyhow::ensure!(aopts.contexts >= 1, "--contexts must be at least 1");
    }
    let json = opts.contains_key("json");

    // manifest source: explicit --manifest PATH beats <artifacts>/manifest.json
    // beats the builtin configs. A file that fails to parse is itself an
    // analyzer finding (severity error), not a CLI crash.
    let explicit = opts.get("manifest").cloned();
    let path = explicit
        .clone()
        .unwrap_or_else(|| format!("{}/manifest.json", artifacts_dir(opts)));
    let (mut manifest, raw_text) = match std::fs::read_to_string(&path) {
        Ok(text) => match Manifest::parse(&text) {
            Ok(m) => (m, Some(text)),
            Err(e) => {
                let report = AnalysisReport {
                    findings: vec![Finding::new(
                        "lint",
                        "parse-error",
                        Severity::Error,
                        "<manifest>",
                        format!("{path}: {e}"),
                    )],
                };
                emit_report(report, json)?;
                unreachable!("parse-error report always has errors")
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound && explicit.is_none() => {
            (Manifest::builtin(), None)
        }
        Err(e) => anyhow::bail!("cannot read {path}: {e}"),
    };

    // --act-topk/--act-threshold: analyze as if the manifest declared the
    // spec (applied to --config's entry, or every entry), so the lint
    // pass covers a planned deployment without editing the file
    if let Some(spec) = parse_act(opts)? {
        match opts.get("config") {
            Some(name) => {
                let e = manifest
                    .configs
                    .get_mut(name)
                    .ok_or_else(|| anyhow::anyhow!("config '{name}' not in manifest"))?;
                e.act = Some(spec);
            }
            None => {
                for e in manifest.configs.values_mut() {
                    e.act = Some(spec);
                }
            }
        }
    }

    let mut report = match opts.get("config") {
        Some(name) => {
            let entry = manifest
                .configs
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("config '{name}' not in manifest"))?;
            analysis::analyze_config(name, entry, &aopts)
        }
        None => analysis::analyze_manifest(&manifest, &aopts),
    };
    // raw-document lint: fields the parser silently ignores or drops
    if let Some(text) = &raw_text {
        report.findings.extend(analysis::lint::lint_text(text));
    }
    emit_report(report, json)
}

/// Print an analysis report (human or `--json`) and turn error-level
/// findings into a nonzero exit.
fn emit_report(mut report: pds::analysis::AnalysisReport, json: bool) -> anyhow::Result<()> {
    report.sort_by_severity();
    if json {
        println!("{}", report.to_json());
    } else {
        print!("{report}");
    }
    if report.has_errors() {
        anyhow::bail!(
            "analysis found {} error-level finding(s)",
            report.count(pds::analysis::Severity::Error)
        );
    }
    Ok(())
}

fn cmd_patterns(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let layers = parse_list(opts.get("layers").map(String::as_str).unwrap_or("800,100,10"))?;
    let dout = DoutConfig(parse_list(opts.get("dout").map(String::as_str).unwrap_or("20,10"))?);
    let method = Method::parse(opts.get("method").map(String::as_str).unwrap_or("clash-free"))
        .ok_or_else(|| anyhow::anyhow!("bad method"))?;
    let znet = opts.get("z").map(|s| parse_list(s)).transpose()?;
    let seed: u64 = opts.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let netc = NetConfig::new(layers);
    netc.validate_dout(&dout).map_err(|e| anyhow::anyhow!(e))?;
    let mut rng = Rng::new(seed);
    let p = generate(method, &netc, &dout, znet.as_deref(), &mut rng);
    println!(
        "method {} rho_net {:.1}% edges {:?}",
        method.name(),
        p.rho_net() * 100.0,
        p.junctions.iter().map(|j| j.n_edges()).collect::<Vec<_>>()
    );
    for (i, j) in p.junctions.iter().enumerate() {
        j.audit().map_err(|e| anyhow::anyhow!("junction {i}: {e}"))?;
        println!(
            "junction {}: {}x{} density {:.1}% structured={} disconnected L/R = {}/{}",
            i + 1,
            j.shape.n_left,
            j.shape.n_right,
            j.density() * 100.0,
            j.is_structured(),
            j.disconnected_left(),
            j.disconnected_right()
        );
    }
    if let Some(z) = &znet {
        let cfg = pds::hw::zconfig::validate(&netc, &dout, z).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "z_net {:?}: junction cycle C = {} ({}), idle {:.1}%",
            cfg.z,
            cfg.junction_cycle,
            if cfg.balanced { "balanced" } else { "max" },
            cfg.idle_fraction() * 100.0
        );
    }
    Ok(())
}

fn cmd_storage(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let layers = parse_list(opts.get("layers").map(String::as_str).unwrap_or("800,100,10"))?;
    let dout = DoutConfig(parse_list(opts.get("dout").map(String::as_str).unwrap_or("20,10"))?);
    let netc = NetConfig::new(layers);
    netc.validate_dout(&dout).map_err(|e| anyhow::anyhow!(e))?;
    let cmp = pds::hw::storage::StorageComparison::new(&netc, &dout);
    println!(
        "FC total {} words; sparse total {} words; memory reduction {:.1}X; compute reduction {:.1}X",
        cmp.fc.total(),
        cmp.sparse.total(),
        cmp.memory_reduction(),
        cmp.compute_reduction()
    );
    Ok(())
}

fn cmd_simulate(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let get = |k: &str, d: usize| -> anyhow::Result<usize> {
        Ok(opts.get(k).map(|s| s.parse()).transpose()?.unwrap_or(d))
    };
    let (nl, nr, dout, z) = (get("left", 800)?, get("right", 100)?, get("dout", 20)?, get("z", 200)?);
    let shape = pds::sparsity::config::JunctionShape { n_left: nl, n_right: nr };
    anyhow::ensure!(nl * dout % nr == 0, "d_in not integral");
    let d_in = nl * dout / nr;
    let mut rng = Rng::new(1);
    let sched = clash_free::schedule(nl, z, dout, clash_free::Flavor::Type1 { dither: false }, &mut rng);
    sched.verify_clash_free().map_err(|e| anyhow::anyhow!(e))?;
    let z_next = JunctionUnit::required_z_next(nr * d_in, z, d_in);
    let mut unit = JunctionUnit::new(shape, d_in, sched, z_next);
    let dense: Vec<f32> = (0..nr * nl).map(|_| rng.normal()).collect();
    unit.load_weights_dense(&dense);
    let a: Vec<f32> = (0..nl).map(|_| rng.normal()).collect();
    let bias = vec![0.1f32; nr];
    let t0 = std::time::Instant::now();
    let ff = unit.feedforward(&a, &bias, Act::Relu).map_err(|e| anyhow::anyhow!("{e}"))?;
    let dt = t0.elapsed();
    println!(
        "junction ({nl} x {nr}), d_out {dout}, d_in {d_in}, z {z}: junction cycle C = {} cycles",
        unit.junction_cycle
    );
    println!(
        "FF pass: {} cycles, {} weight reads, max {} right neurons/cycle (bound {}), wall {dt:?}",
        ff.stats.cycles,
        ff.stats.weight_reads,
        ff.stats.max_rights_per_cycle,
        pds::util::ceil_div(z, d_in)
    );
    let dr: Vec<f32> = (0..nr).map(|_| rng.normal()).collect();
    let (_, bp) = unit.backprop(&dr, &vec![1.0; nl]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut b2 = bias.clone();
    let up = unit.update(&a, &dr, &mut b2, 0.01).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("BP pass: {} cycles; UP pass: {} cycles (all clash-free)", bp.cycles, up.cycles);
    Ok(())
}

fn cmd_train(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let config = opts.get("config").cloned().unwrap_or_else(|| "tiny".into());
    let epochs: usize = opts.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(5);
    let lr: f32 = opts.get("lr").map(|s| s.parse()).transpose()?.unwrap_or(1e-3);
    let seed: u64 = opts.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let act = parse_act(opts)?;
    let mut engine = Engine::new(artifacts_dir(opts))?;
    let entry = engine
        .manifest
        .configs
        .get(&config)
        .ok_or_else(|| anyhow::anyhow!("no config {config}"))?;
    let layers = entry.layers.clone();
    let entry_batch = entry.batch;
    let entry_dout = entry.gather_dout.clone();
    let netc = NetConfig::new(layers.clone());
    let dout = if opts.contains_key("fc") {
        netc.fc_dout()
    } else {
        DoutConfig(match opts.get("dout") {
            Some(s) => parse_list(s)?,
            None => entry_dout.unwrap_or_else(|| netc.fc_dout().0.clone()),
        })
    };
    netc.validate_dout(&dout).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(spec) = act {
        anyhow::ensure!(
            !opts.contains_key("pipeline"),
            "--act-topk/--act-threshold: the pipelined trainer has no masked \
             schedule yet; use the sequential path"
        );
        // the native train program reads the spec off its manifest entry
        if let Some(e) = engine.manifest.configs.get_mut(&config) {
            e.act = Some(spec);
        }
        println!("activation sparsity: {spec} on hidden layers");
    }
    let mut rng = Rng::new(seed);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    println!(
        "training config {config} {layers:?} rho_net {:.1}% on {}",
        pattern.rho_net() * 100.0,
        engine.platform()
    );
    if opts.contains_key("pipeline") {
        return cmd_train_pipelined(&engine, &config, &pattern, opts, epochs, lr, seed, &mut rng);
    }
    let mut session = pds::coordinator::TrainSession::new(&engine, &config, &pattern, lr, 1e-4, seed)?;
    let spec = spec_for_features(layers[0], *layers.last().unwrap());
    let splits = spec.splits(entry_batch * 8, 0, entry_batch * 3, seed ^ 99);
    for e in 0..epochs {
        let (loss, acc) = session.epoch(&splits.train, &mut rng)?;
        let test = session.evaluate(&splits.test)?;
        println!("epoch {e:>3}: train loss {loss:.4} acc {:.1}% | test acc {:.1}%", acc * 100.0, test * 100.0);
    }
    session.check_mask_invariant()?;
    println!("mask invariant holds: excluded edges exactly zero after training");
    if let Some(fmt) = parse_quant(opts, "quant-eval")? {
        // rebuild the compacted net from the session's dense parameters
        let mut pairs = Vec::with_capacity(pattern.junctions.len());
        for j in 0..pattern.junctions.len() {
            pairs.push((
                session.param(j, false).as_f32()?,
                session.param(j, true).as_f32()?,
            ));
        }
        let snet = SparseNet::from_pattern_dense(&pattern, &pairs);
        // sequential path has no trainer-owned banked views, so derive a
        // balanced z_net and replay the quantized words through it
        let edges: Vec<usize> = snet.junctions.iter().map(|j| j.n_edges()).collect();
        let zcfg = pds::hw::zconfig::balanced_for_edges(&edges, 100);
        for (junction, &z) in snet.junctions.iter().zip(&zcfg.z) {
            pds::hw::banked::BankedWeights::new(junction.n_edges(), z)
                .audit_fixed(&fmt.quantize_slice(&junction.wc))
                .map_err(|e| anyhow::anyhow!("banked quantized audit: {e}"))?;
        }
        println!("banked quantized weight audit clean ({fmt}, z_net {:?})", zcfg.z);
        quant_eval_report(&snet, &splits.test, fmt)?;
    }
    Ok(())
}

/// `train --quant-eval`: re-evaluate a trained compacted net in Qm.n
/// fixed point and report the accuracy delta plus every headroom
/// violation (clipped parameters, saturated outputs). Banked quantized
/// replay is the caller's job — the pipelined path audits through the
/// trainer's *actual* banked views, the sequential path derives its own.
fn quant_eval_report(
    snet: &SparseNet,
    test: &pds::data::Dataset,
    fmt: QFormat,
) -> anyhow::Result<()> {
    let qnet = FixedSparseNet::from_f32(snet, fmt);
    let clipped = qnet.clipped_params();
    let classes = *snet.layers.last().unwrap();
    let (mut correct_f, mut correct_q, mut sats, mut seen) = (0usize, 0usize, 0usize, 0usize);
    let idxs: Vec<usize> = (0..test.n).collect();
    for chunk in idxs.chunks(256) {
        let (x, y) = test.gather(chunk);
        let lf = snet.logits(&x, y.len());
        for (i, &yi) in y.iter().enumerate() {
            let row = &lf[i * classes..(i + 1) * classes];
            let best = (0..classes).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
            if best == yi as usize {
                correct_f += 1;
            }
        }
        let (cq, s) = qnet.eval_batch(&x, &y);
        correct_q += cq;
        sats += s;
        seen += y.len();
    }
    println!(
        "quant eval {fmt}: f32 test acc {:.1}% | quantized {:.1}% ({:+.2} pts), \
         {sats} saturated outputs / {clipped} clipped params over {seen} samples",
        100.0 * correct_f as f64 / seen.max(1) as f64,
        100.0 * correct_q as f64 / seen.max(1) as f64,
        100.0 * (correct_q as f64 - correct_f as f64) / seen.max(1) as f64,
    );
    Ok(())
}

/// `train --pipeline`: stream minibatches through the Sec. III-A junction
/// pipeline (native backend only), then report the schedule's measured
/// weight staleness against the paper's closed form and re-audit the
/// banked weight views.
#[allow(clippy::too_many_arguments)]
fn cmd_train_pipelined(
    engine: &Engine,
    config: &str,
    pattern: &pds::sparsity::pattern::NetPattern,
    opts: &BTreeMap<String, String>,
    epochs: usize,
    lr: f32,
    seed: u64,
    rng: &mut Rng,
) -> anyhow::Result<()> {
    let depth: usize = opts.get("depth").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let batch: usize = opts.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let z0: usize = opts.get("z0").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let cfg = PipelineConfig {
        epochs,
        batch,
        depth,
        adam: pds::nn::adam::AdamConfig {
            lr,
            ..Default::default()
        },
        l2: 1e-4,
        seed,
        z0,
        tune_kernel_threads: true,
        profile: opts.contains_key("profile"),
    };
    let mut session = PipelinedTrainSession::new(engine, config, pattern, &cfg)?;
    let t = session.trainer();
    let l = session.layers.len() - 1;
    println!(
        "pipelined schedule: L = {l}, depth {} in flight (stride {}), batch {}",
        t.depth(),
        t.stride(),
        session.batch
    );
    println!(
        "banked weight views: z_net {:?}, junction cycle C = {} ({})",
        t.z_net().z,
        t.z_net().junction_cycle,
        if t.z_net().balanced { "balanced" } else { "max" }
    );
    let spec = spec_for_features(session.layers[0], *session.layers.last().unwrap());
    let splits = spec.splits(session.batch * 8, 0, session.batch * 3, seed ^ 99);
    for e in 0..epochs {
        let (loss, acc) = session.epoch(&splits.train, rng)?;
        let test = session.evaluate(&splits.test);
        println!(
            "epoch {e:>3}: train loss {loss:.4} acc {:.1}% | test acc {:.1}%",
            acc * 100.0,
            test * 100.0
        );
    }
    let t = session.trainer();
    for i in 1..=l {
        match t.measured_staleness(i) {
            Some(s) => println!(
                "junction {i}: measured weight staleness {s} update(s) (schedule says {})",
                t.expected_staleness(i)
            ),
            None => println!("junction {i}: staleness not measured (pipeline never filled)"),
        }
    }
    let m = session.metrics();
    println!(
        "schedule: {} junction cycles, {} ops, max {} ops co-scheduled per cycle (3L-1 = {})",
        m.taus,
        m.ops,
        m.max_ops_in_tau,
        3 * l - 1
    );
    t.audit_banked()?;
    println!("banked weight audit clean: clash-free under the Fig. 4 port discipline");
    if cfg.profile {
        println!("-- per-stage profile --");
        print!("{}", t.prof.report());
    }
    if let Some(fmt) = parse_quant(opts, "quant-eval")? {
        t.audit_banked_quantized(fmt)?;
        println!("banked quantized weight audit clean ({fmt})");
        quant_eval_report(t.net(), &splits.test, fmt)?;
    }
    Ok(())
}

/// Pick a surrogate whose feature/class dims match an artifact config.
fn spec_for_features(features: usize, classes: usize) -> Spec {
    let mut spec = match features {
        800 => Spec::mnist_like(),
        2000 => Spec::reuters_like(),
        39 => Spec::timit_like(39),
        4000 => Spec::cifar_features_like(true),
        _ => Spec {
            name: "generic",
            features,
            classes,
            latent_dim: (features / 3).clamp(4, 64),
            shaping: pds::data::Shaping::Continuous,
            separation: 3.0,
            noise: 0.4,
        },
    };
    spec.classes = classes;
    spec
}

/// Comma-separated model list (`--models a,b`; `--config` kept as an
/// alias for the single-model case).
fn parse_models(opts: &BTreeMap<String, String>, default: &str) -> Vec<String> {
    opts.get("models")
        .or_else(|| opts.get("config"))
        .map(String::as_str)
        .unwrap_or(default)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn cmd_serve(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let models = parse_models(opts, "tiny");
    let requests: usize = opts.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let clients: usize = opts.get("clients").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let wait_ms: u64 = opts.get("wait-ms").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let workers: usize = opts.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let queue_depth: usize = opts.get("queue-depth").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let contexts: usize = opts.get("contexts").map(|s| s.parse()).transpose()?.unwrap_or(1);
    anyhow::ensure!(contexts >= 1, "--contexts must be at least 1");
    let quant = parse_quant(opts, "quant")?;
    let act = parse_act(opts)?;
    let dir = artifacts_dir(opts);
    let specs = models
        .iter()
        .map(|m| {
            loadgen::model_spec(&dir, m, 0.25, 3).map(|s| {
                let s = s.with_contexts(contexts);
                let s = match quant {
                    Some(fmt) => s.with_quant(fmt),
                    None => s,
                };
                match act {
                    Some(a) => s.with_act(a),
                    None => s,
                }
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let svc = InferenceService::start(
        &dir,
        specs,
        ServerConfig {
            max_wait: Duration::from_millis(wait_ms),
            workers,
            queue_depth,
            tune_kernel_threads: true,
        },
    )?;
    if let Some(listen) = opts.get("listen") {
        return cmd_serve_listen(svc, listen, &models, opts);
    }
    println!(
        "serving {models:?}: {workers} workers/model, {contexts} tenant context(s)/model, \
         queue depth {queue_depth}, max_wait {wait_ms}ms; \
         {clients} clients x {requests} requests per model{}{}",
        match quant {
            Some(fmt) => format!("; fixed-point {fmt}"),
            None => String::new(),
        },
        match act {
            Some(a) => format!("; activation sparsity {a}"),
            None => String::new(),
        }
    );
    let load = LoadSpec {
        clients,
        requests,
        think_time: Duration::ZERO,
        burst: 1,
        contexts,
    };
    let reports = loadgen::run_load(&svc, &models, &load, 42)?;
    for r in &reports {
        r.print();
    }
    println!("-- metrics (registry snapshot) --");
    print!("{}", svc.registry().snapshot().report());
    svc.shutdown()?;
    Ok(())
}

/// `serve --listen ADDR`: front the service with the TCP server and
/// park until a client requests drain with a shutdown frame.
fn cmd_serve_listen(
    svc: InferenceService,
    listen: &str,
    models: &[String],
    opts: &BTreeMap<String, String>,
) -> anyhow::Result<()> {
    let window_us: u64 = opts
        .get("batch-window")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1000);
    let max_conns: usize = opts
        .get("max-conns")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let frame_timeout_ms: u64 = opts
        .get("frame-timeout-ms")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5000);
    let trace_sample: u64 = opts
        .get("trace-sample")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let trace_out = opts.get("trace-out").cloned();
    let svc = std::sync::Arc::new(svc);
    let server = NetServer::start_tuned(
        std::sync::Arc::clone(&svc),
        listen,
        NetServerConfig {
            max_connections: max_conns,
            batch_window: Duration::from_micros(window_us),
            trace_sample,
        },
        ReactorTuning {
            frame_timeout: Duration::from_millis(frame_timeout_ms),
            ..ReactorTuning::default()
        },
    )?;
    // keep the sink alive past server teardown so --trace-out can
    // export after the drain
    let trace_sink = std::sync::Arc::clone(server.trace_sink());
    println!(
        "serving {models:?} — listening on {} (batch window {window_us}us, \
         max {max_conns} connections); send a shutdown frame to drain \
         (`pds client --addr {} --shutdown`)",
        server.local_addr(),
        server.local_addr(),
    );
    if trace_sample > 0 {
        println!("request tracing: sampling 1 in {trace_sample} requests");
    }
    server.run_until_shutdown();
    println!("shutdown requested: draining in-flight requests");
    // batcher handles survive the server teardown, so the summary below
    // includes requests answered *during* the drain
    let handles: Vec<_> = models.iter().filter_map(|m| server.batcher(m)).collect();
    let peak = server
        .metrics()
        .peak_active
        .load(std::sync::atomic::Ordering::Relaxed);
    let net = server.shutdown()?;
    println!("reactor peak {peak} concurrent connections");
    for h in &handles {
        if let Some(snap) = pds::net::model_metrics_snapshot(&net, h) {
            println!(
                "model {}: {} served, {} engine batches (mean occupancy {:.1}), \
                 {} micro-batch flushes (mean coalesced {:.1})",
                snap.model,
                snap.requests,
                snap.batches,
                snap.mean_occupancy,
                snap.net_flushes,
                snap.mean_coalesced(),
            );
        }
    }
    if let Some(path) = &trace_out {
        std::fs::write(path, trace_sink.to_chrome_json().to_string())
            .map_err(|e| anyhow::anyhow!("--trace-out {path}: {e}"))?;
        println!(
            "wrote {} trace events to {path} ({} dropped at capacity)",
            trace_sink.len(),
            trace_sink.dropped(),
        );
    }
    // both Arcs must go before the unwrap: ours and the one the server
    // handed back
    drop(svc);
    match std::sync::Arc::try_unwrap(net) {
        Ok(svc) => svc.shutdown()?,
        Err(_) => anyhow::bail!("service still referenced after network drain"),
    }
    println!("clean shutdown: network drained, engine workers joined");
    Ok(())
}

/// One row of the `client --trace` waterfall: stage name, microseconds,
/// and a bar proportional to the stage's share of the server-side total.
fn print_trace_bar(name: &str, us: u32, total: u32) {
    const WIDTH: usize = 40;
    let filled = if total == 0 {
        0
    } else {
        ((us as usize * WIDTH).div_ceil(total as usize)).min(WIDTH)
    };
    let bar = "#".repeat(filled);
    let pad = " ".repeat(WIDTH - filled);
    println!("  {name:<8} {us:>9}us |{bar}{pad}|");
}

/// `client`: drive a `serve --listen` server over TCP.
fn cmd_client(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let addr = opts
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("client requires --addr HOST:PORT"))?;
    let mut net = NetClient::connect(addr)?;
    let health = net.health().map_err(|e| anyhow::anyhow!("health: {e}"))?;
    anyhow::ensure!(!health.models.is_empty(), "server serves no models");
    if opts.get("shutdown").map(String::as_str) == Some("true") && !opts.contains_key("requests")
    {
        // pure shutdown call: no inference traffic wanted
        net.shutdown_server()
            .map_err(|e| anyhow::anyhow!("shutdown: {e}"))?;
        println!("server acknowledged shutdown");
        return Ok(());
    }
    let model = match opts.get("model") {
        Some(m) => m.clone(),
        None => health.models[0].name.clone(),
    };
    let info = health
        .models
        .iter()
        .find(|i| i.name == model)
        .ok_or_else(|| anyhow::anyhow!("model '{model}' not served (have: {:?})",
            health.models.iter().map(|i| &i.name).collect::<Vec<_>>()))?;
    let requests: usize = opts.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(16);
    // clamp to the engine batch: a larger group cannot coalesce any
    // further, and past the server's batcher queue cap it would only
    // earn Busy sheds (same clamp as loadgen::run_socket_load)
    let pipeline: usize = opts
        .get("pipeline")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4)
        .clamp(1, info.batch as usize);
    let seed: u64 = opts.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let context: u32 = opts.get("context").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let idle_conns: usize = opts
        .get("idle-conns")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    // mostly-idle scale-out check: hold extra connections open for the
    // whole request loop so the reactor multiplexes them alongside the
    // active one (they are dropped — closed — only after the loop)
    let mut idle_pool = Vec::with_capacity(idle_conns);
    for _ in 0..idle_conns {
        idle_pool.push(NetClient::connect(addr)?);
    }
    if idle_conns > 0 {
        println!("holding {idle_conns} idle connections open");
    }
    anyhow::ensure!(
        context < info.contexts.max(1),
        "--context {context} out of range: '{model}' hosts {} context(s)",
        info.contexts.max(1)
    );
    println!(
        "connected to {addr}: {} model(s), targeting '{model}' context {context} \
         ({} features, {} classes, engine batch {}, {} tenant context(s))",
        health.models.len(),
        info.features,
        info.classes,
        info.batch,
        info.contexts.max(1)
    );
    let mut rng = Rng::new(seed);
    if opts.get("trace").map(String::as_str) == Some("true") {
        // client-minted trace ID: the server honors it regardless of
        // its own --trace-sample setting, so one-off waterfalls work
        // against an otherwise-unsampled server
        let trace_id = (seed << 8) | 0xA5;
        let features: Vec<f32> =
            (0..info.features as usize).map(|_| rng.normal()).collect();
        let p = net
            .classify_traced(&model, context, features, trace_id)
            .map_err(|e| anyhow::anyhow!("traced request: {e}"))?;
        match p.trace {
            Some(echo) => {
                println!(
                    "trace {:#018x}: class {} (server latency {:?}, worker {})",
                    echo.trace_id, p.class, p.latency, p.worker
                );
                let total = echo
                    .queue_us
                    .saturating_add(echo.batch_us)
                    .saturating_add(echo.execute_us);
                print_trace_bar("queue", echo.queue_us, total);
                print_trace_bar("batch", echo.batch_us, total);
                print_trace_bar("execute", echo.execute_us, total);
            }
            None => println!("server answered without a trace echo"),
        }
    }
    let mut served = 0usize;
    let mut occupancy_sum = 0u64;
    let mut busy_retries = 0usize;
    let t0 = std::time::Instant::now();
    let mut remaining = requests;
    while remaining > 0 {
        let k = pipeline.min(remaining);
        let group: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..info.features as usize).map(|_| rng.normal()).collect())
            .collect();
        // a transiently saturated server sheds Busy; retry with the
        // load generator's shared policy — but bounded, so a
        // persistently saturated server fails loudly instead of
        // hanging the CLI
        let retry_deadline = std::time::Instant::now() + Duration::from_secs(30);
        let (preds, retries) = loadgen::classify_group_with_retry(
            &mut net,
            &model,
            context,
            &group,
            Some(retry_deadline),
        )?;
        busy_retries += retries as usize;
        for p in &preds {
            anyhow::ensure!(
                p.class < info.classes as usize,
                "class {} out of range",
                p.class
            );
            occupancy_sum += p.batch_occupancy as u64;
        }
        served += k;
        remaining -= k;
    }
    let wall = t0.elapsed();
    drop(idle_pool);
    println!(
        "client: {served} predictions round-tripped in {wall:?} \
         ({:.0} samp/s, mean engine occupancy {:.1}, {busy_retries} busy retries)",
        served as f64 / wall.as_secs_f64().max(1e-9),
        occupancy_sum as f64 / served.max(1) as f64,
    );
    if let Ok(snap) = net.metrics(&model) {
        if opts.get("metrics-json").map(String::as_str) == Some("true") {
            println!("{}", snap.to_json());
        } else {
            println!(
                "server metrics for {model}: {} served, {} engine batches, \
                 {} micro-batch flushes (mean coalesced {:.1})",
                snap.requests,
                snap.batches,
                snap.net_flushes,
                snap.mean_coalesced(),
            );
        }
    }
    if opts.get("shutdown").map(String::as_str) == Some("true") {
        net.shutdown_server()
            .map_err(|e| anyhow::anyhow!("shutdown: {e}"))?;
        println!("server acknowledged shutdown");
    }
    Ok(())
}

fn cmd_serve_bench(opts: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let models = parse_models(opts, "tiny,mnist_fc2");
    let workers: usize = opts.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let clients: usize = opts.get("clients").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let requests: usize = opts.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let wait_ms: u64 = opts.get("wait-ms").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let queue_depth: usize = opts.get("queue-depth").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let think_us: u64 = opts.get("think-us").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let burst: usize = opts.get("burst").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let contexts: usize = opts.get("contexts").map(|s| s.parse()).transpose()?.unwrap_or(1);
    anyhow::ensure!(contexts >= 1, "--contexts must be at least 1");
    let dir = artifacts_dir(opts);
    let load = LoadSpec {
        clients,
        requests,
        think_time: Duration::from_micros(think_us),
        burst,
        contexts,
    };
    let quant = parse_quant(opts, "quant")?;
    let act = parse_act(opts)?;
    let max_wait = Duration::from_millis(wait_ms);
    println!(
        "serve-bench: models {models:?}, {clients} clients x {requests} requests per model{}{}",
        match quant {
            Some(fmt) => format!(", fixed-point {fmt}"),
            None => String::new(),
        },
        match act {
            Some(a) => format!(", activation sparsity {a}"),
            None => String::new(),
        }
    );
    let sweep: Vec<usize> = if workers <= 1 { vec![1] } else { vec![1, workers] };
    let mut scenarios = Vec::new();
    for w in sweep {
        println!("-- {w} worker(s) per model --");
        let reports =
            loadgen::bench_service(&dir, &models, w, queue_depth, max_wait, &load, 7, quant, act)?;
        for r in &reports {
            r.print();
        }
        scenarios.push((w, reports));
    }
    if scenarios.len() == 2 {
        let t1: f64 = scenarios[0].1.iter().map(|r| r.throughput).sum();
        let tn: f64 = scenarios[1].1.iter().map(|r| r.throughput).sum();
        println!(
            "sustained throughput: {tn:.0} req/s with {workers} workers vs {t1:.0} req/s \
             single-worker ({:.2}X)",
            tn / t1.max(1e-9)
        );
    }
    if let Some(path) = opts.get("out") {
        let doc = loadgen::bench_json(&scenarios);
        loadgen::write_bench_json(path, doc)?;
        println!("wrote {path}");
    }
    Ok(())
}
