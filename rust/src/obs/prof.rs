//! Per-stage pipeline profiling: wall time and model cycles per FF/BP/UP
//! stage, per junction, per context.
//!
//! `nn::pipeline::PipelinedTrainer` owns a [`StageProf`] and, when
//! profiling is enabled (`train --profile`), stamps every op it executes.
//! Wall time comes from `Instant` pairs taken around the op closures;
//! model cycles use the paper's hardware cost model — a junction with `E`
//! edges and parallelism `z` spends `ceil(E / z)` clocks per op — so the
//! report shows both what the software pipeline measured and what the
//! accelerator schedule would charge. The disabled path takes zero
//! timestamps and is a no-op on [`record`](StageProf::record).
//!
//! Profiles are merged into the bench JSON writers (`BENCH_train.json`
//! gains a `profile` section) and printed as a table by the CLI.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::Json;

/// Pipeline stage kind, matching the paper's FF / BP / UP decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Feed-forward.
    Ff,
    /// Backpropagation.
    Bp,
    /// Weight update.
    Up,
}

impl Stage {
    /// All stages in display order.
    pub const ALL: [Stage; 3] = [Stage::Ff, Stage::Bp, Stage::Up];

    /// Short display label.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Ff => "ff",
            Stage::Bp => "bp",
            Stage::Up => "up",
        }
    }

    fn idx(self) -> usize {
        match self {
            Stage::Ff => 0,
            Stage::Bp => 1,
            Stage::Up => 2,
        }
    }
}

/// Accumulated cost of one stage at one junction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageAcc {
    /// Ops executed.
    pub ops: u64,
    /// Wall time summed over those ops, nanoseconds.
    pub wall_ns: u64,
}

/// Per-junction, per-stage profile for one pipelined trainer.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageProf {
    enabled: bool,
    cycles_per_op: Vec<u64>,
    acc: Vec<[StageAcc; 3]>,
}

impl StageProf {
    /// A profile for `cycles_per_op.len()` junctions; `cycles_per_op[j]`
    /// is the modelled clock cost `ceil(E_j / z_j)` of one op at junction
    /// `j+1`. When `enabled` is false every [`record`](StageProf::record)
    /// is a no-op.
    pub fn new(cycles_per_op: Vec<u64>, enabled: bool) -> Self {
        let n = cycles_per_op.len();
        StageProf { enabled, cycles_per_op, acc: vec![[StageAcc::default(); 3]; n] }
    }

    /// A permanently-disabled profile (no junctions).
    pub fn disabled() -> Self {
        StageProf::new(Vec::new(), false)
    }

    /// Whether recording is active — callers use this to skip taking
    /// timestamps entirely on the disabled path.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Number of junctions covered.
    pub fn junctions(&self) -> usize {
        self.acc.len()
    }

    /// Record one executed op. `junction` is 1-based (junction `i`
    /// connects layers `i-1` and `i`, matching the pipeline's numbering).
    pub fn record(&mut self, junction: usize, stage: Stage, wall: Duration) {
        if !self.enabled || junction == 0 || junction > self.acc.len() {
            return;
        }
        let a = &mut self.acc[junction - 1][stage.idx()];
        a.ops += 1;
        a.wall_ns += wall.as_nanos() as u64;
    }

    /// The accumulated cost of `stage` at 1-based `junction`.
    pub fn stage(&self, junction: usize, stage: Stage) -> StageAcc {
        if junction == 0 || junction > self.acc.len() {
            return StageAcc::default();
        }
        self.acc[junction - 1][stage.idx()]
    }

    /// Modelled clocks per op at 1-based `junction` (`ceil(E / z)`).
    pub fn cycles_per_op(&self, junction: usize) -> u64 {
        if junction == 0 || junction > self.cycles_per_op.len() {
            return 0;
        }
        self.cycles_per_op[junction - 1]
    }

    /// Total wall time across all junctions and stages.
    pub fn total_wall(&self) -> Duration {
        let ns: u64 = self.acc.iter().flatten().map(|a| a.wall_ns).sum();
        Duration::from_nanos(ns)
    }

    /// Total modelled clocks: `sum_j ops_j * ceil(E_j / z_j)`.
    pub fn total_cycles(&self) -> u64 {
        self.acc
            .iter()
            .zip(&self.cycles_per_op)
            .map(|(stages, cpo)| stages.iter().map(|a| a.ops).sum::<u64>() * cpo)
            .sum()
    }

    /// Fold another profile into this one (stage-wise sums). The junction
    /// geometry must match; extra junctions in `other` are appended. Used
    /// to aggregate per-context tenant profiles into a run total.
    pub fn merge(&mut self, other: &StageProf) {
        while self.acc.len() < other.acc.len() {
            self.acc.push([StageAcc::default(); 3]);
        }
        while self.cycles_per_op.len() < other.cycles_per_op.len() {
            let j = self.cycles_per_op.len();
            self.cycles_per_op.push(other.cycles_per_op[j]);
        }
        for (j, stages) in other.acc.iter().enumerate() {
            for (s, a) in stages.iter().enumerate() {
                self.acc[j][s].ops += a.ops;
                self.acc[j][s].wall_ns += a.wall_ns;
            }
        }
        self.enabled = self.enabled || other.enabled;
    }

    /// Human-readable per-junction table for `train --profile`.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>8} {:>10} {:>8} {:>10} {:>8} {:>10} {:>8} {:>10}\n",
            "junction", "clk/op", "ff ops", "ff wall", "bp ops", "bp wall", "up ops", "up wall"
        ));
        for j in 1..=self.junctions() {
            let [ff, bp, up] = self.acc[j - 1];
            out.push_str(&format!(
                "{:>8} {:>10} {:>8} {:>8.2}ms {:>8} {:>8.2}ms {:>8} {:>8.2}ms\n",
                j,
                self.cycles_per_op(j),
                ff.ops,
                ff.wall_ns as f64 / 1e6,
                bp.ops,
                bp.wall_ns as f64 / 1e6,
                up.ops,
                up.wall_ns as f64 / 1e6,
            ));
        }
        out.push_str(&format!(
            "total: {} ops, {:.2}ms wall, {} modelled clocks\n",
            self.acc.iter().flatten().map(|a| a.ops).sum::<u64>(),
            self.total_wall().as_secs_f64() * 1e3,
            self.total_cycles()
        ));
        out
    }

    /// JSON section for the bench writers:
    /// `{"junctions": [...], "total_wall_ms": .., "total_model_cycles": ..}`.
    pub fn to_json(&self) -> Json {
        let junctions = (1..=self.junctions())
            .map(|j| {
                let mut o = BTreeMap::new();
                o.insert("junction".into(), Json::Num(j as f64));
                o.insert("cycles_per_op".into(), Json::Num(self.cycles_per_op(j) as f64));
                for stage in Stage::ALL {
                    let a = self.stage(j, stage);
                    let mut so = BTreeMap::new();
                    so.insert("ops".into(), Json::Num(a.ops as f64));
                    so.insert("wall_ms".into(), Json::Num(a.wall_ns as f64 / 1e6));
                    so.insert(
                        "model_cycles".into(),
                        Json::Num((a.ops * self.cycles_per_op(j)) as f64),
                    );
                    o.insert(stage.label().into(), Json::Obj(so));
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("junctions".into(), Json::Arr(junctions));
        root.insert(
            "total_wall_ms".into(),
            Json::Num(self.total_wall().as_secs_f64() * 1e3),
        );
        root.insert("total_model_cycles".into(), Json::Num(self.total_cycles() as f64));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_record_is_noop() {
        let mut p = StageProf::disabled();
        p.record(1, Stage::Ff, Duration::from_millis(5));
        assert!(!p.enabled());
        assert_eq!(p.total_wall(), Duration::ZERO);
        assert_eq!(p.total_cycles(), 0);
    }

    #[test]
    fn record_accumulates_per_junction_and_stage() {
        let mut p = StageProf::new(vec![100, 10], true);
        p.record(1, Stage::Ff, Duration::from_micros(300));
        p.record(1, Stage::Ff, Duration::from_micros(200));
        p.record(1, Stage::Bp, Duration::from_micros(400));
        p.record(2, Stage::Up, Duration::from_micros(50));
        p.record(9, Stage::Up, Duration::from_micros(1)); // out of range: ignored
        p.record(0, Stage::Up, Duration::from_micros(1)); // junctions are 1-based

        assert_eq!(p.stage(1, Stage::Ff), StageAcc { ops: 2, wall_ns: 500_000 });
        assert_eq!(p.stage(1, Stage::Bp).ops, 1);
        assert_eq!(p.stage(2, Stage::Up).ops, 1);
        // 3 ops at 100 clk + 1 op at 10 clk.
        assert_eq!(p.total_cycles(), 310);
        assert_eq!(p.total_wall(), Duration::from_micros(950));
        let rep = p.report();
        assert!(rep.contains("junction"));
        assert!(rep.contains("310 modelled clocks"));
    }

    #[test]
    fn merge_sums_stagewise() {
        let mut a = StageProf::new(vec![100], true);
        a.record(1, Stage::Ff, Duration::from_micros(10));
        let mut b = StageProf::new(vec![100], true);
        b.record(1, Stage::Ff, Duration::from_micros(30));
        b.record(1, Stage::Up, Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.stage(1, Stage::Ff), StageAcc { ops: 2, wall_ns: 40_000 });
        assert_eq!(a.stage(1, Stage::Up).ops, 1);
        assert_eq!(a.total_cycles(), 300);
    }

    #[test]
    fn json_section_shape() {
        let mut p = StageProf::new(vec![64], true);
        p.record(1, Stage::Ff, Duration::from_micros(100));
        let doc = Json::parse(&p.to_json().to_string()).unwrap();
        let js = doc.get("junctions").unwrap().as_arr().unwrap();
        assert_eq!(js.len(), 1);
        let j0 = &js[0];
        assert_eq!(j0.get("junction").unwrap().as_usize(), Some(1));
        assert_eq!(j0.get("cycles_per_op").unwrap().as_usize(), Some(64));
        let ff = j0.get("ff").unwrap();
        assert_eq!(ff.get("ops").unwrap().as_usize(), Some(1));
        assert_eq!(ff.get("model_cycles").unwrap().as_usize(), Some(64));
        assert!(doc.get("total_wall_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("total_model_cycles").unwrap().as_usize(), Some(64));
    }
}
