//! Central metrics registry: one coherent snapshot over every subsystem.
//!
//! The hot paths keep their existing lock-free shape — plain `AtomicU64`
//! counters and the log-bucketed [`LatencyHistogram`] bumped with relaxed
//! ordering, sharded per worker/model where the subsystems already shard
//! them. The registry never sits on those paths. Instead each subsystem
//! registers a *collector* closure once at startup; [`Registry::snapshot`]
//! walks the collectors and merges whatever the shards hold right now into
//! a single typed [`Snapshot`]. Every consumer — the CLI metrics dump, the
//! wire `Metrics` frame, the load generators and bench JSON writers, and
//! the Prometheus-style text exposition — reads that one snapshot instead
//! of poking three ad-hoc structs.
//!
//! Collectors hold [`std::sync::Weak`] references to the subsystems they
//! observe (upgraded per snapshot), so registering a collector never
//! extends a subsystem's lifetime or blocks teardown.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// Lock-free latency histogram with power-of-two microsecond buckets.
///
/// Bucket `i` counts samples in `[2^i, 2^(i+1))` microseconds; sub-µs
/// samples clamp up to 1µs. 40 buckets cover ~12.7 days; samples beyond
/// the top bucket are still counted there *and* tallied in an explicit
/// [`overflow`](LatencyHistogram::overflow) counter so the tail is never
/// silently clamped. All operations are relaxed atomics — safe to share
/// across worker threads without locking.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    overflow: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    const BUCKETS: usize = 40;

    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..Self::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            overflow: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = (d.as_micros() as u64).max(1);
        let raw = us.ilog2() as usize;
        if raw >= Self::BUCKETS {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
        let idx = raw.min(Self::BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded (overflowed samples are included — they
    /// land in the top bucket as well as the overflow counter).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Samples that exceeded the top bucket (`>= 2^40` µs).
    pub fn overflow(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (bucket-wise + overflow sum).
    /// Used to aggregate per-worker / per-shard histograms at snapshot
    /// time; the operation is associative and commutative.
    pub fn merge(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.overflow
            .fetch_add(other.overflow.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Approximate quantile (upper bucket edge), `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << ((i as u32 + 1).min(63)));
            }
        }
        Duration::from_micros(1u64 << (Self::BUCKETS as u32))
    }

    /// The count/p50/p95/p99/overflow summary exported in snapshots.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            p50_us: self.quantile(0.50).as_micros() as u64,
            p95_us: self.quantile(0.95).as_micros() as u64,
            p99_us: self.quantile(0.99).as_micros() as u64,
            overflow: self.overflow(),
        }
    }
}

/// Point-in-time summary of a [`LatencyHistogram`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    /// Total samples.
    pub count: u64,
    /// Median, microseconds (upper bucket edge).
    pub p50_us: u64,
    /// 95th percentile, microseconds.
    pub p95_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Samples beyond the top bucket.
    pub overflow: u64,
}

/// The value carried by one [`Sample`].
#[derive(Clone, Debug, PartialEq)]
pub enum SampleValue {
    /// Monotonically increasing count.
    Counter(u64),
    /// Instantaneous value.
    Gauge(f64),
    /// Latency distribution summary.
    Histogram(HistSummary),
}

/// One named, labelled measurement in a [`Snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Dotted metric name, e.g. `serve.requests`.
    pub name: &'static str,
    /// Label pairs, e.g. `[("model", "tiny")]`. Keys come from the fixed
    /// vocabulary `model` / `context` / `worker` / `junction`.
    pub labels: Vec<(&'static str, String)>,
    /// The measurement.
    pub value: SampleValue,
}

impl Sample {
    /// A counter sample.
    pub fn counter(name: &'static str, labels: Vec<(&'static str, String)>, v: u64) -> Sample {
        Sample { name, labels, value: SampleValue::Counter(v) }
    }

    /// A gauge sample.
    pub fn gauge(name: &'static str, labels: Vec<(&'static str, String)>, v: f64) -> Sample {
        Sample { name, labels, value: SampleValue::Gauge(v) }
    }

    /// A histogram-summary sample.
    pub fn histogram(
        name: &'static str,
        labels: Vec<(&'static str, String)>,
        h: &LatencyHistogram,
    ) -> Sample {
        Sample { name, labels, value: SampleValue::Histogram(h.summary()) }
    }

    fn matches(&self, name: &str, labels: &[(&str, &str)]) -> bool {
        self.name == name
            && labels.iter().all(|(k, v)| {
                self.labels.iter().any(|(sk, sv)| sk == k && sv == v)
            })
    }
}

/// A collector contributes its subsystem's current samples to a snapshot.
pub type Collector = Box<dyn Fn(&mut Vec<Sample>) + Send + Sync>;

/// The registry: a list of collectors walked at snapshot time.
///
/// Registration happens once per subsystem at startup; the hot path never
/// touches the registry (see module docs).
#[derive(Default)]
pub struct Registry {
    collectors: Mutex<Vec<Collector>>,
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let n = lock_unpoisoned(&self.collectors).len();
        write!(f, "Registry({n} collectors)")
    }
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Register a collector. Collectors observing reference-counted
    /// subsystems should capture [`std::sync::Weak`] handles and upgrade
    /// per snapshot, so the registry never extends a subsystem's lifetime.
    pub fn register<F>(&self, f: F)
    where
        F: Fn(&mut Vec<Sample>) + Send + Sync + 'static,
    {
        lock_unpoisoned(&self.collectors).push(Box::new(f));
    }

    /// Number of registered collectors.
    pub fn collectors(&self) -> usize {
        lock_unpoisoned(&self.collectors).len()
    }

    /// Walk every collector and materialise one coherent snapshot.
    pub fn snapshot(&self) -> Snapshot {
        let mut samples = Vec::new();
        for c in lock_unpoisoned(&self.collectors).iter() {
            c(&mut samples);
        }
        samples.sort_by(|a, b| (a.name, &a.labels).cmp(&(b.name, &b.labels)));
        Snapshot { samples }
    }
}

/// A point-in-time view over every registered subsystem.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// All samples, sorted by `(name, labels)` for deterministic output.
    pub samples: Vec<Sample>,
}

impl Snapshot {
    fn find(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Sample> {
        self.samples.iter().find(|s| s.matches(name, labels))
    }

    /// Counter lookup by name + label subset (`None` if absent).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        match self.find(name, labels)?.value {
            SampleValue::Counter(v) => Some(v),
            _ => None,
        }
    }

    /// Gauge lookup by name + label subset (`None` if absent).
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        match self.find(name, labels)?.value {
            SampleValue::Gauge(v) => Some(v),
            _ => None,
        }
    }

    /// Histogram-summary lookup by name + label subset (`None` if absent).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<HistSummary> {
        match self.find(name, labels)?.value {
            SampleValue::Histogram(h) => Some(h),
            _ => None,
        }
    }

    /// Stable JSON exposition: `{"samples": [{name, labels, type, ...}]}`.
    pub fn to_json(&self) -> Json {
        let samples = self
            .samples
            .iter()
            .map(|s| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(s.name.into()));
                let labels = s
                    .labels
                    .iter()
                    .map(|(k, v)| ((*k).to_string(), Json::Str(v.clone())))
                    .collect();
                o.insert("labels".into(), Json::Obj(labels));
                match &s.value {
                    SampleValue::Counter(v) => {
                        o.insert("type".into(), Json::Str("counter".into()));
                        o.insert("value".into(), Json::Num(*v as f64));
                    }
                    SampleValue::Gauge(v) => {
                        o.insert("type".into(), Json::Str("gauge".into()));
                        o.insert("value".into(), Json::Num(*v));
                    }
                    SampleValue::Histogram(h) => {
                        o.insert("type".into(), Json::Str("histogram".into()));
                        o.insert("count".into(), Json::Num(h.count as f64));
                        o.insert("p50_us".into(), Json::Num(h.p50_us as f64));
                        o.insert("p95_us".into(), Json::Num(h.p95_us as f64));
                        o.insert("p99_us".into(), Json::Num(h.p99_us as f64));
                        o.insert("overflow".into(), Json::Num(h.overflow as f64));
                    }
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("samples".into(), Json::Arr(samples));
        Json::Obj(root)
    }

    /// Prometheus-style text exposition. Dots become underscores, one
    /// `# TYPE` line per metric name, histograms as `summary` quantile
    /// series plus `_count` and `_overflow` lines.
    pub fn to_prometheus(&self) -> String {
        fn mangled(name: &str) -> String {
            name.replace('.', "_")
        }
        fn label_str(labels: &[(&'static str, String)], extra: Option<(&str, &str)>) -> String {
            let mut parts: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            if let Some((k, v)) = extra {
                parts.push(format!("{k}=\"{v}\""));
            }
            if parts.is_empty() {
                String::new()
            } else {
                format!("{{{}}}", parts.join(","))
            }
        }
        let mut out = String::new();
        let mut typed: Vec<&'static str> = Vec::new();
        for s in &self.samples {
            let base = mangled(s.name);
            match &s.value {
                SampleValue::Counter(v) => {
                    if !typed.contains(&s.name) {
                        typed.push(s.name);
                        out.push_str(&format!("# TYPE {base} counter\n"));
                    }
                    out.push_str(&format!("{base}{} {v}\n", label_str(&s.labels, None)));
                }
                SampleValue::Gauge(v) => {
                    if !typed.contains(&s.name) {
                        typed.push(s.name);
                        out.push_str(&format!("# TYPE {base} gauge\n"));
                    }
                    out.push_str(&format!("{base}{} {v}\n", label_str(&s.labels, None)));
                }
                SampleValue::Histogram(h) => {
                    if !typed.contains(&s.name) {
                        typed.push(s.name);
                        out.push_str(&format!("# TYPE {base}_us summary\n"));
                    }
                    for (q, v) in [("0.5", h.p50_us), ("0.95", h.p95_us), ("0.99", h.p99_us)] {
                        out.push_str(&format!(
                            "{base}_us{} {v}\n",
                            label_str(&s.labels, Some(("quantile", q)))
                        ));
                    }
                    out.push_str(&format!(
                        "{base}_us_count{} {}\n",
                        label_str(&s.labels, None),
                        h.count
                    ));
                    out.push_str(&format!(
                        "{base}_us_overflow{} {}\n",
                        label_str(&s.labels, None),
                        h.overflow
                    ));
                }
            }
        }
        out
    }

    /// Human-readable report for the CLI metrics dump: one line per
    /// sample, `name{label=value} value`.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for s in &self.samples {
            let mut head = s.name.to_string();
            if !s.labels.is_empty() {
                let parts: Vec<String> =
                    s.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
                head.push('{');
                head.push_str(&parts.join(","));
                head.push('}');
            }
            match &s.value {
                SampleValue::Counter(v) => {
                    out.push_str(&format!("{head:<40} {v}\n"));
                }
                SampleValue::Gauge(v) => {
                    out.push_str(&format!("{head:<40} {v:.3}\n"));
                }
                SampleValue::Histogram(h) => {
                    out.push_str(&format!(
                        "{head:<40} n={} p50={}us p95={}us p99={}us overflow={}\n",
                        h.count, h.p50_us, h.p95_us, h.p99_us, h.overflow
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_quantiles_are_monotonic() {
        let h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1_000, 10_000] {
            for _ in 0..20 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 falls in the bucket holding the 100us samples: [64, 128) -> 128.
        assert_eq!(p50, Duration::from_micros(128));
        assert_eq!(h.quantile(1.0), Duration::from_micros(16_384));
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.summary(), HistSummary::default());
    }

    fn replay(samples: &[u64]) -> LatencyHistogram {
        let h = LatencyHistogram::new();
        for &us in samples {
            h.record(Duration::from_micros(us));
        }
        h
    }

    #[test]
    fn merge_is_associative() {
        let a: Vec<u64> = vec![1, 5, 900, 1 << 41];
        let b: Vec<u64> = vec![30, 30, 30, 1 << 45];
        let c: Vec<u64> = vec![2, 1 << 20];

        // (a ⊕ b) ⊕ c
        let left = replay(&a);
        left.merge(&replay(&b));
        left.merge(&replay(&c));
        // a ⊕ (b ⊕ c)
        let bc = replay(&b);
        bc.merge(&replay(&c));
        let right = replay(&a);
        right.merge(&bc);

        for i in 0..LatencyHistogram::BUCKETS {
            assert_eq!(
                left.buckets[i].load(Ordering::Relaxed),
                right.buckets[i].load(Ordering::Relaxed),
                "bucket {i} differs"
            );
        }
        assert_eq!(left.overflow(), right.overflow());
        assert_eq!(left.overflow(), 2); // the 2^41 and 2^45 µs samples
        assert_eq!(left.summary(), right.summary());
        // Merging matches recording everything in one histogram.
        let all: Vec<u64> = a.iter().chain(&b).chain(&c).copied().collect();
        assert_eq!(left.summary(), replay(&all).summary());
    }

    #[test]
    fn overflow_counts_tail_without_losing_samples() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_secs(20 * 24 * 3600)); // ~20 days > 2^40 µs
        assert_eq!(h.count(), 2, "overflowed sample still counted");
        assert_eq!(h.overflow(), 1);
        // The in-range sample keeps quantiles sane at the low end.
        assert_eq!(h.quantile(0.5), Duration::from_micros(128));
    }

    #[test]
    fn registry_snapshot_merges_collectors_and_looks_up_by_label() {
        let r = Registry::new();
        r.register(|out| {
            out.push(Sample::counter("serve.requests", vec![("model", "tiny".into())], 7));
            out.push(Sample::gauge("serve.occupancy_mean", vec![("model", "tiny".into())], 1.5));
        });
        r.register(|out| {
            out.push(Sample::counter("serve.requests", vec![("model", "big".into())], 9));
        });
        assert_eq!(r.collectors(), 2);
        let snap = r.snapshot();
        assert_eq!(snap.samples.len(), 3);
        assert_eq!(snap.counter("serve.requests", &[("model", "tiny")]), Some(7));
        assert_eq!(snap.counter("serve.requests", &[("model", "big")]), Some(9));
        assert_eq!(snap.gauge("serve.occupancy_mean", &[("model", "tiny")]), Some(1.5));
        assert_eq!(snap.counter("serve.requests", &[("model", "absent")]), None);
        assert_eq!(snap.counter("no.such.metric", &[]), None);
        // Empty label filter matches the first sample with that name.
        assert!(snap.counter("serve.requests", &[]).is_some());
    }

    #[test]
    fn snapshot_histogram_roundtrips_through_json() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(50));
        h.record(Duration::from_micros(500));
        let r = Registry::new();
        let summary = h.summary();
        r.register(move |out| {
            out.push(Sample { name: "serve.latency", labels: vec![("model", "tiny".into())], value: SampleValue::Histogram(summary) });
        });
        let snap = r.snapshot();
        assert_eq!(snap.histogram("serve.latency", &[("model", "tiny")]), Some(summary));
        let j = snap.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        let samples = parsed.get("samples").unwrap().as_arr().unwrap();
        assert_eq!(samples.len(), 1);
        let s = &samples[0];
        assert_eq!(s.get("name").unwrap().as_str(), Some("serve.latency"));
        assert_eq!(s.get("type").unwrap().as_str(), Some("histogram"));
        assert_eq!(s.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(
            s.get("labels").unwrap().get("model").unwrap().as_str(),
            Some("tiny")
        );
    }

    #[test]
    fn prometheus_exposition_has_type_lines_and_mangled_names() {
        let r = Registry::new();
        r.register(|out| {
            out.push(Sample::counter("net.requests", vec![], 3));
            out.push(Sample::counter("serve.requests", vec![("model", "tiny".into())], 7));
            let h = LatencyHistogram::new();
            h.record(Duration::from_micros(10));
            out.push(Sample::histogram("serve.latency", vec![("model", "tiny".into())], &h));
        });
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE net_requests counter\n"));
        assert!(text.contains("net_requests 3\n"));
        assert!(text.contains("serve_requests{model=\"tiny\"} 7\n"));
        assert!(text.contains("# TYPE serve_latency_us summary\n"));
        assert!(text.contains("serve_latency_us{model=\"tiny\",quantile=\"0.5\"} 16\n"));
        assert!(text.contains("serve_latency_us_count{model=\"tiny\"} 1\n"));
        assert!(text.contains("serve_latency_us_overflow{model=\"tiny\"} 0\n"));
        assert!(!text.contains('.'), "metric names must be mangled");
    }

    #[test]
    fn report_lists_every_sample() {
        let r = Registry::new();
        r.register(|out| {
            out.push(Sample::counter("serve.requests", vec![("model", "tiny".into())], 7));
            out.push(Sample::gauge("net.active_connections", vec![], 2.0));
        });
        let text = r.snapshot().report();
        assert!(text.contains("serve.requests{model=tiny}"));
        assert!(text.contains("net.active_connections"));
    }
}
