//! Unified observability layer: metrics registry, request tracing, and
//! per-stage pipeline profiling.
//!
//! Three pieces, one principle — instrumentation is compiled in but gated,
//! so the disabled path costs (at most) one branch:
//!
//! * [`registry`] — typed counters/gauges/histograms collected from every
//!   subsystem into one coherent [`registry::Snapshot`], with JSON and
//!   Prometheus-style exposition. Hot paths keep their lock-free atomics;
//!   the registry only walks collector closures at snapshot time.
//! * [`trace`] — sampled per-request spans (net → batcher → engine) with
//!   trace IDs minted at the net front door, echoed in v4 `Response`
//!   frames, and exportable as Chrome `trace_event` JSON.
//! * [`prof`] — per-junction FF/BP/UP stage profiles for the training
//!   pipeline, reporting both measured wall time and the paper's
//!   `ceil(E/z)` clock model.

pub mod prof;
pub mod registry;
pub mod trace;

pub use prof::{Stage, StageAcc, StageProf};
pub use registry::{HistSummary, LatencyHistogram, Registry, Sample, SampleValue, Snapshot};
pub use trace::{ReqTrace, Sampler, SpanEvent, TraceEcho, TraceSink};
