//! Sampled request tracing: span lifecycle from the net front door to the
//! engine worker, with Chrome `trace_event` export.
//!
//! Trace IDs are minted by a [`Sampler`] when a request enters the net
//! reactor (or supplied by the client in the v4 `Request` frame). A sampled
//! request carries a boxed [`ReqTrace`] through `MicroBatcher` coalescing
//! and the `InferenceService` shard queue to the engine worker, which
//! closes the trace and produces a [`TraceEcho`] — three durations (queue
//! wait, batch wait, execute) echoed back in the v4 `Response` frame so
//! clients can print a waterfall. The completed spans land in a bounded
//! [`TraceSink`] exportable as Chrome `trace_event` JSON
//! (`serve --trace-out PATH`, load it in `chrome://tracing` or Perfetto).
//!
//! Unsampled requests pay exactly one branch in [`Sampler::sample`] and
//! allocate nothing — [`TraceSink::handles_created`] counts every
//! [`ReqTrace`] ever built so tests can assert the zero-allocation path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// Per-request timing echoed in the v4 `Response` frame.
///
/// All durations are saturating microsecond casts (caps at ~71 minutes
/// per stage, far beyond any serving deadline).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceEcho {
    /// The trace ID minted at the front door (or supplied by the client).
    pub trace_id: u64,
    /// Time spent queued in the `MicroBatcher` before dispatch.
    pub queue_us: u32,
    /// Time spent in the engine shard waiting for a batch to fill.
    pub batch_us: u32,
    /// Forward-pass execution time (shared across the batch).
    pub execute_us: u32,
}

/// Deterministic 1-in-N request sampler; also mints trace IDs.
///
/// `every == 0` disables sampling entirely: the hot path is then a single
/// branch on a plain field — no atomics touched, nothing allocated. This
/// is the disabled-path cost bounded by the `serve_load` bench.
#[derive(Debug)]
pub struct Sampler {
    every: u64,
    counter: AtomicU64,
    next_id: AtomicU64,
}

impl Sampler {
    /// Sample every `every`-th request (0 = off).
    pub fn new(every: u64) -> Self {
        Sampler { every, counter: AtomicU64::new(0), next_id: AtomicU64::new(1) }
    }

    /// Sampling period (0 = off).
    pub fn every(&self) -> u64 {
        self.every
    }

    /// Decide whether to sample this request; returns a fresh trace ID
    /// when it is sampled.
    pub fn sample(&self) -> Option<u64> {
        if self.every == 0 {
            return None;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.every == 0 {
            Some(self.next_id.fetch_add(1, Ordering::Relaxed))
        } else {
            None
        }
    }
}

/// One completed span, timestamped in microseconds relative to the sink's
/// epoch (Chrome `trace_event` "X" form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// The owning request's trace ID.
    pub trace_id: u64,
    /// Stage name (`net`, `batcher`, `engine.wait`, `engine.exec`).
    pub name: &'static str,
    /// Category (`net`, `batcher`, `engine`).
    pub cat: &'static str,
    /// Start, µs since the sink epoch.
    pub start_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
    /// Thread lane for the Chrome view (0 = reactor, 1+w = engine worker).
    pub tid: u32,
}

/// Bounded collector for completed spans.
///
/// Spans beyond `cap` are dropped (counted in [`dropped`](TraceSink::dropped))
/// so a long-running server with aggressive sampling cannot grow without
/// bound. [`handles_created`](TraceSink::handles_created) counts every
/// [`ReqTrace`] allocation for the zero-allocation regression test.
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    events: Mutex<Vec<SpanEvent>>,
    cap: usize,
    dropped: AtomicU64,
    handles: AtomicU64,
}

impl TraceSink {
    /// Default span capacity (4 spans per traced request ≈ 16k requests).
    pub const DEFAULT_CAP: usize = 65_536;

    /// A sink holding at most `cap` spans.
    pub fn new(cap: usize) -> Self {
        TraceSink {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
            cap,
            dropped: AtomicU64::new(0),
            handles: AtomicU64::new(0),
        }
    }

    /// The instant all span timestamps are relative to.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Record a completed span from absolute instants.
    pub fn record(
        &self,
        trace_id: u64,
        name: &'static str,
        cat: &'static str,
        start: Instant,
        end: Instant,
        tid: u32,
    ) {
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        let ev = SpanEvent { trace_id, name, cat, start_us, dur_us, tid };
        let mut events = lock_unpoisoned(&self.events);
        if events.len() < self.cap {
            events.push(ev);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Spans recorded so far.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.events).len()
    }

    /// True when no spans have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped at the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total [`ReqTrace`] handles ever built against this sink — the
    /// tracing-allocation counter asserted by the unsampled-path test.
    pub fn handles_created(&self) -> u64 {
        self.handles.load(Ordering::Relaxed)
    }

    /// Snapshot of the recorded spans (test/report helper).
    pub fn events(&self) -> Vec<SpanEvent> {
        lock_unpoisoned(&self.events).clone()
    }

    /// Export as Chrome `trace_event` JSON:
    /// `{"traceEvents": [{name, cat, ph: "X", ts, dur, pid, tid, args}]}`.
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .events()
            .iter()
            .map(|ev| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(ev.name.into()));
                o.insert("cat".into(), Json::Str(ev.cat.into()));
                o.insert("ph".into(), Json::Str("X".into()));
                o.insert("ts".into(), Json::Num(ev.start_us as f64));
                o.insert("dur".into(), Json::Num(ev.dur_us as f64));
                o.insert("pid".into(), Json::Num(1.0));
                o.insert("tid".into(), Json::Num(f64::from(ev.tid)));
                let mut args = BTreeMap::new();
                args.insert("trace_id".into(), Json::Num(ev.trace_id as f64));
                o.insert("args".into(), Json::Obj(args));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("traceEvents".into(), Json::Arr(events));
        root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
        Json::Obj(root)
    }
}

/// The per-request trace baton carried (boxed, only when sampled) from the
/// net reactor through the batcher queue to the engine worker.
///
/// Lifecycle: [`ReqTrace::new`] at the front door → [`mark_enqueued`]
/// when the batcher queues it → [`mark_dispatched`] when the collector
/// submits it to the engine → [`finish`] on the worker with the batch's
/// execution window. `finish` records the `batcher` / `engine.wait` /
/// `engine.exec` spans and returns the [`TraceEcho`]; the reactor records
/// the enclosing `net` span itself when the response leaves.
///
/// [`mark_enqueued`]: ReqTrace::mark_enqueued
/// [`mark_dispatched`]: ReqTrace::mark_dispatched
/// [`finish`]: ReqTrace::finish
#[derive(Debug)]
pub struct ReqTrace {
    id: u64,
    sink: Arc<TraceSink>,
    t0: Instant,
    enqueued: Option<Instant>,
    dispatched: Option<Instant>,
}

fn span_us(a: Instant, b: Instant) -> u32 {
    let us = b.saturating_duration_since(a).as_micros();
    us.min(u128::from(u32::MAX)) as u32
}

impl ReqTrace {
    /// Open a trace minted at the front door (bumps the sink's handle
    /// counter; boxed because the baton rides inside request structs that
    /// stay small on the unsampled path).
    pub fn new(id: u64, sink: Arc<TraceSink>) -> Box<ReqTrace> {
        sink.handles.fetch_add(1, Ordering::Relaxed);
        Box::new(ReqTrace { id, sink, t0: Instant::now(), enqueued: None, dispatched: None })
    }

    /// The trace ID.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The instant the trace was opened (net receipt).
    pub fn t0(&self) -> Instant {
        self.t0
    }

    /// Stamp entry into the batcher queue.
    pub fn mark_enqueued(&mut self) {
        self.enqueued = Some(Instant::now());
    }

    /// Stamp dispatch out of the batcher into the engine shard.
    pub fn mark_dispatched(&mut self) {
        self.dispatched = Some(Instant::now());
    }

    /// Close the trace on the engine worker: record the batcher/engine
    /// spans and return the per-request echo. `exec_start`/`exec_end`
    /// bound the batch's forward pass; `worker` is the engine worker
    /// index (its Chrome lane is `1 + worker`).
    pub fn finish(self, exec_start: Instant, exec_end: Instant, worker: usize) -> TraceEcho {
        let enqueued = self.enqueued.unwrap_or(self.t0);
        let dispatched = self.dispatched.unwrap_or(enqueued);
        self.sink.record(self.id, "batcher", "batcher", enqueued, dispatched, 0);
        self.sink.record(self.id, "engine.wait", "engine", dispatched, exec_start, 0);
        let lane = 1 + worker.min(u32::MAX as usize - 1) as u32;
        self.sink.record(self.id, "engine.exec", "engine", exec_start, exec_end, lane);
        TraceEcho {
            trace_id: self.id,
            queue_us: span_us(enqueued, dispatched),
            batch_us: span_us(dispatched, exec_start),
            execute_us: span_us(exec_start, exec_end),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_sampler_never_samples() {
        let s = Sampler::new(0);
        for _ in 0..1000 {
            assert_eq!(s.sample(), None);
        }
    }

    #[test]
    fn every_1_samples_all_with_unique_ids() {
        let s = Sampler::new(1);
        let ids: Vec<u64> = (0..10).map(|_| s.sample().expect("every=1 samples all")).collect();
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids, dedup);
        assert_eq!(ids[0], 1);
    }

    #[test]
    fn every_n_samples_one_in_n() {
        let s = Sampler::new(4);
        let hits = (0..100).filter(|_| s.sample().is_some()).count();
        assert_eq!(hits, 25);
    }

    #[test]
    fn finish_produces_nonnegative_echo_and_three_spans() {
        let sink = Arc::new(TraceSink::new(16));
        let mut tr = ReqTrace::new(42, Arc::clone(&sink));
        assert_eq!(sink.handles_created(), 1);
        tr.mark_enqueued();
        tr.mark_dispatched();
        let exec_start = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let exec_end = Instant::now();
        let echo = tr.finish(exec_start, exec_end, 3);
        assert_eq!(echo.trace_id, 42);
        assert!(echo.execute_us >= 1_000, "slept 2ms, got {}us", echo.execute_us);
        let evs = sink.events();
        assert_eq!(evs.len(), 3);
        let names: Vec<&str> = evs.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["batcher", "engine.wait", "engine.exec"]);
        assert!(evs.iter().all(|e| e.trace_id == 42));
        assert_eq!(evs[2].tid, 4);
        // Spans nest in order with non-negative extents.
        assert!(evs[0].start_us + evs[0].dur_us <= evs[1].start_us + evs[1].dur_us + 1);
        assert!(evs[1].start_us <= evs[2].start_us);
    }

    #[test]
    fn sink_cap_drops_beyond_capacity() {
        let sink = TraceSink::new(2);
        let t = Instant::now();
        for i in 0..5 {
            sink.record(i, "net", "net", t, t, 0);
        }
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.dropped(), 3);
    }

    #[test]
    fn chrome_export_shape() {
        let sink = TraceSink::new(8);
        let t0 = sink.epoch();
        sink.record(7, "net", "net", t0, t0 + Duration::from_micros(250), 0);
        let doc = Json::parse(&sink.to_chrome_json().to_string()).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        let ev = &evs[0];
        assert_eq!(ev.get("name").unwrap().as_str(), Some("net"));
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(ev.get("dur").unwrap().as_usize(), Some(250));
        assert_eq!(ev.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(
            ev.get("args").unwrap().get("trace_id").unwrap().as_usize(),
            Some(7)
        );
    }
}
