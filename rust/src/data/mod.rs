//! Synthetic surrogate datasets (DESIGN.md §Substitutions).
//!
//! The paper's Sec. IV trends are driven by *feature redundancy*: inputs
//! are generated from a low-dimensional class-conditional latent embedded
//! into a higher-dimensional feature space. The redundancy knob is the
//! `features / latent_dim` ratio — MNIST-784 is highly redundant, its
//! PCA-200 variant less so, TIMIT-13 least. Per-dataset shaping mimics
//! each corpus' feature statistics (pixel-like, log(1+count) token-like,
//! MFCC-like, CNN-feature-like).

use crate::util::rng::Rng;

/// A labelled dataset: row-major features `[n, features]`, integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Features, row-major `[n, features]`.
    pub x: Vec<f32>,
    /// Integer class labels, one per sample.
    pub y: Vec<i32>,
    /// Sample count.
    pub n: usize,
    /// Feature dimension.
    pub features: usize,
    /// Number of classes.
    pub classes: usize,
}

/// Train/validation/test split.
#[derive(Clone, Debug)]
pub struct Splits {
    /// Training split.
    pub train: Dataset,
    /// Validation split.
    pub val: Dataset,
    /// Test split.
    pub test: Dataset,
}

/// Feature shaping applied on top of the latent projection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shaping {
    /// Pixel-like: values clipped to [0, 1], many exactly-zero entries.
    Pixels,
    /// Token-count-like: log(1 + count) of non-negative quantized counts.
    LogCounts,
    /// Continuous cepstral-like: zero-mean standardized features.
    Continuous,
    /// CNN-feature-like: ReLU of a (deep or shallow) random feature net.
    CnnFeatures { deep: bool },
}

/// Generator specification. `latent_dim` relative to `features` sets the
/// redundancy (`features >> latent_dim` = high redundancy).
#[derive(Clone, Debug)]
pub struct Spec {
    /// Label used in experiment printouts.
    pub name: &'static str,
    /// Feature dimension of generated samples.
    pub features: usize,
    /// Number of classes.
    pub classes: usize,
    /// Dimension of the class-conditional latent.
    pub latent_dim: usize,
    /// Per-dataset feature shaping.
    pub shaping: Shaping,
    /// Class-center separation relative to within-class noise; larger is
    /// an easier problem.
    pub separation: f32,
    /// Per-feature observation noise.
    pub noise: f32,
}

impl Spec {
    /// MNIST surrogate: 784 pixel-like features (padded to 800 with
    /// always-zero features like the paper's footnote 8), 10 classes.
    pub fn mnist_like() -> Spec {
        Spec {
            name: "mnist-like",
            features: 800,
            classes: 10,
            latent_dim: 24,
            shaping: Shaping::Pixels,
            separation: 0.7,
            noise: 0.5,
        }
    }

    /// The reduced-redundancy MNIST variant of Sec. IV-C (PCA to 200).
    pub fn mnist_like_pca200() -> Spec {
        Spec {
            name: "mnist-like-pca200",
            features: 200,
            classes: 10,
            latent_dim: 24,
            shaping: Shaping::Continuous,
            separation: 0.7,
            noise: 0.5,
        }
    }

    /// Reuters RCV1 surrogate: 2000 log(1+count) token features, 50 topics.
    pub fn reuters_like() -> Spec {
        Spec {
            name: "reuters-like",
            features: 2000,
            classes: 50,
            latent_dim: 64,
            shaping: Shaping::LogCounts,
            separation: 2.5,
            noise: 0.5,
        }
    }

    /// Reduced-redundancy Reuters (400 most frequent tokens, Sec. IV-C).
    pub fn reuters_like_400() -> Spec {
        Spec {
            name: "reuters-like-400",
            features: 400,
            classes: 50,
            latent_dim: 64,
            shaping: Shaping::LogCounts,
            separation: 2.5,
            noise: 0.5,
        }
    }

    /// TIMIT surrogate: `mfcc` cepstral features (13 / 39 / 117 in
    /// Sec. IV-C), 39 phoneme classes. Latent dim fixed at 12 so 13
    /// MFCCs carry almost no redundancy while 117 carry plenty.
    pub fn timit_like(mfcc: usize) -> Spec {
        Spec {
            name: "timit-like",
            features: mfcc,
            classes: 39,
            latent_dim: 12,
            shaping: Shaping::Continuous,
            separation: 1.6,
            noise: 0.8,
        }
    }

    /// CIFAR-100 MLP-head surrogate: 4000 CNN features, 100 classes;
    /// `deep` mirrors the 6-conv-layer front end, `!deep` the single-layer
    /// reduced-redundancy variant of Sec. IV-C.
    pub fn cifar_features_like(deep: bool) -> Spec {
        Spec {
            name: if deep { "cifar-like" } else { "cifar-like-shallow" },
            features: 4000,
            classes: 100,
            latent_dim: if deep { 96 } else { 48 },
            shaping: Shaping::CnnFeatures { deep },
            separation: if deep { 2.8 } else { 1.8 },
            noise: if deep { 0.4 } else { 0.9 },
        }
    }

    /// Redundancy ratio features / latent_dim (Sec. IV-C knob).
    pub fn redundancy(&self) -> f64 {
        self.features as f64 / self.latent_dim as f64
    }

    /// Generate `n` samples.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Dataset {
        let r = self.latent_dim;
        let d = self.features;
        // fixed class centers and projection for this generator draw
        let centers: Vec<f32> = (0..self.classes * r)
            .map(|_| rng.normal() * self.separation)
            .collect();
        let proj: Vec<f32> = (0..d * r)
            .map(|_| rng.normal() / (r as f32).sqrt())
            .collect();
        // second mixing stage for the deep CNN-feature shaping
        let hidden_dim = 64usize;
        let proj2: Vec<f32> = match self.shaping {
            Shaping::CnnFeatures { deep: true } => (0..d * hidden_dim)
                .map(|_| rng.normal() / (hidden_dim as f32).sqrt())
                .collect(),
            _ => Vec::new(),
        };

        let mut x = vec![0f32; n * d];
        let mut y = vec![0i32; n];
        let mut latent = vec![0f32; r];
        for i in 0..n {
            let c = rng.below(self.classes);
            y[i] = c as i32;
            for (j, l) in latent.iter_mut().enumerate() {
                *l = centers[c * r + j] + rng.normal();
            }
            let row = &mut x[i * d..(i + 1) * d];
            for (f, out) in row.iter_mut().enumerate() {
                let mut v = 0f32;
                for (j, l) in latent.iter().enumerate() {
                    v += proj[f * r + j] * l;
                }
                *out = v + rng.normal() * self.noise;
            }
            self.shape_row(row, &proj2, hidden_dim);
        }
        Dataset {
            x,
            y,
            n,
            features: d,
            classes: self.classes,
        }
    }

    fn shape_row(&self, row: &mut [f32], proj2: &[f32], hidden_dim: usize) {
        match self.shaping {
            Shaping::Pixels => {
                for v in row.iter_mut() {
                    // shift so a large fraction of pixels clamp to exactly
                    // zero, like handwritten-digit rasters
                    *v = (*v - 0.3).clamp(0.0, 3.0) / 3.0;
                }
            }
            Shaping::LogCounts => {
                for v in row.iter_mut() {
                    let count = (v.max(0.0) * 2.0).floor();
                    *v = (1.0 + count).ln();
                }
            }
            Shaping::Continuous => {}
            Shaping::CnnFeatures { deep } => {
                if deep && !proj2.is_empty() {
                    // extra nonlinear mixing = richer, more redundant
                    // features (the deep CNN "eases the burden of the MLP")
                    let hidden: Vec<f32> =
                        row.iter().take(hidden_dim).map(|v| v.max(0.0)).collect();
                    for (f, v) in row.iter_mut().enumerate() {
                        let mut acc = *v;
                        for (j, h) in hidden.iter().enumerate() {
                            acc += proj2[f * hidden_dim + j] * h;
                        }
                        *v = acc.max(0.0);
                    }
                } else {
                    for v in row.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
            }
        }
    }

    /// Generate standard train/val/test splits from one generator draw
    /// (fixed centers/projection), so all splits share the distribution.
    pub fn splits(&self, n_train: usize, n_val: usize, n_test: usize, seed: u64) -> Splits {
        let mut rng = Rng::new(seed);
        let all = self.generate(n_train + n_val + n_test, &mut rng);
        let slice = |lo: usize, hi: usize| Dataset {
            x: all.x[lo * self.features..hi * self.features].to_vec(),
            y: all.y[lo..hi].to_vec(),
            n: hi - lo,
            features: self.features,
            classes: self.classes,
        };
        Splits {
            train: slice(0, n_train),
            val: slice(n_train, n_train + n_val),
            test: slice(n_train + n_val, n_train + n_val + n_test),
        }
    }
}

impl Dataset {
    /// Row i as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.features..(i + 1) * self.features]
    }

    /// Per-feature variance (the §V-A attention signal).
    pub fn feature_variances(&self) -> Vec<f32> {
        let mut mean = vec![0f64; self.features];
        for i in 0..self.n {
            for (f, m) in mean.iter_mut().enumerate() {
                *m += self.x[i * self.features + f] as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= self.n as f64;
        }
        let mut var = vec![0f64; self.features];
        for i in 0..self.n {
            for (f, v) in var.iter_mut().enumerate() {
                let d = self.x[i * self.features + f] as f64 - mean[f];
                *v += d * d;
            }
        }
        var.iter().map(|v| (*v / self.n as f64) as f32).collect()
    }

    /// Minibatch (x, y) gather for the given sample indices.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.features);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Rng::new(0);
        let ds = Spec::mnist_like().generate(64, &mut rng);
        assert_eq!(ds.x.len(), 64 * 800);
        assert_eq!(ds.y.len(), 64);
        assert!(ds.y.iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn pixel_shaping_in_unit_range_with_zeros() {
        let mut rng = Rng::new(1);
        let ds = Spec::mnist_like().generate(32, &mut rng);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let zeros = ds.x.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros as f64 > ds.x.len() as f64 * 0.2, "{zeros} zeros");
    }

    #[test]
    fn log_counts_nonnegative() {
        let mut rng = Rng::new(2);
        let ds = Spec::reuters_like_400().generate(16, &mut rng);
        assert!(ds.x.iter().all(|&v| v >= 0.0));
        // log(1+x) of integer counts: exp(v)-1 should be integral
        for &v in ds.x.iter().take(100) {
            let c = (v.exp() - 1.0).round();
            assert!((v - (1.0 + c).ln()).abs() < 1e-4);
        }
    }

    #[test]
    fn classes_are_separable_in_latent_space() {
        // nearest-class-center classification on raw features should beat
        // chance by a wide margin (sanity: the problem is learnable)
        let mut rng = Rng::new(3);
        let spec = Spec::timit_like(39);
        let ds = spec.generate(800, &mut rng);
        let mut proto = vec![0f32; spec.classes * spec.features];
        let mut count = vec![0f32; spec.classes];
        for i in 0..ds.n {
            let c = ds.y[i] as usize;
            count[c] += 1.0;
            for f in 0..spec.features {
                proto[c * spec.features + f] += ds.row(i)[f];
            }
        }
        for c in 0..spec.classes {
            for f in 0..spec.features {
                proto[c * spec.features + f] /= count[c].max(1.0);
            }
        }
        let mut correct = 0;
        for i in 0..ds.n {
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..spec.classes {
                let d: f32 = ds
                    .row(i)
                    .iter()
                    .zip(&proto[c * spec.features..(c + 1) * spec.features])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 0.30, "nearest-prototype acc {acc} (chance = 0.026)");
    }

    #[test]
    fn splits_are_disjoint_same_distribution() {
        let s = Spec::mnist_like_pca200().splits(100, 20, 30, 7);
        assert_eq!(s.train.n, 100);
        assert_eq!(s.val.n, 20);
        assert_eq!(s.test.n, 30);
        assert_ne!(s.train.x[..200], s.test.x[..200]);
    }

    #[test]
    fn redundancy_ordering_matches_paper_variants() {
        assert!(Spec::mnist_like().redundancy() > Spec::mnist_like_pca200().redundancy());
        assert!(Spec::reuters_like().redundancy() > Spec::reuters_like_400().redundancy());
        assert!(Spec::timit_like(117).redundancy() > Spec::timit_like(39).redundancy());
        assert!(Spec::timit_like(39).redundancy() > Spec::timit_like(13).redundancy());
    }

    #[test]
    fn feature_variances_and_gather() {
        let mut rng = Rng::new(4);
        let ds = Spec::timit_like(13).generate(50, &mut rng);
        let v = ds.feature_variances();
        assert_eq!(v.len(), 13);
        assert!(v.iter().all(|&x| x > 0.0));
        let (bx, by) = ds.gather(&[0, 49, 7]);
        assert_eq!(bx.len(), 3 * 13);
        assert_eq!(by, vec![ds.y[0], ds.y[49], ds.y[7]]);
        assert_eq!(&bx[13..26], ds.row(49));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Spec::mnist_like().splits(10, 5, 5, 42);
        let b = Spec::mnist_like().splits(10, 5, 5, 42);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.test.y, b.test.y);
    }
}
