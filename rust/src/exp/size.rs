//! Figs. 9-11: 'large and sparse' vs 'small and dense' at matched
//! trainable-parameter budgets.

use super::common::{fmt_acc, run_on_splits, Approach, Scale};
use crate::data::Spec;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::util::{ci90, mean};

/// Find the admissible d_out config whose parameter count best matches
/// `budget`, scaling all junctions except the final one (kept FC for the
/// MNIST experiments, per Fig. 9's caption).
fn dout_for_budget(netc: &NetConfig, budget: usize, final_fc: bool) -> Option<DoutConfig> {
    let l = netc.n_junctions();
    let mut best: Option<(usize, DoutConfig)> = None;
    // scan multiples of each junction's min d_out jointly by a density knob
    for k in 1..=100 {
        let rho = k as f64 / 100.0;
        let dout = DoutConfig(
            (0..l)
                .map(|i| {
                    if final_fc && i == l - 1 {
                        netc.layers[i + 1]
                    } else {
                        netc.junction(i).dout_for_density(rho)
                    }
                })
                .collect(),
        );
        if netc.validate_dout(&dout).is_err() {
            continue;
        }
        let params = netc.trainable_params(&dout);
        let gap = params.abs_diff(budget);
        if best.as_ref().map(|(g, _)| gap < *g).unwrap_or(true) {
            best = Some((gap, dout));
        }
    }
    best.map(|(_, d)| d)
}

fn acc(spec: &Spec, layers: &[usize], dout: Option<&DoutConfig>, scale: &Scale) -> (f32, f32) {
    let sc = scale.for_spec(spec);
    let accs: Vec<f32> = (0..sc.repeats)
        .map(|r| {
            let splits = spec.splits(sc.n_train, 0, sc.n_test, 12000 + r as u64);
            let approach = if dout.is_some() {
                Approach::Structured
            } else {
                Approach::Fc
            };
            run_on_splits(&splits, layers, dout, approach, &sc, 17 * (r as u64 + 1)) as f32 * 100.0
        })
        .collect();
    (mean(&accs), ci90(&accs))
}

fn run_budget_table(
    title: &str,
    spec: &Spec,
    hidden_sizes: &[usize],
    make_layers: impl Fn(usize) -> Vec<usize>,
    budget: usize,
    final_fc: bool,
    scale: &Scale,
) {
    println!("\n{title} — equal trainable-parameter budget ≈ {budget}");
    println!(
        "{:>22} {:>10} {:>9} {:>14}",
        "N_net", "params", "rho_net%", "acc"
    );
    for &x in hidden_sizes {
        let layers = make_layers(x);
        let netc = NetConfig::new(layers.clone());
        let fc_params = netc.trainable_params(&netc.fc_dout());
        let (dout, params, rho) = if fc_params <= budget {
            // small net: run FC (densest point on its curve)
            (None, fc_params, 1.0)
        } else {
            match dout_for_budget(&netc, budget, final_fc) {
                Some(d) => {
                    let p = netc.trainable_params(&d);
                    let r = netc.rho_net(&d);
                    (Some(d), p, r)
                }
                None => continue,
            }
        };
        let (m, ci) = acc(spec, &layers, dout.as_ref(), scale);
        println!(
            "{:>22} {:>10} {:>9.1} {:>14}",
            format!("{layers:?}"),
            params,
            rho * 100.0,
            fmt_acc(m, ci)
        );
    }
    println!("(paper: larger-and-sparser wins until a junction falls below its critical density)");
}

/// Fig. 9: MNIST, one and three hidden layers.
pub fn run_fig9(scale: &Scale) {
    let spec = Spec::mnist_like();
    run_budget_table(
        "Fig. 9(a) mnist-like, N_net = (800, x, 10)",
        &spec,
        &[14, 28, 56, 112],
        |x| vec![800, x, 10],
        11_500,
        true,
        scale,
    );
    run_budget_table(
        "Fig. 9(b) mnist-like, N_net = (800, x, x, x, 10)",
        &spec,
        &[14, 28, 56, 112],
        |x| vec![800, x, x, x, 10],
        11_500,
        true,
        scale,
    );
}

/// Fig. 10: Reuters, N_net = (2000, x, 50).
pub fn run_fig10(scale: &Scale) {
    let spec = Spec::reuters_like();
    run_budget_table(
        "Fig. 10 reuters-like, N_net = (2000, x, 50)",
        &spec,
        &[10, 20, 50, 100],
        |x| vec![2000, x, 50],
        25_000,
        false,
        scale,
    );
}

/// Fig. 11: TIMIT 4-hidden-layer and the CIFAR MLP head.
pub fn run_fig11(scale: &Scale) {
    let timit = Spec::timit_like(39);
    run_budget_table(
        "Fig. 11(a) timit-like, N_net = (39, x, x, x, x, 39)",
        &timit,
        &[50, 100, 200, 390],
        |x| vec![39, x, x, x, x, 39],
        30_000,
        false,
        scale,
    );
    let cifar = Spec::cifar_features_like(true);
    run_budget_table(
        "Fig. 11(b) cifar-like MLP head, N_net = (4000, x, 100)",
        &cifar,
        &[50, 125, 250, 500],
        |x| vec![4000, x, 100],
        60_000,
        false,
        scale,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_matching_is_close() {
        let netc = NetConfig::new(vec![800, 112, 112, 112, 10]);
        let d = dout_for_budget(&netc, 11_500, true).unwrap();
        let p = netc.trainable_params(&d);
        assert!(
            (p as f64 - 11_500.0).abs() / 11_500.0 < 0.35,
            "params {p} far from budget"
        );
        // final junction kept FC
        assert_eq!(*d.0.last().unwrap(), 10);
    }
}
