//! Fig. 12: clash-free pre-defined sparsity vs the §V baselines —
//! attention-based preprocessed sparsity and LSS (learning structured
//! sparsity during FC training + magnitude pruning).

use super::common::{dout_for_rho_net, fmt_acc, run_on_splits, Approach, Scale};
use crate::data::Spec;
use crate::sparsity::config::NetConfig;
use crate::util::{ci90, mean};

/// Print the Fig. 12 comparison (clash-free vs attention vs LSS).
pub fn run(scale: &Scale) {
    let cases: Vec<(Spec, Vec<usize>)> = vec![
        (Spec::mnist_like(), vec![800, 100, 10]),
        (Spec::reuters_like(), vec![2000, 50, 50]),
        (Spec::timit_like(39), vec![39, 390, 39]),
    ];
    let rhos = [0.5, 0.2, 0.05];
    for (spec, layers) in cases {
        let netc = NetConfig::new(layers.clone());
        println!("\nFig. 12 — {} N_net = {layers:?}", spec.name);
        println!(
            "{:>9} {:>14} {:>14} {:>14}",
            "rho_net%", "clash-free", "attention", "LSS"
        );
        for &rho in &rhos {
            let dout = dout_for_rho_net(&netc, rho);
            if netc.validate_dout(&dout).is_err() {
                continue;
            }
            let mut cells = Vec::new();
            for approach in [Approach::ClashFree, Approach::Attention, Approach::Lss] {
                let sc = scale.for_spec(&spec);
                let accs: Vec<f32> = (0..sc.repeats)
                    .map(|r| {
                        let splits = spec.splits(sc.n_train, 0, sc.n_test, 15000 + r as u64);
                        run_on_splits(&splits, &layers, Some(&dout), approach, &sc, 53 * (r as u64 + 1))
                            as f32
                            * 100.0
                    })
                    .collect();
                cells.push(fmt_acc(mean(&accs), ci90(&accs)));
            }
            println!(
                "{:>9.1} {:>14} {:>14} {:>14}",
                netc.rho_net(&dout) * 100.0,
                cells[0],
                cells[1],
                cells[2]
            );
        }
    }
    println!("\n(paper: LSS best — least constrained — but clash-free within ~2% at rho_net = 20%)");
}
