//! Density sweeps: Fig. 6 (dataset redundancy), Fig. 7 (junction-density
//! allocation on redundant datasets), Fig. 8 (the trend reversal on
//! low-redundancy TIMIT variants and Reuters-400).

use super::common::{dout_for_rho_net, fmt_acc, run_on_splits, Approach, Scale};
use crate::data::Spec;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::util::{ci90, mean};

fn sweep_row(
    spec: &Spec,
    layers: &[usize],
    dout: Option<&DoutConfig>,
    scale: &Scale,
) -> (f32, f32) {
    let sc = scale.for_spec(spec);
    let accs: Vec<f32> = (0..sc.repeats)
        .map(|r| {
            let splits = spec.splits(sc.n_train, 0, sc.n_test, 9000 + r as u64);
            let approach = if dout.is_some() {
                Approach::ClashFree
            } else {
                Approach::Fc
            };
            run_on_splits(&splits, layers, dout, approach, &sc, 31 * (r as u64 + 1)) as f32 * 100.0
        })
        .collect();
    (mean(&accs), ci90(&accs))
}

/// Fig. 6: accuracy vs rho_net for original vs redundancy-modified specs.
pub fn run_fig6(scale: &Scale) {
    let pairs: Vec<(Vec<usize>, Vec<Spec>)> = vec![
        (vec![800, 100, 10], vec![Spec::mnist_like()]),
        (vec![200, 100, 10], vec![Spec::mnist_like_pca200()]),
        (vec![2000, 50, 50], vec![Spec::reuters_like()]),
        (vec![400, 50, 50], vec![Spec::reuters_like_400()]),
        (vec![39, 390, 39], vec![Spec::timit_like(39)]),
        (vec![13, 390, 39], vec![Spec::timit_like(13)]),
        (vec![117, 390, 39], vec![Spec::timit_like(117)]),
    ];
    println!("Fig. 6 — accuracy vs rho_net, original vs reduced/increased redundancy");
    let rhos = [1.0, 0.5, 0.2, 0.1, 0.05];
    for (layers, specs) in pairs {
        for spec in specs {
            let netc = NetConfig::new(layers.clone());
            print!(
                "{:<22} (redund {:>5.1}):",
                spec.name,
                spec.redundancy()
            );
            for &rho in &rhos {
                let dout = (rho < 1.0).then(|| dout_for_rho_net(&netc, rho));
                let (m, _) = sweep_row(&spec, &layers, dout.as_ref(), scale);
                print!("  rho{:>3.0}%={m:>5.1}", rho * 100.0);
            }
            println!();
        }
    }
    println!("(paper: less redundant variants degrade more sharply as rho_net falls)");
}

/// Fig. 7: fixed rho_2 curves — reducing rho_net via junction 1 only.
pub fn run_fig7(scale: &Scale) {
    let cases: Vec<(Spec, Vec<usize>)> = vec![
        (Spec::mnist_like(), vec![800, 100, 10]),
        (Spec::reuters_like(), vec![2000, 50, 50]),
    ];
    println!("Fig. 7 — junction density allocation (rho_2 fixed per curve, rho_1 varies)");
    for (spec, layers) in cases {
        let netc = NetConfig::new(layers.clone());
        let n2 = *layers.last().unwrap();
        println!("\n{} N_net = {layers:?}", spec.name);
        println!("{:>8} {:>8} {:>9} {:>14}", "rho_1%", "rho_2%", "rho_net%", "acc");
        for rho2 in [1.0, 0.5, 0.1] {
            let d2 = netc.junction(1).dout_for_density(rho2).max(netc.junction(1).min_dout());
            for rho1 in [0.5, 0.1, 0.02] {
                let d1 = netc.junction(0).dout_for_density(rho1);
                let dout = DoutConfig(vec![d1, d2]);
                if netc.validate_dout(&dout).is_err() {
                    continue;
                }
                let (m, ci) = sweep_row(&spec, &layers, Some(&dout), scale);
                println!(
                    "{:>8.1} {:>8.1} {:>9.1} {:>14}",
                    100.0 * d1 as f64 / layers[1] as f64,
                    100.0 * d2 as f64 / n2 as f64,
                    netc.rho_net(&dout) * 100.0,
                    fmt_acc(m, ci)
                );
            }
        }
        println!("(paper: at equal rho_net, higher rho_2 wins on redundant datasets)");
    }
}

/// Fig. 8: TIMIT feature-size variants + Reuters-400 — where the
/// junction-density trend reverses.
pub fn run_fig8(scale: &Scale) {
    println!("Fig. 8 — low-redundancy variants: junction-1 density matters more");
    for (spec, layers) in [
        (Spec::timit_like(13), vec![13usize, 390, 39]),
        (Spec::timit_like(39), vec![39, 390, 39]),
        (Spec::timit_like(117), vec![117, 390, 39]),
        (Spec::reuters_like_400(), vec![400, 50, 50]),
    ] {
        let netc = NetConfig::new(layers.clone());
        println!("\n{} ({} features) N_net = {layers:?}", spec.name, layers[0]);
        println!("{:>8} {:>8} {:>9} {:>14}", "rho_1%", "rho_2%", "rho_net%", "acc");
        // complementary allocations at matched rho_net
        for (rho1, rho2) in [(0.5, 0.05), (0.05, 0.5), (0.25, 0.25)] {
            let d1 = netc.junction(0).dout_for_density(rho1);
            let d2 = netc.junction(1).dout_for_density(rho2);
            let dout = DoutConfig(vec![d1, d2]);
            if netc.validate_dout(&dout).is_err() {
                continue;
            }
            let (m, ci) = sweep_row(&spec, &layers, Some(&dout), scale);
            println!(
                "{:>8.1} {:>8.1} {:>9.1} {:>14}",
                100.0 * d1 as f64 / layers[1] as f64,
                100.0 * d2 as f64 / layers[2] as f64,
                netc.rho_net(&dout) * 100.0,
                fmt_acc(m, ci)
            );
        }
    }
    println!("(paper: with few input features, starving junction 1 hurts more than starving junction 2 — the Fig. 7 trend reverses)");
}
