//! Table I: hardware storage cost, FC vs sparse, for
//! N_net = (800, 100, 10), d_out = (20, 10) — plus the Sec. III-A
//! pipeline accounting harness (`pds exp pipeline`).

use super::common::Scale;
use crate::hw::pipeline::{speedup, throughput_inputs_per_sec, Pipeline};
use crate::hw::storage::{training_storage, StorageComparison, StorageCost};
use crate::hw::zconfig;
use crate::sparsity::config::{DoutConfig, NetConfig};

/// Print the Table-I storage comparison.
pub fn run(_scale: &Scale) {
    let net = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    let fc = training_storage(&net, &net.fc_dout());
    let sp = training_storage(&net, &dout);
    println!("Table I — storage (words), N_net = (800,100,10), sparse d_out = (20,10), rho_net = {:.0}%",
        net.rho_net(&dout) * 100.0);
    println!("{:<12} {:>12} {:>14}", "parameter", "count (FC)", "count (sparse)");
    let rows: [(&str, fn(&StorageCost) -> usize); 5] = [
        ("a", |c| c.activations),
        ("a-dot", |c| c.act_derivatives),
        ("delta", |c| c.deltas),
        ("b", |c| c.biases),
        ("W", |c| c.weights),
    ];
    for (name, get) in rows {
        println!("{:<12} {:>12} {:>14}", name, get(&fc), get(&sp));
    }
    println!("{:<12} {:>12} {:>14}", "TOTAL", fc.total(), sp.total());
    let cmp = StorageComparison::new(&net, &dout);
    println!(
        "memory reduction {:.1}X (paper: 3.9X), compute reduction {:.1}X (paper: 4.8X)",
        cmp.memory_reduction(),
        cmp.compute_reduction()
    );
    println!(
        "inference-only storage: {} words",
        StorageCost::inference_only(&net, &dout).total()
    );
}

/// Print the Sec. III-A pipeline accounting (`pds exp pipeline`).
pub fn run_pipeline(_scale: &Scale) {
    println!("Sec. III-A junction pipelining / operational parallelism");
    for l in [2usize, 4] {
        let p = Pipeline::new(l);
        p.audit(200).unwrap();
        println!(
            "L={l}: steady-state ops/junction-cycle = {} (≈3L), FF latency {} jc, train latency {} jc, speedup@1e5 inputs = {:.2}",
            p.steady_state_ops(),
            p.ff_latency(),
            p.train_latency(),
            speedup(l, 100_000)
        );
        for i in 1..=l {
            println!(
                "  junction {i}: weight staleness (FF vs BP) = {} updates; a-queue banks = {}",
                p.staleness(i),
                p.queue_banks(i)
            );
        }
    }
    // the initial FPGA implementation's operating point [40]
    let net = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    let cfg = zconfig::validate(&net, &dout, &[160, 10]).unwrap();
    println!(
        "\n[40]-style operating point: z_net = {:?}, junction cycle C = {} cycles (+2 flush)",
        cfg.z, cfg.junction_cycle
    );
    println!(
        "throughput at 100 MHz: {:.0} inputs/s (training), idle fraction {:.1}%",
        throughput_inputs_per_sec(100e6, cfg.junction_cycle, 2),
        cfg.idle_fraction() * 100.0
    );
}
