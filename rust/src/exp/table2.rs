//! Table II: clash-free vs structured vs random pre-defined sparsity
//! across the four dataset surrogates and density ladders, with the
//! paper's z_net hardware configurations validated for every clash-free
//! row. Also reports disconnected-neuron counts for the random method at
//! low density (the Sec. IV-B blue-value failure mode).

use super::common::{fmt_acc, run_on_splits, Approach, Scale};
use crate::data::Spec;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::sparsity::{generate, Method};
use crate::util::rng::Rng;
use crate::util::{ci90, mean};

struct Block {
    spec: Spec,
    layers: Vec<usize>,
    /// (d_out rows, z_net) — z_net from the paper's Table II.
    rows: Vec<(Vec<usize>, Vec<usize>)>,
}

fn blocks(full: bool) -> Vec<Block> {
    let mut out = vec![
        Block {
            spec: Spec::mnist_like(),
            layers: vec![800, 100, 100, 100, 10],
            rows: if full {
                vec![
                    (vec![80, 80, 80, 10], vec![200, 25, 25, 4]),
                    (vec![40, 40, 40, 10], vec![200, 25, 25, 5]),
                    (vec![20, 20, 20, 10], vec![200, 25, 25, 10]),
                    (vec![10, 10, 10, 10], vec![200, 25, 25, 25]),
                    (vec![2, 5, 5, 10], vec![80, 25, 25, 50]),
                ]
            } else {
                vec![
                    (vec![40, 40, 40, 10], vec![200, 25, 25, 5]),
                    (vec![10, 10, 10, 10], vec![200, 25, 25, 25]),
                ]
            },
        },
        Block {
            spec: Spec::reuters_like(),
            layers: vec![2000, 50, 50],
            rows: if full {
                vec![
                    (vec![25, 25], vec![1000, 25]),
                    (vec![10, 10], vec![400, 10]),
                    (vec![5, 5], vec![200, 5]),
                    (vec![2, 2], vec![80, 2]),
                    (vec![1, 1], vec![40, 1]),
                ]
            } else {
                vec![(vec![10, 10], vec![400, 10]), (vec![1, 1], vec![40, 1])]
            },
        },
        Block {
            spec: Spec::timit_like(39),
            layers: vec![39, 390, 39],
            rows: if full {
                vec![
                    (vec![270, 27], vec![13, 13]),
                    (vec![90, 9], vec![13, 13]),
                    (vec![30, 3], vec![13, 13]),
                ]
            } else {
                vec![(vec![90, 9], vec![13, 13])]
            },
        },
    ];
    if full {
        out.push(Block {
            spec: Spec::cifar_features_like(true),
            layers: vec![4000, 500, 100],
            rows: vec![
                (vec![100, 100], vec![2000, 250]),
                (vec![12, 12], vec![400, 50]),
                (vec![2, 2], vec![80, 10]),
            ],
        });
    }
    out
}

/// Print the Table-II grid (full corpus set only at standard scale).
pub fn run(scale: &Scale) {
    run_with(scale, scale.repeats > 2)
}

/// Print the Table-II grid; `full` includes every corpus block.
pub fn run_with(scale: &Scale, full: bool) {
    for block in blocks(full) {
        let netc = NetConfig::new(block.layers.clone());
        println!(
            "\nTable II — {}: N_net = {:?}",
            block.spec.name, block.layers
        );
        println!(
            "{:<20} {:>8} {:>18} {:>14} {:>14} {:>14} {:>10}",
            "d_out", "rho%", "z_net(junction C)", "clash-free", "structured", "random", "disc.n"
        );
        // FC reference row
        let sc = scale.for_spec(&block.spec);
        let fc_accs: Vec<f32> = (0..sc.repeats.min(2))
            .map(|r| {
                let splits = block.spec.splits(sc.n_train, 0, sc.n_test, 5000 + r as u64);
                run_on_splits(&splits, &block.layers, None, Approach::Fc, &sc, 50 + r as u64) as f32
                    * 100.0
            })
            .collect();
        println!(
            "{:<20} {:>8} {:>18} {:>14}",
            "FC",
            "100",
            "-",
            fmt_acc(mean(&fc_accs), ci90(&fc_accs))
        );

        for (dout_v, znet) in &block.rows {
            let dout = DoutConfig(dout_v.clone());
            netc.validate_dout(&dout).expect("paper row must be admissible");
            // validate the paper's hardware z_net for this row
            let zcfg = crate::hw::zconfig::validate(&netc, &dout, znet)
                .unwrap_or_else(|e| panic!("paper z_net {znet:?} invalid: {e}"));
            let rho = netc.rho_net(&dout) * 100.0;

            let mut cells: Vec<String> = Vec::new();
            let mut disconnected = 0usize;
            for approach in [Approach::ClashFree, Approach::Structured, Approach::Random] {
                let accs: Vec<f32> = (0..sc.repeats)
                    .map(|r| {
                        let splits =
                            block.spec.splits(sc.n_train, 0, sc.n_test, 5000 + r as u64);
                        run_on_splits(
                            &splits,
                            &block.layers,
                            Some(&dout),
                            approach,
                            &sc,
                            100 + 13 * r as u64,
                        ) as f32
                            * 100.0
                    })
                    .collect();
                cells.push(fmt_acc(mean(&accs), ci90(&accs)));
                if approach == Approach::Random {
                    let mut rng = Rng::new(77);
                    let p = generate(Method::Random, &netc, &dout, None, &mut rng);
                    disconnected = p.disconnected_neurons();
                }
            }
            println!(
                "{:<20} {:>8.1} {:>13?}({:>3}) {:>14} {:>14} {:>14} {:>10}",
                DoutConfig(dout_v.clone()).show(),
                rho,
                znet,
                zcfg.junction_cycle,
                cells[0],
                cells[1],
                cells[2],
                disconnected
            );
        }
    }
    println!("\n(paper: clash-free ≈ structured ≈ random at moderate density; random degrades at the lowest densities via disconnected neurons)");
}
