//! Shared experiment machinery: training-run scales, the approach
//! selector (Table II methods + §V baselines + FC reference), and the
//! accuracy-with-CI runner all figures are built from.

use crate::data::{Spec, Splits};
use crate::nn::dense::DenseNet;
use crate::nn::sparse::SparseNet;
use crate::nn::trainer::{self, l2_for_density, Network, TrainConfig};
use crate::sparsity::attention;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::sparsity::{generate, Method};
use crate::util::rng::Rng;
use crate::util::{ci90, mean};

/// Workload scale knobs (the paper: full corpora, 50 epochs, >= 5 runs;
/// here: synthetic surrogates at a single-core budget).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Training samples per run.
    pub n_train: usize,
    /// Test samples per run.
    pub n_test: usize,
    /// Epochs per run.
    pub epochs: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Repeats per reported number (for the 90% CIs).
    pub repeats: usize,
}

impl Scale {
    /// Default experiment scale (what `pds exp` runs without `--quick`).
    pub fn standard() -> Scale {
        Scale {
            n_train: 1000,
            n_test: 400,
            epochs: 8,
            batch: 64,
            repeats: 3,
        }
    }

    /// CI-friendly: tiny but still signal-bearing.
    pub fn quick() -> Scale {
        Scale {
            n_train: 250,
            n_test: 120,
            epochs: 4,
            batch: 32,
            repeats: 2,
        }
    }

    /// Heavier feature spaces (the CIFAR-like 4000-dim head) get fewer
    /// samples/epochs to stay within budget.
    pub fn for_spec(&self, spec: &Spec) -> Scale {
        if spec.features >= 4000 {
            Scale {
                n_train: self.n_train / 2,
                n_test: self.n_test / 2,
                epochs: (self.epochs / 2).max(2),
                ..*self
            }
        } else {
            *self
        }
    }
}

/// The sparsity approaches compared across the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// Hardware-compatible clash-free pre-defined patterns (Sec. III-C).
    ClashFree,
    /// Structured pre-defined (fixed degrees, random placement).
    Structured,
    /// Unconstrained random pre-defined.
    Random,
    /// §V-A attention (feature-variance weighted input out-degrees).
    Attention,
    /// §V-B learning structured sparsity (L1 during FC training + prune).
    Lss,
    /// Fully-connected reference.
    Fc,
}

impl Approach {
    /// Display name used in the experiment tables.
    pub fn name(&self) -> &'static str {
        match self {
            Approach::ClashFree => "clash-free",
            Approach::Structured => "structured",
            Approach::Random => "random",
            Approach::Attention => "attention",
            Approach::Lss => "LSS",
            Approach::Fc => "FC",
        }
    }
}

/// One training run; returns final test accuracy.
pub fn accuracy_run(
    spec: &Spec,
    layers: &[usize],
    dout: Option<&DoutConfig>,
    approach: Approach,
    scale: &Scale,
    seed: u64,
) -> f64 {
    let scale = scale.for_spec(spec);
    let splits = spec.splits(scale.n_train, 0, scale.n_test, seed ^ 0xDA7A);
    run_on_splits(&splits, layers, dout, approach, &scale, seed)
}

/// Same, over pre-generated splits (reused across approaches so methods
/// are compared on identical data).
pub fn run_on_splits(
    splits: &Splits,
    layers: &[usize],
    dout: Option<&DoutConfig>,
    approach: Approach,
    scale: &Scale,
    seed: u64,
) -> f64 {
    let netc = NetConfig::new(layers.to_vec());
    let mut rng = Rng::new(seed);
    let rho = dout.map(|d| netc.rho_net(d)).unwrap_or(1.0);
    let cfg = TrainConfig {
        epochs: scale.epochs,
        batch: scale.batch,
        l2: l2_for_density(1e-4, rho),
        seed,
        ..Default::default()
    };
    match approach {
        Approach::Fc => {
            let mut net = Network::Dense(DenseNet::init_he(layers, 0.1, &mut rng));
            trainer::train(&mut net, &splits.train, &splits.test, &cfg).final_test_acc()
        }
        Approach::ClashFree | Approach::Structured | Approach::Random => {
            let method = match approach {
                Approach::ClashFree => Method::ClashFree,
                Approach::Structured => Method::Structured,
                _ => Method::Random,
            };
            let dout = dout.expect("sparse approach needs d_out");
            let pattern = generate(method, &netc, dout, None, &mut rng);
            let mut net = Network::Sparse(SparseNet::init_he(&pattern, 0.1, &mut rng));
            trainer::train(&mut net, &splits.train, &splits.test, &cfg).final_test_acc()
        }
        Approach::Attention => {
            let dout = dout.expect("attention needs d_out");
            let variances = splits.train.feature_variances();
            let pattern = attention::generate_net(&netc, dout, &variances, &mut rng);
            let mut net = Network::Sparse(SparseNet::init_he(&pattern, 0.1, &mut rng));
            trainer::train(&mut net, &splits.train, &splits.test, &cfg).final_test_acc()
        }
        Approach::Lss => {
            // §V-B: FC training with an L1 sparsity promoter, magnitude
            // pruning to the target per-junction densities, brief masked
            // fine-tune. Training complexity is FC-like by construction.
            let dout = dout.expect("LSS needs target densities");
            let rho_j = netc.rho_per_junction(dout);
            let gammas: Vec<f32> = rho_j.iter().map(|&r| 2e-4 * (1.0 - r as f32)).collect();
            let mut dnet = DenseNet::init_he(layers, 0.1, &mut rng);
            let mut net = Network::Dense(dnet.clone());
            let lss_cfg = TrainConfig {
                l1: Some(gammas),
                ..cfg.clone()
            };
            trainer::train(&mut net, &splits.train, &splits.test, &lss_cfg);
            if let Network::Dense(n) = net {
                dnet = n;
            }
            dnet.prune_to_density(&rho_j);
            let mut net = Network::Dense(dnet);
            let ft_cfg = TrainConfig {
                epochs: (scale.epochs / 2).max(2),
                ..cfg
            };
            trainer::train(&mut net, &splits.train, &splits.test, &ft_cfg).final_test_acc()
        }
    }
}

/// Repeat a run over seeds; returns (mean, 90% CI half-width) in percent.
pub fn repeated(
    spec: &Spec,
    layers: &[usize],
    dout: Option<&DoutConfig>,
    approach: Approach,
    scale: &Scale,
) -> (f32, f32) {
    let accs: Vec<f32> = (0..scale.repeats)
        .map(|r| accuracy_run(spec, layers, dout, approach, scale, 1000 + 7 * r as u64) as f32 * 100.0)
        .collect();
    (mean(&accs), ci90(&accs))
}

/// The admissible out-degree config nearest a target overall density, with
/// junction densities scaled uniformly (used by the rho_net sweeps).
pub fn dout_for_rho_net(netc: &NetConfig, rho: f64) -> DoutConfig {
    DoutConfig(
        (0..netc.n_junctions())
            .map(|i| netc.junction(i).dout_for_density(rho))
            .collect(),
    )
}

/// Format "mean ± ci" like the paper's tables.
pub fn fmt_acc(mean: f32, ci: f32) -> String {
    format!("{mean:.1} ± {ci:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> Spec {
        Spec {
            name: "toy",
            features: 24,
            classes: 4,
            latent_dim: 8,
            shaping: crate::data::Shaping::Continuous,
            separation: 3.0,
            noise: 0.4,
        }
    }

    #[test]
    fn all_approaches_produce_learnable_runs() {
        let spec = toy_spec();
        let scale = Scale {
            n_train: 400,
            n_test: 120,
            epochs: 10,
            batch: 32,
            repeats: 1,
        };
        let layers = [24usize, 16, 4];
        let dout = DoutConfig(vec![8, 2]);
        for approach in [
            Approach::Fc,
            Approach::ClashFree,
            Approach::Structured,
            Approach::Random,
            Approach::Attention,
            Approach::Lss,
        ] {
            let acc = accuracy_run(&spec, &layers, Some(&dout), approach, &scale, 3);
            assert!(
                acc > 0.45,
                "{} acc {acc} barely above chance (0.25)",
                approach.name()
            );
        }
    }

    #[test]
    fn dout_for_rho_net_tracks_target() {
        let netc = NetConfig::new(vec![800, 100, 10]);
        let d = dout_for_rho_net(&netc, 0.2);
        let got = netc.rho_net(&d);
        assert!((got - 0.2).abs() < 0.07, "rho {got}");
    }

    #[test]
    fn repeated_reports_ci() {
        let spec = toy_spec();
        let scale = Scale {
            n_train: 150,
            n_test: 60,
            epochs: 3,
            batch: 32,
            repeats: 2,
        };
        let (m, ci) = repeated(&spec, &[24, 12, 4], None, Approach::Fc, &scale);
        assert!(m > 25.0 && m <= 100.0);
        assert!(ci >= 0.0);
    }
}
