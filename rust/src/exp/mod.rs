//! Experiment harnesses: one per table/figure in the paper's evaluation
//! (see the DESIGN.md experiment index). Each prints the same rows/series
//! the paper reports.
//!
//! Scale note: the paper trained 50 epochs on the full corpora over >= 5
//! repeats; this harness runs the synthetic surrogates at a single-core
//! budget (see [`common::Scale`]) — absolute accuracies differ, the
//! *shape* (who wins, by what factor, where crossovers fall) is the
//! reproduction target.

pub mod common;
pub mod fig1;
pub mod fig12;
pub mod size;
pub mod sweeps;
pub mod table1;
pub mod table2;
pub mod table3;

use common::Scale;

/// Run an experiment by id ("fig1", "table2", ... or "all").
pub fn run(id: &str, scale: &Scale) -> Result<(), String> {
    let all: &[(&str, fn(&Scale))] = &[
        ("fig1", fig1::run),
        ("table1", table1::run),
        ("table2", table2::run),
        ("fig6", sweeps::run_fig6),
        ("fig7", sweeps::run_fig7),
        ("fig8", sweeps::run_fig8),
        ("fig9", size::run_fig9),
        ("fig10", size::run_fig10),
        ("fig11", size::run_fig11),
        ("fig12", fig12::run),
        ("table3", table3::run),
        ("pipeline", table1::run_pipeline),
    ];
    if id == "all" {
        for (name, f) in all {
            println!("\n================ {name} ================");
            f(scale);
        }
        return Ok(());
    }
    match all.iter().find(|(name, _)| *name == id) {
        Some((_, f)) => {
            f(scale);
            Ok(())
        }
        None => Err(format!(
            "unknown experiment '{id}'; known: {} or 'all'",
            all.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
        )),
    }
}
