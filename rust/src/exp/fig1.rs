//! Fig. 1: weight histograms per junction of trained FC nets on the
//! MNIST surrogate (a-b: L=2, d-g: L=4), plus test accuracy vs rho_net
//! (c, h). The motivating observation: earlier junctions end training with
//! many near-zero weights, so they tolerate aggressive pre-defined
//! sparsification.

use super::common::{accuracy_run, dout_for_rho_net, fmt_acc, repeated, Approach, Scale};
use crate::data::Spec;
use crate::nn::dense::DenseNet;
use crate::nn::trainer::{self, Network, TrainConfig};
use crate::sparsity::config::NetConfig;
use crate::util::rng::Rng;

/// ASCII histogram of weight values.
fn histogram(w: &[f32], bins: usize) -> String {
    let lo = w.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let width = (hi - lo).max(1e-9) / bins as f32;
    let mut counts = vec![0usize; bins];
    for &v in w {
        let b = (((v - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let maxc = *counts.iter().max().unwrap();
    let mut out = String::new();
    for (b, &c) in counts.iter().enumerate() {
        let bar = "#".repeat((c * 40 / maxc.max(1)).max(usize::from(c > 0)));
        out.push_str(&format!(
            "  [{:+.3},{:+.3}) {:>6}  {}\n",
            lo + b as f32 * width,
            lo + (b + 1) as f32 * width,
            c,
            bar
        ));
    }
    out
}

/// Fraction of weights within +-eps of zero — Fig. 1's "many weights are
/// near zero after training" signal.
pub fn near_zero_fraction(w: &[f32], eps: f32) -> f64 {
    w.iter().filter(|v| v.abs() < eps).count() as f64 / w.len() as f64
}

fn train_fc(layers: &[usize], scale: &Scale, seed: u64) -> DenseNet {
    let spec = Spec::mnist_like();
    let splits = spec.splits(scale.n_train, 0, scale.n_test, seed);
    let mut rng = Rng::new(seed);
    let mut net = Network::Dense(DenseNet::init_he(layers, 0.1, &mut rng));
    let cfg = TrainConfig {
        epochs: scale.epochs,
        batch: scale.batch,
        seed,
        ..Default::default()
    };
    trainer::train(&mut net, &splits.train, &splits.test, &cfg);
    match net {
        Network::Dense(n) => n,
        _ => unreachable!(),
    }
}

/// Print the Fig. 1 weight histograms and accuracy-vs-density rows.
pub fn run(scale: &Scale) {
    for layers in [vec![800usize, 100, 10], vec![800, 100, 100, 100, 10]] {
        println!("\nFig. 1 weight histograms — FC N_net = {layers:?} (mnist-like)");
        let net = train_fc(&layers, scale, 42);
        for (i, w) in net.w.iter().enumerate() {
            let nz = near_zero_fraction(w, 0.02);
            println!(
                "junction {} ({}x{}): {:.0}% of weights within ±0.02 of zero",
                i + 1,
                layers[i + 1],
                layers[i],
                nz * 100.0
            );
            println!("{}", histogram(w, 12));
        }
    }

    println!("Fig. 1(c): accuracy vs rho_net for N_net = (800, 100, 10), sparsifying junction 1 first");
    println!("{:>8}  {:>12}", "rho_net", "test acc %");
    let netc = NetConfig::new(vec![800, 100, 10]);
    let spec = Spec::mnist_like();
    for rho in [1.0, 0.5, 0.21, 0.11, 0.05] {
        let (dout, approach) = if rho >= 1.0 {
            (None, Approach::Fc)
        } else {
            (Some(dout_for_rho_net(&netc, rho)), Approach::ClashFree)
        };
        let (m, ci) = repeated(&spec, &netc.layers, dout.as_ref(), approach, scale);
        println!("{:>7.0}%  {:>12}", netc.rho_net(&dout.clone().unwrap_or(netc.fc_dout())) * 100.0, fmt_acc(m, ci));
    }
    // single quick L=4 reference point
    let acc4 = accuracy_run(
        &spec,
        &[800, 100, 100, 100, 10],
        None,
        Approach::Fc,
        scale,
        7,
    );
    println!("Fig. 1(h) FC reference, L=4: {:.1}%", acc4 * 100.0);
}
