//! Table III / Appendix C: count of clash-free left-memory access patterns
//! |S_Mi| and the address-generation storage cost for types 1-3, with and
//! without memory dithering, on the paper's (12, 12, 2, 2, 4) junction.

use super::common::Scale;
use crate::sparsity::clash_free::{address_storage_cost, pattern_space, Flavor};
use crate::sparsity::config::JunctionShape;

fn fmt_count(log10: f64, exact: Option<u128>) -> String {
    match exact {
        Some(v) if v < 10_000 => format!("{v}"),
        Some(v) if v < 1_000_000 => format!("{:.0}k", v as f64 / 1e3),
        Some(v) if v < 1_000_000_000 => format!("{:.2}M", v as f64 / 1e6),
        _ => format!("1e{log10:.1}"),
    }
}

/// Print the Table-III pattern-space and address-storage rows.
pub fn run(_scale: &Scale) {
    println!("Table III — clash-free pattern spaces, junction (N_l, N_r, d_out, d_in, z) = (12, 12, 2, 2, 4)");
    println!(
        "{:<8} {:>8} {:>12} {:>24}",
        "type", "dither", "|S_Mi|", "addr storage (words)"
    );
    let shape = JunctionShape { n_left: 12, n_right: 12 };
    let flavors = [
        Flavor::Type1 { dither: false },
        Flavor::Type1 { dither: true },
        Flavor::Type2 { dither: false },
        Flavor::Type2 { dither: true },
        Flavor::Type3 { dither: false },
        Flavor::Type3 { dither: true },
    ];
    for f in flavors {
        let space = pattern_space(shape, 2, 4, f);
        let (t, d) = match f {
            Flavor::Type1 { dither } => (1, dither),
            Flavor::Type2 { dither } => (2, dither),
            Flavor::Type3 { dither } => (3, dither),
        };
        println!(
            "{:<8} {:>8} {:>12} {:>24}",
            t,
            if d { "yes" } else { "no" },
            fmt_count(space.log10, space.exact),
            address_storage_cost(shape, 2, 4, f)
        );
    }

    // a production-sized junction for perspective (Table II MNIST row)
    println!("\nSame accounting for the MNIST junction (800, 100, d_out=20, d_in=160, z=200):");
    let big = JunctionShape { n_left: 800, n_right: 100 };
    for f in [Flavor::Type1 { dither: false }, Flavor::Type3 { dither: true }] {
        let space = pattern_space(big, 20, 200, f);
        println!(
            "  {:<16} |S_Mi| ~ 1e{:.0}, storage {} words{}",
            format!("{f:?}"),
            space.log10,
            address_storage_cost(big, 20, 200, f),
            if space.is_exact_formula { "" } else { " (upper bound)" }
        );
    }
}
