//! PJRT execution backend (cargo feature `pjrt`, off by default): loads
//! the AOT HLO-text artifacts produced by `python/compile/aot.py` and
//! executes them on the PJRT CPU plugin (the platform the xla 0.1.6 crate
//! ships). Linking requires native XLA libraries, which is why this
//! backend is feature-gated; the default build uses
//! [`super::native::NativeEngine`] instead.
//!
//! PJRT objects wrap thread-affine raw handles (not `Send`), so each
//! thread that needs this backend builds its own engine — see
//! `coordinator::server`.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use super::{ConfigEntry, Dtype, ExecBackend, Manifest, ProgramExec, ProgramSpec, Value};

/// The PJRT client over an artifacts directory.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

struct PjrtProgram {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

fn to_literal(v: &Value) -> Result<xla::Literal> {
    let lit = match v {
        Value::F32(data, shape) => {
            if shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
        Value::I32(data, shape) => {
            if shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
    };
    Ok(lit)
}

impl PjrtEngine {
    /// Create a CPU engine over an artifacts directory (reads
    /// `manifest.json`; fails with guidance if `make artifacts` never
    /// ran). Returns the engine together with the parsed manifest for the
    /// [`super::Engine`] facade.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<(Self, Manifest)> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest =
            Manifest::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok((
            PjrtEngine {
                client,
                artifacts_dir: dir,
            },
            manifest,
        ))
    }
}

impl ExecBackend for PjrtEngine {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_program(
        &self,
        config: &str,
        program: &str,
        _entry: &ConfigEntry,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn ProgramExec>> {
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Box::new(PjrtProgram {
            exe,
            name: format!("{config}/{program}"),
        }))
    }
}

impl ProgramExec for PjrtProgram {
    fn run(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>> {
        let literals = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest says {}",
                self.name,
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
            let v = match ospec.dtype {
                Dtype::F32 => Value::F32(lit.to_vec::<f32>()?, ospec.shape.clone()),
                Dtype::I32 => Value::I32(lit.to_vec::<i32>()?, ospec.shape.clone()),
            };
            out.push(v);
        }
        Ok(out)
    }
}
