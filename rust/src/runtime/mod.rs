//! Backend-agnostic execution layer (see DESIGN.md §Backends).
//!
//! The manifest (`artifacts/manifest.json`, or the built-in synthesized
//! configs) describes every program's positional input/output tensors, so
//! marshalling is validated, not guessed. Execution is pluggable behind
//! the [`ExecBackend`] trait:
//!
//! - [`native::NativeEngine`] — always compiled, the default: executes the
//!   manifest's forward / train / gather_forward programs with the crate's
//!   own `nn::matrix` / `nn::sparse` kernels (batch-parallel over the
//!   `util::parallel` thread pool). Needs no artifact files and no native
//!   libraries.
//! - `pjrt::PjrtEngine` (cargo feature `pjrt`, off by default) — loads the
//!   AOT HLO-text artifacts produced by `python/compile/aot.py` and runs
//!   them on the PJRT CPU plugin via the `xla` crate. Python never runs
//!   here — `make artifacts` is the only compile-path step.
//!
//! [`Engine::new`] picks PJRT when the feature is enabled and compiled
//! artifacts exist, and the native backend otherwise, so every caller
//! (coordinator, CLI, benches, tests) is backend-agnostic.

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
#[allow(missing_docs)] // optional backend, not compiled in the offline CI doc build
pub mod pjrt;

use anyhow::{anyhow, bail, Result};
use std::path::Path;

use crate::nn::pipeline::{MultiPipelinedTrainer, PipelineConfig, PipelinedTrainer};
use crate::sparsity::pattern::NetPattern;

pub use manifest::{ConfigEntry, Dtype, Manifest, ProgramSpec, QuantSpec, TensorSpec};
pub use native::NativeEngine;

/// A host-side tensor crossing the backend boundary.
#[derive(Clone, Debug)]
pub enum Value {
    /// f32 data with its shape (empty shape = scalar).
    F32(Vec<f32>, Vec<usize>),
    /// i32 data with its shape (labels, gather indices).
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    /// A scalar f32 value (empty shape).
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![v], vec![])
    }

    /// The tensor's shape (empty = scalar).
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(d, _) => d.len(),
        }
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The element dtype.
    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(..) => Dtype::F32,
            Value::I32(..) => Dtype::I32,
        }
    }

    /// The f32 data, or an error for i32 tensors.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    /// The i32 data, or an error for f32 tensors.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => bail!("expected i32 value"),
        }
    }

    /// The single f32 element of a scalar, or an error otherwise.
    pub fn scalar(&self) -> Result<f32> {
        match self {
            Value::F32(d, _) if d.len() == 1 => Ok(d[0]),
            _ => bail!("expected f32 scalar"),
        }
    }
}

/// A pluggable execution backend: resolves manifest (config, program)
/// pairs into executable programs.
pub trait ExecBackend {
    /// Human-readable platform tag (e.g. "native-cpu", "Host").
    fn platform(&self) -> String;

    /// Build the executable for `programs[program]` of `config`. The
    /// facade passes the manifest entry and program spec; inputs are
    /// validated by [`Program::run`] before reaching the executable.
    fn load_program(
        &self,
        config: &str,
        program: &str,
        entry: &ConfigEntry,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn ProgramExec>>;

    /// Streaming pipelined trainer (the Sec. III-A FF/BP/UP interleave,
    /// `nn::pipeline`) for `entry`'s network, if this backend can execute
    /// it junction by junction. Default: `None` — fused AOT artifacts run
    /// a whole train step as one executable and cannot be split into
    /// per-junction stages; only the native backend overrides this.
    fn pipelined_trainer(
        &self,
        entry: &ConfigEntry,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
    ) -> Option<Result<PipelinedTrainer>> {
        let _ = (entry, pattern, cfg);
        None
    }

    /// Multi-tenant variant of [`ExecBackend::pipelined_trainer`]:
    /// `contexts` tenant contexts interleaved through one junction
    /// schedule over one manifest entry
    /// ([`crate::nn::pipeline::MultiPipelinedTrainer`]). Default `None`
    /// for the same reason — only the native backend can step junction
    /// by junction.
    fn pipelined_multi_trainer(
        &self,
        entry: &ConfigEntry,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
        contexts: usize,
    ) -> Option<Result<MultiPipelinedTrainer>> {
        let _ = (entry, pattern, cfg, contexts);
        None
    }
}

/// One loaded executable. `run` receives inputs already validated against
/// the manifest spec and must return outputs in manifest order.
pub trait ProgramExec {
    /// Execute with validated positional inputs.
    fn run(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>>;
}

/// Backend-agnostic engine over an artifacts directory.
pub struct Engine {
    backend: Box<dyn ExecBackend>,
    /// The parsed manifest (artifact file or built-in configs).
    pub manifest: Manifest,
}

/// One compiled executable with its validated signature.
pub struct Program {
    exec: Box<dyn ProgramExec>,
    /// The manifest signature `run` validates inputs against.
    pub spec: ProgramSpec,
    /// `config/program` label used in error messages.
    pub name: String,
}

impl Engine {
    /// Default engine: PJRT when the `pjrt` feature is enabled and
    /// compiled artifacts exist in `artifacts_dir`, the pure-Rust native
    /// backend otherwise.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        #[cfg(feature = "pjrt")]
        if artifacts_dir.as_ref().join("manifest.json").exists() {
            return Engine::pjrt(artifacts_dir);
        }
        Engine::native(artifacts_dir)
    }

    /// Pure-Rust native engine. Reads `manifest.json` for config shapes
    /// when present; otherwise serves the built-in synthesized configs —
    /// no artifact files are required either way.
    pub fn native(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load_or_builtin(artifacts_dir)?;
        Ok(Engine {
            backend: Box::new(NativeEngine),
            manifest,
        })
    }

    /// PJRT engine over compiled AOT artifacts (requires `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let (backend, manifest) = pjrt::PjrtEngine::new(artifacts_dir)?;
        Ok(Engine {
            backend: Box::new(backend),
            manifest,
        })
    }

    /// Native engine over an already-parsed manifest — no file I/O, no
    /// parsing, effectively free. This is what lets a multi-worker
    /// service parse `manifest.json` once and still give every worker
    /// thread its own engine.
    ///
    /// Runs the cheap static-analysis pass ([`crate::analysis::quick_lint`])
    /// on the manifest and panics on an error-level finding: every
    /// manifest in the system arrives here either synthesized
    /// ([`Manifest::builtin`]) or through [`Manifest::load_or_builtin`],
    /// whose lint gate already rejects broken files — so a failure at
    /// this point is a programmer error (a hand-mutated `ConfigEntry`),
    /// not an input error.
    pub fn from_manifest(manifest: Manifest) -> Engine {
        let report = crate::analysis::quick_lint(&manifest);
        assert!(
            !report.has_errors(),
            "manifest failed static lint: {report}"
        );
        Engine {
            backend: Box::new(NativeEngine),
            manifest,
        }
    }

    /// Cheap per-worker engine construction. Backend handles can be
    /// thread-affine (PJRT executables wrap raw pointers that must not
    /// cross threads), so each worker thread needs its *own* engine;
    /// this constructor keeps that cheap by reusing `manifest`, the
    /// single shared parse, on the native path. With the `pjrt` feature
    /// enabled and compiled artifacts present it builds a fresh PJRT
    /// engine instead (the artifact load is the unavoidable per-worker
    /// cost there).
    ///
    /// ```
    /// use pds::runtime::{Engine, Manifest};
    ///
    /// // parse (or synthesize) the manifest once...
    /// let manifest = Manifest::builtin();
    /// // ...then hand every worker thread its own engine, nearly free
    /// let engine = Engine::for_worker("/nonexistent/dir", &manifest).unwrap();
    /// assert!(engine.load("tiny", "forward").is_ok());
    /// assert!(engine.platform().starts_with("native"));
    /// ```
    pub fn for_worker(artifacts_dir: impl AsRef<Path>, manifest: &Manifest) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        #[cfg(feature = "pjrt")]
        if dir.join("manifest.json").exists() {
            return Engine::pjrt(dir);
        }
        let _ = dir;
        Ok(Engine::from_manifest(manifest.clone()))
    }

    /// The active backend's platform tag (e.g. `native-cpu (8 threads)`).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Build the streaming pipelined training engine
    /// ([`crate::nn::pipeline::PipelinedTrainer`]) for `config`: the
    /// Sec. III-A schedule where junction i runs FF on batch `t` while
    /// junction i-1 runs BP/UP on batch `t-1`. Fails when the active
    /// backend cannot train junction by junction (fused PJRT artifacts;
    /// the always-available native backend can).
    ///
    /// ```
    /// use pds::nn::pipeline::PipelineConfig;
    /// use pds::runtime::Engine;
    /// use pds::sparsity::config::{DoutConfig, NetConfig};
    /// use pds::sparsity::{generate, Method};
    /// use pds::util::rng::Rng;
    ///
    /// let engine = Engine::native("/nonexistent/dir").unwrap();
    /// let layers = engine.manifest.configs["tiny"].layers.clone();
    /// let netc = NetConfig::new(layers);
    /// let mut rng = Rng::new(0);
    /// let pattern = generate(Method::ClashFree, &netc, &DoutConfig(vec![4, 2]), None, &mut rng);
    /// let cfg = PipelineConfig { batch: 16, ..Default::default() };
    /// let trainer = engine.train_pipelined("tiny", &pattern, &cfg).unwrap();
    /// // full Fig. 2c schedule for an L = 2 net: 4 minibatches in flight
    /// assert_eq!(trainer.depth(), 4);
    /// trainer.audit_banked().unwrap();
    /// ```
    pub fn train_pipelined(
        &self,
        config: &str,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
    ) -> Result<PipelinedTrainer> {
        let entry = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))?;
        match self.backend.pipelined_trainer(entry, pattern, cfg) {
            Some(trainer) => trainer,
            None => bail!(
                "backend '{}' has no pipelined training path (the native backend trains \
                 junction by junction; fused AOT artifacts cannot)",
                self.platform()
            ),
        }
    }

    /// One engine hosting `contexts` tenant contexts over one parsed
    /// manifest entry: the multi-tenant twin of
    /// [`Engine::train_pipelined`]. Every tenant shares `config`'s
    /// layers and `pattern`; per-tenant weights start from
    /// [`crate::nn::pipeline::context_seed`] so context 0 reproduces the
    /// single-tenant path bit for bit.
    ///
    /// ```
    /// use pds::nn::pipeline::PipelineConfig;
    /// use pds::runtime::Engine;
    /// use pds::sparsity::config::{DoutConfig, NetConfig};
    /// use pds::sparsity::{generate, Method};
    /// use pds::util::rng::Rng;
    ///
    /// let engine = Engine::native("/nonexistent/dir").unwrap();
    /// let layers = engine.manifest.configs["tiny"].layers.clone();
    /// let netc = NetConfig::new(layers);
    /// let mut rng = Rng::new(0);
    /// let pattern = generate(Method::ClashFree, &netc, &DoutConfig(vec![4, 2]), None, &mut rng);
    /// let cfg = PipelineConfig { batch: 16, ..Default::default() };
    /// let multi = engine.train_pipelined_contexts("tiny", &pattern, &cfg, 4).unwrap();
    /// assert_eq!(multi.contexts(), 4);
    /// // each tenant's own batches are C·k junction cycles apart
    /// assert_eq!(multi.stride(), 4);
    /// multi.audit_banked().unwrap();
    /// ```
    pub fn train_pipelined_contexts(
        &self,
        config: &str,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
        contexts: usize,
    ) -> Result<MultiPipelinedTrainer> {
        let entry = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))?;
        match self
            .backend
            .pipelined_multi_trainer(entry, pattern, cfg, contexts)
        {
            Some(trainer) => trainer,
            None => bail!(
                "backend '{}' has no pipelined training path (the native backend trains \
                 junction by junction; fused AOT artifacts cannot)",
                self.platform()
            ),
        }
    }

    /// Load the fixed-point forward executable of `config`: the
    /// `forward_quantized` program, which takes the same positional
    /// inputs as `forward` but executes in the config's Qm.n format
    /// ([`QuantSpec`], `nn::fixed`) and returns `[logits, saturations]`
    /// — the saturation count tells callers when the format's integer
    /// headroom was exceeded. Fails with a pointed error when the config
    /// carries no quant spec (every built-in synthesized config does).
    ///
    /// ```
    /// use pds::runtime::Engine;
    ///
    /// let engine = Engine::native("/nonexistent/dir").unwrap();
    /// let prog = engine.forward_quantized("tiny").unwrap();
    /// // same inputs as `forward`, one extra output (the saturation count)
    /// let fwd = engine.load("tiny", "forward").unwrap();
    /// assert_eq!(prog.spec.inputs.len(), fwd.spec.inputs.len());
    /// assert_eq!(prog.spec.outputs.len(), 2);
    /// ```
    pub fn forward_quantized(&self, config: &str) -> Result<Program> {
        let entry = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))?;
        if entry.quant.is_none() {
            bail!(
                "config '{config}' has no quant spec: add `\"quant\": \"Qm.n\"` to the \
                 manifest entry (built-in synthesized configs carry one by default)"
            );
        }
        self.load(config, "forward_quantized")
    }

    /// Load `programs[program]` of config `config`.
    pub fn load(&self, config: &str, program: &str) -> Result<Program> {
        let entry = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))?;
        let spec = entry
            .programs
            .get(program)
            .ok_or_else(|| anyhow!("program '{program}' not in config '{config}'"))?;
        let exec = self.backend.load_program(config, program, entry, spec)?;
        Ok(Program {
            exec,
            spec: spec.clone(),
            name: format!("{config}/{program}"),
        })
    }
}

impl Program {
    /// Execute with positional inputs; validates every shape/dtype against
    /// the manifest and returns outputs in manifest order.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, manifest wants {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = spec.shape.iter().product();
            if v.len() != want || v.dtype() != spec.dtype {
                bail!(
                    "{}: input '{}' expects {:?}{:?}, got {:?} with {} elements",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    v.dtype(),
                    v.len()
                );
            }
        }
        let out = self.exec.run(inputs, &self.spec)?;
        if out.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest says {}",
                self.name,
                out.len(),
                self.spec.outputs.len()
            );
        }
        Ok(out)
    }

    /// Index of a named input in the positional signature.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no input named '{name}'", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_accessors() {
        let v = Value::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.len(), 4);
        assert_eq!(v.as_f32().unwrap()[3], 4.0);
        assert!(v.scalar().is_err());
        assert!(v.as_i32().is_err());
        let s = Value::scalar_f32(7.5);
        assert_eq!(s.scalar().unwrap(), 7.5);
        assert_eq!(s.dtype(), Dtype::F32);
    }

    #[test]
    fn native_fallback_serves_builtin_configs() {
        // no manifest.json anywhere near this path: the native backend
        // must still come up with the built-in configs
        let e = Engine::native("/nonexistent/dir").unwrap();
        assert!(e.manifest.configs.contains_key("tiny"));
        assert!(e.platform().starts_with("native"));
        assert!(e.load("tiny", "forward").is_ok());
        assert!(e.load("tiny", "train").is_ok());
        assert!(e.load("tiny", "bogus").is_err());
        assert!(e.load("bogus", "forward").is_err());
    }

    #[test]
    fn worker_engines_share_one_parsed_manifest() {
        let m = Manifest::builtin();
        let e = Engine::from_manifest(m.clone());
        assert!(e.load("tiny", "forward").is_ok());
        // for_worker falls back to the shared parse when no compiled
        // artifacts exist at the path
        let e2 = Engine::for_worker("/nonexistent/dir", &m).unwrap();
        assert!(e2.manifest.configs.contains_key("mnist_fc2"));
        assert!(e2.load("timit", "forward").is_ok());
    }

    #[test]
    fn program_facade_validates_inputs() {
        let e = Engine::native("/nonexistent/dir").unwrap();
        let p = e.load("tiny", "forward").unwrap();
        // wrong arity
        let err = p.run(&[Value::scalar_f32(1.0)]).unwrap_err();
        assert!(format!("{err:#}").contains("inputs given"));
        assert!(p.input_index("x").is_ok());
        assert!(p.input_index("nope").is_err());
    }
}
