//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python never runs here — `make artifacts` is the only compile-path step;
//! afterwards the `pds` binary is self-contained. The manifest
//! (`artifacts/manifest.json`) describes every program's positional
//! input/output literals so marshalling is validated, not guessed.

pub mod manifest;

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

pub use manifest::{ConfigEntry, Dtype, Manifest, ProgramSpec, TensorSpec};

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(..) => Dtype::F32,
            Value::I32(..) => Dtype::I32,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
            Value::I32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match self {
            Value::F32(d, _) if d.len() == 1 => Ok(d[0]),
            _ => bail!("expected f32 scalar"),
        }
    }
}

/// The PJRT client (CPU plugin, the platform the xla 0.1.6 crate ships).
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

/// One compiled executable with its validated signature.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ProgramSpec,
    pub name: String,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory (reads
    /// `manifest.json`; fails with guidance if `make artifacts` never ran).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("bad manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            artifacts_dir: dir,
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile `programs[program]` of config `config`.
    pub fn load(&self, config: &str, program: &str) -> Result<Program> {
        let entry = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))?;
        let spec = entry
            .programs
            .get(program)
            .ok_or_else(|| anyhow!("program '{program}' not in config '{config}'"))?;
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Program {
            exe,
            spec: spec.clone(),
            name: format!("{config}/{program}"),
        })
    }
}

impl Program {
    /// Execute with positional inputs; validates every shape/dtype against
    /// the manifest and returns outputs in manifest order.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, manifest wants {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, spec) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = spec.shape.iter().product();
            if v.len() != want || v.dtype() != spec.dtype {
                bail!(
                    "{}: input '{}' expects {:?}{:?}, got {:?} with {} elements",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    v.dtype(),
                    v.len()
                );
            }
            literals.push(v.to_literal()?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest says {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.spec.outputs) {
            let v = match spec.dtype {
                Dtype::F32 => Value::F32(lit.to_vec::<f32>()?, spec.shape.clone()),
                Dtype::I32 => Value::I32(lit.to_vec::<i32>()?, spec.shape.clone()),
            };
            out.push(v);
        }
        Ok(out)
    }

    /// Index of a named input in the positional signature.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no input named '{name}'", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_accessors() {
        let v = Value::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.len(), 4);
        assert_eq!(v.as_f32().unwrap()[3], 4.0);
        assert!(v.scalar().is_err());
        let s = Value::scalar_f32(7.5);
        assert_eq!(s.scalar().unwrap(), 7.5);
        assert_eq!(s.dtype(), Dtype::F32);
    }

    #[test]
    fn engine_requires_manifest() {
        let err = match Engine::new("/nonexistent/dir") {
            Err(e) => e,
            Ok(_) => panic!("engine created from nonexistent dir"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
