//! Pure-Rust execution backend: runs the manifest's standard programs
//! (`forward`, `train`, `gather_forward`) by delegating to the crate's
//! reference implementations — `nn::dense::DenseNet` + `nn::adam` for
//! the masked fwd/bwd/Adam math (the same math the AOT JAX artifacts
//! compile; one implementation, cross-checked in
//! `rust/tests/native_backend.rs`) and the `nn::sparse` gather kernel
//! for the compacted path.
//!
//! Always compiled and used by default: it needs no artifact files, no
//! Python, and no native libraries, which is what lets `cargo test` and
//! `cargo bench` run green in the offline CI environment. The hot paths
//! are batch-parallel via [`crate::util::parallel`] (the kernels chunk the
//! batch dimension over a scoped thread pool), so the inference server's
//! batched execution and the trainer's full fwd/bwd/update step both scale
//! across cores.

use anyhow::{bail, Result};

use super::{ConfigEntry, ExecBackend, ProgramExec, ProgramSpec, Value};
use crate::nn::actsparse::ActSpec;
use crate::nn::adam::{AdamConfig, AdamState};
use crate::nn::dense::DenseNet;
use crate::nn::fixed::{self, FixedSparseLayer, QFormat};
use crate::nn::pipeline::{MultiPipelinedTrainer, PipelineConfig, PipelinedTrainer};
use crate::nn::relu;
use crate::nn::sparse::{SparseLayer, SparseNet};
use crate::sparsity::pattern::NetPattern;
use crate::util::parallel;

/// The always-available CPU backend (stateless: program shapes come from
/// the manifest entry at load time).
pub struct NativeEngine;

enum Kind {
    Forward,
    Train,
    GatherForward,
    /// Fixed-point forward in the config's Qm.n format (`nn::fixed`).
    QuantForward(QFormat),
}

struct NativeProgram {
    kind: Kind,
    layers: Vec<usize>,
    batch: usize,
    /// The config's activation-sparsity spec: when present, `forward`,
    /// `train` and `forward_quantized` run the sparse-sparse CSR kernels
    /// (`nn::actsparse`) instead of the dense-activation reference path.
    /// Program signatures are unchanged either way.
    act: Option<ActSpec>,
    name: String,
}

impl ExecBackend for NativeEngine {
    fn platform(&self) -> String {
        format!("native-cpu ({} threads)", parallel::max_threads())
    }

    fn load_program(
        &self,
        config: &str,
        program: &str,
        entry: &ConfigEntry,
        _spec: &ProgramSpec,
    ) -> Result<Box<dyn ProgramExec>> {
        let kind = match program {
            "forward" => Kind::Forward,
            "train" => Kind::Train,
            "gather_forward" => Kind::GatherForward,
            "forward_quantized" => match entry.quant {
                Some(q) => Kind::QuantForward(q.format),
                None => bail!("config '{config}' has no quant spec for 'forward_quantized'"),
            },
            other => bail!(
                "native backend has no implementation for program '{other}' (config '{config}')"
            ),
        };
        Ok(Box::new(NativeProgram {
            kind,
            layers: entry.layers.clone(),
            batch: entry.batch,
            act: entry.act,
            name: format!("{config}/{program}"),
        }))
    }

    /// The native backend executes junctions individually, so it can run
    /// the streaming pipelined schedule (`nn::pipeline`) directly on the
    /// compacted CSR kernels.
    fn pipelined_trainer(
        &self,
        entry: &ConfigEntry,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
    ) -> Option<Result<PipelinedTrainer>> {
        Some(PipelinedTrainer::from_pattern(&entry.layers, pattern, cfg))
    }

    /// Likewise for the multi-tenant interleave: one native engine hosts
    /// `contexts` tenant contexts over one manifest entry, each tenant's
    /// state fetched per cycle from the context bank.
    fn pipelined_multi_trainer(
        &self,
        entry: &ConfigEntry,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
        contexts: usize,
    ) -> Option<Result<MultiPipelinedTrainer>> {
        Some(MultiPipelinedTrainer::from_pattern(
            &entry.layers,
            pattern,
            cfg,
            contexts,
        ))
    }
}

/// Assemble the reference masked-dense net from the program's positional
/// `params` (w/b interleaved) and `masks` inputs. Weights are pre-masked
/// (w .* mask) so the `DenseNet` invariant — excluded edges exactly zero
/// — holds regardless of what the caller passed.
fn dense_net_from_inputs(
    layers: &[usize],
    params: &[Value],
    masks: &[Value],
) -> Result<DenseNet> {
    let l = layers.len() - 1;
    let mut w: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut b: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut m: Vec<Vec<f32>> = Vec::with_capacity(l);
    for i in 0..l {
        let wi = params[2 * i].as_f32()?;
        let mi = masks[i].as_f32()?;
        w.push(wi.iter().zip(mi).map(|(wv, mv)| wv * mv).collect());
        b.push(params[2 * i + 1].as_f32()?.to_vec());
        m.push(mi.to_vec());
    }
    Ok(DenseNet {
        layers: layers.to_vec(),
        w,
        b,
        masks: m,
    })
}

/// Compact the program's positional `params` + `masks` inputs into the
/// CSR net the sparse-sparse (activation-masked) paths execute. The
/// extraction walks edges in row-major order — the same order
/// [`SparseLayer::from_pattern_dense`] produces — so the masked kernels'
/// all-ones bit-for-bit guarantee applies to this net too.
fn sparse_net_from_inputs(
    layers: &[usize],
    params: &[Value],
    masks: &[Value],
) -> Result<SparseNet> {
    let l = layers.len() - 1;
    let mut junctions = Vec::with_capacity(l);
    for i in 0..l {
        let (nl, nr) = (layers[i], layers[i + 1]);
        let w = params[2 * i].as_f32()?;
        let b = params[2 * i + 1].as_f32()?;
        let m = masks[i].as_f32()?;
        let mut offsets = Vec::with_capacity(nr + 1);
        let mut idx = Vec::new();
        let mut wc = Vec::new();
        offsets.push(0u32);
        for j in 0..nr {
            for k in 0..nl {
                if m[j * nl + k] != 0.0 {
                    idx.push(k as u32);
                    wc.push(w[j * nl + k]);
                }
            }
            offsets.push(idx.len() as u32);
        }
        junctions.push(SparseLayer {
            n_left: nl,
            n_right: nr,
            offsets,
            idx,
            wc,
            bias: b.to_vec(),
        });
    }
    Ok(SparseNet {
        layers: layers.to_vec(),
        junctions,
    })
}

impl NativeProgram {
    fn run_forward(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>> {
        let l = self.layers.len() - 1;
        let x = inputs[3 * l].as_f32()?;
        if let Some(aspec) = &self.act {
            let net =
                sparse_net_from_inputs(&self.layers, &inputs[..2 * l], &inputs[2 * l..3 * l])?;
            let (logits, _stats) = net.logits_act(x, self.batch, aspec);
            return Ok(vec![Value::F32(logits, spec.outputs[0].shape.clone())]);
        }
        let net = dense_net_from_inputs(&self.layers, &inputs[..2 * l], &inputs[2 * l..3 * l])?;
        let logits = net.logits(x, self.batch);
        Ok(vec![Value::F32(logits, spec.outputs[0].shape.clone())])
    }

    /// One fused train step: the reference trainer's masked fwd/bwd
    /// (`DenseNet::step` — masked gradients keep the Adam moments of
    /// excluded edges exactly zero) followed by the reference Adam update
    /// of every parameter tensor, so the native backend and the `nn`
    /// trainer are one implementation, not two kept in sync.
    fn run_train(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>> {
        let l = self.layers.len() - 1;
        let l2n = 2 * l;
        let params = &inputs[..l2n];
        let opt_m = &inputs[l2n..2 * l2n];
        let opt_v = &inputs[2 * l2n..3 * l2n];
        let masks = &inputs[3 * l2n..3 * l2n + l];
        let rest = &inputs[3 * l2n + l..];
        let x = rest[0].as_f32()?;
        let y = rest[1].as_i32()?;
        let t = rest[2].scalar()?;
        let lr = rest[3].scalar()?;
        let l2 = rest[4].scalar()?;

        // (w, b, gw, gb, loss, correct) in the dense layout either way:
        // with an ActSpec the step runs the sparse-sparse CSR kernels and
        // the compacted gradients are scattered back for the fused Adam
        // update below (excluded edges stay exactly zero in both layouts,
        // keeping their Adam moments zero).
        let (wd, bd, gw, gb, loss, correct) = if let Some(aspec) = &self.act {
            let snet = sparse_net_from_inputs(&self.layers, params, masks)?;
            let (step, _stats) = snet.step_act(x, y, self.batch, l2, aspec);
            let mut wd = Vec::with_capacity(l);
            let mut bd = Vec::with_capacity(l);
            let mut gw = Vec::with_capacity(l);
            for (i, junction) in snet.junctions.iter().enumerate() {
                let (w, _mask) = junction.to_dense();
                wd.push(w);
                bd.push(junction.bias.clone());
                let mut g = vec![0f32; junction.n_right * junction.n_left];
                for j in 0..junction.n_right {
                    for e in junction.offsets[j] as usize..junction.offsets[j + 1] as usize {
                        g[j * junction.n_left + junction.idx[e] as usize] = step.grads.gwc[i][e];
                    }
                }
                gw.push(g);
            }
            (wd, bd, gw, step.grads.gb, step.loss, step.correct)
        } else {
            let net = dense_net_from_inputs(&self.layers, params, masks)?;
            let step = net.step(x, y, self.batch, l2, None);
            (net.w, net.b, step.grads.gw, step.grads.gb, step.loss, step.correct)
        };

        // fused Adam update (the paper's configuration; lr comes in as a
        // runtime scalar like in the AOT artifact)
        let cfg = AdamConfig {
            lr,
            ..AdamConfig::default()
        };
        let mut new_p: Vec<Value> = Vec::with_capacity(l2n);
        let mut new_m: Vec<Value> = Vec::with_capacity(l2n);
        let mut new_v: Vec<Value> = Vec::with_capacity(l2n);
        for ti in 0..l2n {
            let junction = ti / 2;
            let is_bias = ti % 2 == 1;
            let mut p = if is_bias {
                bd[junction].clone()
            } else {
                wd[junction].clone()
            };
            let g = if is_bias {
                &gb[junction]
            } else {
                &gw[junction]
            };
            let mut st = AdamState {
                m: opt_m[ti].as_f32()?.to_vec(),
                v: opt_v[ti].as_f32()?.to_vec(),
            };
            st.step(&mut p, g, t, &cfg);
            new_p.push(Value::F32(p, spec.outputs[ti].shape.clone()));
            new_m.push(Value::F32(st.m, spec.outputs[l2n + ti].shape.clone()));
            new_v.push(Value::F32(st.v, spec.outputs[2 * l2n + ti].shape.clone()));
        }
        let mut out = new_p;
        out.extend(new_m);
        out.extend(new_v);
        out.push(Value::scalar_f32(t + 1.0));
        out.push(Value::scalar_f32(loss));
        out.push(Value::scalar_f32(correct as f32));
        Ok(out)
    }

    /// Fixed-point forward (`nn::fixed`): compact each junction's dense
    /// masked weights into CSR, quantize weights / biases / input into
    /// the config's Qm.n format, run the saturating integer kernels with
    /// ReLU in the raw domain, and dequantize the logits. The second
    /// output counts every headroom violation — MAC outputs that
    /// saturated *and* parameters/inputs that clipped during
    /// quantization — so callers (and the parity tests) can tell when
    /// the format was exceeded and the documented error bound no longer
    /// applies.
    fn run_quant_forward(
        &self,
        fmt: QFormat,
        inputs: &[Value],
        spec: &ProgramSpec,
    ) -> Result<Vec<Value>> {
        let l = self.layers.len() - 1;
        let x = inputs[3 * l].as_f32()?;
        // CSR extraction (row-major edge order, weights pre-masked like
        // the f32 path) via the shared compaction helper
        let net = sparse_net_from_inputs(&self.layers, &inputs[..2 * l], &inputs[2 * l..3 * l])?;
        let mut saturations = 0usize;
        let mut aq = fmt.quantize_slice_counted(x, &mut saturations);
        for (i, junction) in net.junctions.iter().enumerate() {
            let layer = FixedSparseLayer::from_f32(junction, fmt);
            saturations += layer.clipped;
            let mut h = vec![0i32; self.batch * junction.n_right];
            match &self.act {
                // hidden-layer activations only: the input layer (i == 0)
                // is never masked. Selection runs on the raw Qm.n words —
                // |raw| ordering equals |dequantized| ordering.
                Some(aspec) if i > 0 => {
                    let m = fixed::mask_raw(aspec, &aq, junction.n_left, self.batch, fmt, 0);
                    saturations += layer.forward_masked(&aq, self.batch, &m.active, &mut h);
                }
                _ => saturations += layer.forward(&aq, self.batch, &mut h),
            }
            if i != l - 1 {
                fixed::relu_raw(&mut h);
            }
            aq = h;
        }
        Ok(vec![
            Value::F32(fmt.dequantize_slice(&aq), spec.outputs[0].shape.clone()),
            Value::scalar_f32(saturations as f32),
        ])
    }

    /// Compacted (CSR-style) forward over the gathered weight/index
    /// memories — the software twin of the hardware's edge processing,
    /// executed with the batch-parallel `SparseLayer` kernel.
    fn run_gather(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>> {
        let l = self.layers.len() - 1;
        let wcs = &inputs[..l];
        let idxs = &inputs[l..2 * l];
        let biases = &inputs[2 * l..3 * l];
        let x = inputs[3 * l].as_f32()?;
        let batch = self.batch;
        let mut a = x.to_vec();
        for i in 0..l {
            let (nl, nr) = (self.layers[i], self.layers[i + 1]);
            let wc = wcs[i].as_f32()?;
            let idx = idxs[i].as_i32()?;
            let bias = biases[i].as_f32()?;
            let din = wc.len() / nr;
            if let Some(&bad) = idx.iter().find(|&&k| k < 0 || k as usize >= nl) {
                bail!("{}: junction {} index {bad} out of range 0..{nl}", self.name, i + 1);
            }
            let layer = SparseLayer {
                n_left: nl,
                n_right: nr,
                offsets: (0..=nr).map(|j| (j * din) as u32).collect(),
                idx: idx.iter().map(|&k| k as u32).collect(),
                wc: wc.to_vec(),
                bias: bias.to_vec(),
            };
            let mut h = vec![0f32; batch * nr];
            match &self.act {
                // same hidden-layers-only rule as the other act paths
                Some(aspec) if i > 0 => {
                    let m = aspec.mask(&a, nl, batch, 0);
                    layer.forward_masked(&a, batch, &m.active, &mut h);
                }
                _ => layer.forward(&a, batch, &mut h),
            }
            if i != l - 1 {
                relu(&mut h);
            }
            a = h;
        }
        Ok(vec![Value::F32(a, spec.outputs[0].shape.clone())])
    }
}

impl ProgramExec for NativeProgram {
    fn run(&self, inputs: &[Value], spec: &ProgramSpec) -> Result<Vec<Value>> {
        match self.kind {
            Kind::Forward => self.run_forward(inputs, spec),
            Kind::Train => self.run_train(inputs, spec),
            Kind::GatherForward => self.run_gather(inputs, spec),
            Kind::QuantForward(fmt) => self.run_quant_forward(fmt, inputs, spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dense::DenseNet;
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    #[test]
    fn unknown_program_is_rejected_at_load() {
        let entry = crate::runtime::ConfigEntry::synthesize(vec![8, 4], 2, None, None);
        let spec = entry.programs["forward"].clone();
        let err = NativeEngine
            .load_program("c", "backward", &entry, &spec)
            .err()
            .expect("must reject");
        assert!(format!("{err:#}").contains("no implementation"));
        // forward_quantized without a quant spec is rejected at load too
        let err = NativeEngine
            .load_program("c", "forward_quantized", &entry, &spec)
            .err()
            .expect("must reject");
        assert!(format!("{err:#}").contains("quant spec"));
    }

    /// Random params + half-dense random masks + input for a synthesized
    /// entry, in the forward program's positional order.
    fn forward_inputs(layers: &[usize], batch: usize, seed: u64) -> Vec<Value> {
        let l = layers.len() - 1;
        let mut rng = Rng::new(seed);
        let mut inputs: Vec<Value> = Vec::new();
        for i in 0..l {
            let (nl, nr) = (layers[i], layers[i + 1]);
            inputs.push(Value::F32(
                (0..nr * nl).map(|_| rng.normal() * 0.3).collect(),
                vec![nr, nl],
            ));
            inputs.push(Value::F32(
                (0..nr).map(|_| rng.normal() * 0.1).collect(),
                vec![nr],
            ));
        }
        for i in 0..l {
            let (nl, nr) = (layers[i], layers[i + 1]);
            inputs.push(Value::F32(
                (0..nr * nl)
                    .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
                    .collect(),
                vec![nr, nl],
            ));
        }
        inputs.push(Value::F32(
            (0..batch * layers[0]).map(|_| rng.normal()).collect(),
            vec![batch, layers[0]],
        ));
        inputs
    }

    #[test]
    fn act_forward_with_saturating_k_matches_the_dense_path() {
        use crate::nn::actsparse::ActSpec;
        let (layers, batch) = (vec![12, 8, 6, 4], 3usize);
        let inputs = forward_inputs(&layers, batch, 11);
        let plain = crate::runtime::ConfigEntry::synthesize(layers.clone(), batch, None, None);
        let spec = plain.programs["forward"].clone();
        let acted = plain.clone().with_act(ActSpec::top_k(usize::MAX));
        let p0 = NativeEngine.load_program("c", "forward", &plain, &spec).unwrap();
        let p1 = NativeEngine.load_program("c", "forward", &acted, &spec).unwrap();
        let a = p0.run(&inputs, &spec).unwrap();
        let b = p1.run(&inputs, &spec).unwrap();
        // all-ones mask: the sparse-sparse path computes the same network
        // as the dense reference (different summation order, so tolerance
        // rather than bit equality across the two implementations)
        for (g, w) in a[0].as_f32().unwrap().iter().zip(b[0].as_f32().unwrap()) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // a tight k actually changes the computation (non-vacuity)
        let tight = plain.with_act(ActSpec::top_k(1));
        let p2 = NativeEngine.load_program("c", "forward", &tight, &spec).unwrap();
        let c = p2.run(&inputs, &spec).unwrap();
        assert_ne!(a[0].as_f32().unwrap(), c[0].as_f32().unwrap());
    }

    #[test]
    fn native_forward_matches_dense_reference() {
        let engine = Engine::native("/nonexistent/dir").unwrap();
        let prog = engine.load("tiny", "forward").unwrap();
        let entry = &engine.manifest.configs["tiny"];
        let (layers, batch) = (entry.layers.clone(), entry.batch);
        let mut rng = Rng::new(3);
        let mut dnet = DenseNet::init_he(&layers, 0.1, &mut rng);
        let mut inputs: Vec<Value> = Vec::new();
        for i in 0..dnet.n_junctions() {
            let (nl, nr) = (layers[i], layers[i + 1]);
            inputs.push(Value::F32(dnet.w[i].clone(), vec![nr, nl]));
            inputs.push(Value::F32(dnet.b[i].clone(), vec![nr]));
        }
        let masks: Vec<Vec<f32>> = (0..dnet.n_junctions())
            .map(|i| {
                (0..layers[i] * layers[i + 1])
                    .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        for (i, m) in masks.iter().enumerate() {
            inputs.push(Value::F32(m.clone(), vec![layers[i + 1], layers[i]]));
        }
        dnet.set_masks(masks);
        let x: Vec<f32> = (0..batch * layers[0]).map(|_| rng.normal()).collect();
        inputs.push(Value::F32(x.clone(), vec![batch, layers[0]]));
        let out = prog.run(&inputs).unwrap();
        let got = out[0].as_f32().unwrap();
        let want = dnet.logits(&x, batch);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }
}
