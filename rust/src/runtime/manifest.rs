//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), plus synthesized built-in configs so the
//! native backend can run with no artifact files at all.
//!
//! Every program follows one fixed positional signature convention
//! (`L` = number of junctions, layers = `[N_0..N_L]`):
//!
//! ```text
//! forward:        [w_i, b_i]*L, [mask_i]*L, x[batch, N_0]
//!                 -> [logits[batch, N_L]]
//! train:          [w_i, b_i]*L, [m_w_i, m_b_i]*L, [v_w_i, v_b_i]*L,
//!                 [mask_i]*L, x, y[batch] i32, t, lr, l2 (scalars)
//!                 -> updated params/m/v in the same order, then
//!                    t+1, mean CE loss, #correct (scalars)
//! gather_forward: [wc_i[N_i, d_in_i]]*L, [idx_i i32]*L, [b_i]*L, x
//!                 -> [logits] (only for uniform-in-degree configs)
//! ```

use std::collections::BTreeMap;

use crate::nn::actsparse::{ActMode, ActSpec};
use crate::nn::fixed::QFormat;
use crate::util::json::Json;

/// Element type of a program tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float (parameters, activations, scalars).
    F32,
    /// 32-bit integer (labels, gather indices).
    I32,
}

/// One positional input/output tensor of a program.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Tensor name (for diagnostics and [`crate::runtime::Program::input_index`]).
    pub name: String,
    /// Expected shape (empty = scalar).
    pub shape: Vec<usize>,
    /// Expected element type.
    pub dtype: Dtype,
}

/// The validated signature of one program.
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    /// Artifact file name (`<native>` for synthesized configs).
    pub file: String,
    /// Positional input tensors.
    pub inputs: Vec<TensorSpec>,
    /// Positional output tensors.
    pub outputs: Vec<TensorSpec>,
}

/// Fixed-point execution parameters of a config: which Qm.n format the
/// quantized programs (`forward_quantized`, the quantized serving path)
/// run in. Manifest syntax: `"quant": "Q5.10"`; every built-in
/// synthesized config carries the default format.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuantSpec {
    /// The Qm.n fixed-point format (see [`crate::nn::fixed::QFormat`]);
    /// defaults to the format's default (Q5.10).
    pub format: QFormat,
}

/// One network configuration and its programs.
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub layers: Vec<usize>,
    /// Batch size the programs are compiled/synthesized for.
    pub batch: usize,
    /// Out-degrees of the `gather_forward` program, when admissible.
    pub gather_dout: Option<Vec<usize>>,
    /// Fixed-point execution parameters; `None` disables the quantized
    /// programs for this config.
    pub quant: Option<QuantSpec>,
    /// Run-time activation sparsity; `None` (the default, and every
    /// built-in config) keeps the weight-sparse-only kernels. Manifest
    /// syntax: `"act_sparsity": {"mode": "topk", "k": 32}` or
    /// `{"mode": "threshold", "threshold": 0.5}`. Does not change any
    /// program signature — it selects the sparse-sparse kernel variants
    /// inside the native engine's `forward`/`train` execution.
    pub act: Option<ActSpec>,
    /// Programs by tag (`forward`, `train`, `gather_forward`,
    /// `forward_quantized`).
    pub programs: BTreeMap<String, ProgramSpec>,
}

/// The full artifact manifest: every servable config.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Configs by name (`tiny`, `mnist_fc2`, ...).
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec, String> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or("spec missing name")?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or("spec missing shape")?
        .iter()
        .map(|v| v.as_usize().ok_or("bad dim"))
        .collect::<Result<Vec<_>, _>>()?;
    let dtype = match j.get("dtype").and_then(|v| v.as_str()) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        other => return Err(format!("unsupported dtype {other:?}")),
    };
    Ok(TensorSpec { name, shape, dtype })
}

/// Cheap host-side config probe (no backend involvement).
pub struct ProbeInfo {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub layers: Vec<usize>,
    /// Compiled/synthesized batch size.
    pub batch: usize,
}

fn spec(name: String, shape: Vec<usize>, dtype: Dtype) -> TensorSpec {
    TensorSpec { name, shape, dtype }
}

impl ConfigEntry {
    /// Synthesize a config (standard program signatures, no artifact
    /// files) for the native backend. `gather_dout` adds a
    /// `gather_forward` program when every junction's in-degree
    /// `N_{i-1} * d_out_i / N_i` is integral; `quant` adds a
    /// `forward_quantized` program (forward signature plus a trailing
    /// saturation-count output) executed in that Qm.n format.
    pub fn synthesize(
        layers: Vec<usize>,
        batch: usize,
        gather_dout: Option<Vec<usize>>,
        quant: Option<QuantSpec>,
    ) -> ConfigEntry {
        let l = layers.len() - 1;
        let n0 = layers[0];
        let classes = layers[l];

        let mut params = Vec::with_capacity(2 * l);
        let mut masks = Vec::with_capacity(l);
        for i in 0..l {
            let (nl, nr) = (layers[i], layers[i + 1]);
            params.push(spec(format!("w{}", i + 1), vec![nr, nl], Dtype::F32));
            params.push(spec(format!("b{}", i + 1), vec![nr], Dtype::F32));
            masks.push(spec(format!("mask{}", i + 1), vec![nr, nl], Dtype::F32));
        }
        let x = spec("x".into(), vec![batch, n0], Dtype::F32);
        let logits = spec("logits".into(), vec![batch, classes], Dtype::F32);

        let mut programs = BTreeMap::new();

        // forward: params, masks, x -> logits
        let mut fin = params.clone();
        fin.extend(masks.iter().cloned());
        fin.push(x.clone());
        programs.insert(
            "forward".to_string(),
            ProgramSpec {
                file: "<native>".into(),
                inputs: fin.clone(),
                outputs: vec![logits.clone()],
            },
        );

        // forward_quantized: same inputs, logits + saturation count out
        if quant.is_some() {
            programs.insert(
                "forward_quantized".to_string(),
                ProgramSpec {
                    file: "<native>".into(),
                    inputs: fin,
                    outputs: vec![
                        logits.clone(),
                        spec("saturations".into(), vec![], Dtype::F32),
                    ],
                },
            );
        }

        // train: params, m, v, masks, x, y, t, lr, l2
        //        -> params', m', v', t+1, loss, correct
        let renamed = |prefix: &str| -> Vec<TensorSpec> {
            params
                .iter()
                .map(|s| spec(format!("{prefix}{}", s.name), s.shape.clone(), s.dtype))
                .collect()
        };
        let mut tin = params.clone();
        tin.extend(renamed("m_"));
        tin.extend(renamed("v_"));
        tin.extend(masks.iter().cloned());
        tin.push(x.clone());
        tin.push(spec("y".into(), vec![batch], Dtype::I32));
        tin.push(spec("t".into(), vec![], Dtype::F32));
        tin.push(spec("lr".into(), vec![], Dtype::F32));
        tin.push(spec("l2".into(), vec![], Dtype::F32));
        let mut tout = params.clone();
        tout.extend(renamed("m_"));
        tout.extend(renamed("v_"));
        tout.push(spec("t_next".into(), vec![], Dtype::F32));
        tout.push(spec("loss".into(), vec![], Dtype::F32));
        tout.push(spec("correct".into(), vec![], Dtype::F32));
        programs.insert(
            "train".to_string(),
            ProgramSpec { file: "<native>".into(), inputs: tin, outputs: tout },
        );

        // gather_forward: wc*, idx*, b*, x -> logits (uniform d_in only)
        if let Some(dout) = &gather_dout {
            let din: Option<Vec<usize>> = (0..l)
                .map(|i| {
                    let (nl, nr) = (layers[i], layers[i + 1]);
                    if (nl * dout[i]) % nr == 0 {
                        Some(nl * dout[i] / nr)
                    } else {
                        None
                    }
                })
                .collect();
            if let Some(din) = din {
                let mut gin = Vec::with_capacity(3 * l + 1);
                for i in 0..l {
                    let nr = layers[i + 1];
                    gin.push(spec(format!("wc{}", i + 1), vec![nr, din[i]], Dtype::F32));
                }
                for i in 0..l {
                    let nr = layers[i + 1];
                    gin.push(spec(format!("idx{}", i + 1), vec![nr, din[i]], Dtype::I32));
                }
                for i in 0..l {
                    gin.push(spec(format!("b{}", i + 1), vec![layers[i + 1]], Dtype::F32));
                }
                gin.push(x);
                programs.insert(
                    "gather_forward".to_string(),
                    ProgramSpec { file: "<native>".into(), inputs: gin, outputs: vec![logits] },
                );
            }
        }

        ConfigEntry { layers, batch, gather_dout, quant, act: None, programs }
    }

    /// Attach an activation-sparsity spec (builder style — program
    /// signatures are unaffected, so this composes with
    /// [`ConfigEntry::synthesize`] output and parsed entries alike).
    pub fn with_act(mut self, spec: ActSpec) -> ConfigEntry {
        self.act = Some(spec);
        self
    }
}

/// Parse the manifest's `"act_sparsity"` object into an [`ActSpec`].
/// A malformed spec is an error, never a silent weight-sparse fallback.
fn parse_act(v: &Json) -> Result<ActSpec, String> {
    let mode = v
        .get("mode")
        .and_then(|m| m.as_str())
        .ok_or("act_sparsity missing mode (\"topk\" or \"threshold\")")?;
    match mode {
        "topk" => {
            let k = v
                .get("k")
                .and_then(|k| k.as_usize())
                .ok_or("act_sparsity topk mode needs an integer \"k\"")?;
            Ok(ActSpec { mode: ActMode::TopK(k) })
        }
        "threshold" => {
            let t = v
                .get("threshold")
                .and_then(|t| t.as_f64())
                .ok_or("act_sparsity threshold mode needs a numeric \"threshold\"")?;
            if !t.is_finite() || t < 0.0 {
                return Err(format!("act_sparsity threshold must be finite and >= 0, got {t}"));
            }
            Ok(ActSpec { mode: ActMode::Threshold(t as f32) })
        }
        other => Err(format!("act_sparsity mode '{other}' (want topk|threshold)")),
    }
}

impl Manifest {
    /// Built-in configs served by the native backend when no
    /// `manifest.json` exists (shapes follow the AOT compile set: the
    /// paper's Table-I MNIST network, its Table-II L=4 MNIST network,
    /// its TIMIT network, and a tiny CI-sized config).
    pub fn builtin() -> Manifest {
        let q = Some(QuantSpec::default());
        let mut configs = BTreeMap::new();
        configs.insert(
            "tiny".to_string(),
            ConfigEntry::synthesize(vec![32, 16, 8], 16, Some(vec![4, 4]), q),
        );
        configs.insert(
            "mnist_fc2".to_string(),
            ConfigEntry::synthesize(vec![800, 100, 10], 256, Some(vec![20, 10]), q),
        );
        configs.insert(
            "mnist_fc4".to_string(),
            ConfigEntry::synthesize(
                vec![800, 100, 100, 100, 10],
                256,
                Some(vec![20, 20, 20, 10]),
                q,
            ),
        );
        configs.insert(
            "timit".to_string(),
            ConfigEntry::synthesize(vec![39, 390, 39], 128, Some(vec![90, 9]), q),
        );
        Manifest { configs }
    }

    /// Read `<dir>/manifest.json` when present, falling back to the
    /// built-in native configs only when the file does not exist; any
    /// other read or parse failure is surfaced rather than silently
    /// replaced with the wrong configs. Parsed manifests additionally
    /// pass through the static lint gate
    /// ([`crate::analysis::quick_lint`]): an error-level finding
    /// (degenerate layers, inadmissible out-degrees, duplicate or
    /// mis-shaped tensors) refuses the manifest here, at load time,
    /// instead of surfacing later inside a worker thread.
    pub fn load_or_builtin(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let m = Manifest::parse(&text)
                    .map_err(|e| anyhow::anyhow!("bad manifest {}: {e}", path.display()))?;
                let report = crate::analysis::quick_lint(&m);
                if report.has_errors() {
                    let first = report
                        .findings
                        .iter()
                        .find(|f| f.severity == crate::analysis::Severity::Error)
                        .expect("has_errors");
                    anyhow::bail!(
                        "manifest {} failed static lint: {first} ({} error finding(s); \
                         run `pds analyze --manifest` for the full report)",
                        path.display(),
                        report.count(crate::analysis::Severity::Error)
                    );
                }
                Ok(m)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Manifest::builtin()),
            Err(e) => Err(anyhow::anyhow!("cannot read {}: {e}", path.display())),
        }
    }

    /// Read just one config's shape info (manifest file when present,
    /// built-in configs otherwise).
    pub fn probe(
        dir: impl AsRef<std::path::Path>,
        config: &str,
    ) -> anyhow::Result<ProbeInfo> {
        let m = Manifest::load_or_builtin(dir)?;
        let entry = m
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?;
        Ok(ProbeInfo {
            layers: entry.layers.clone(),
            batch: entry.batch,
        })
    }

    /// Parse a `manifest.json` document.
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let root = Json::parse(text)?;
        let mut configs = BTreeMap::new();
        let cfgs = root
            .get("configs")
            .and_then(|v| v.as_obj())
            .ok_or("manifest missing configs")?;
        for (name, entry) in cfgs {
            let layers = entry
                .get("layers")
                .and_then(|v| v.as_arr())
                .ok_or("config missing layers")?
                .iter()
                .map(|v| v.as_usize().ok_or("bad layer"))
                .collect::<Result<Vec<_>, _>>()?;
            let batch = entry
                .get("batch")
                .and_then(|v| v.as_usize())
                .ok_or("config missing batch")?;
            let gather_dout = entry.get("gather_dout").and_then(|v| v.as_arr()).map(|a| {
                a.iter()
                    .filter_map(|v| v.as_usize())
                    .collect::<Vec<usize>>()
            });
            // optional fixed-point spec: "quant": "Qm.n" (a malformed
            // format string is an error, not a silent f32 fallback)
            let quant = match entry.get("quant") {
                None => None,
                Some(v) => {
                    let s = v.as_str().ok_or("quant must be a \"Qm.n\" string")?;
                    let format = QFormat::parse(s)
                        .ok_or_else(|| format!("bad quant format '{s}' (want Qm.n)"))?;
                    Some(QuantSpec { format })
                }
            };
            // optional activation sparsity: "act_sparsity": {"mode": ...}
            // (a malformed spec is an error, not a silent dense fallback)
            let act = entry.get("act_sparsity").map(parse_act).transpose()?;
            let mut programs = BTreeMap::new();
            let progs = entry
                .get("programs")
                .and_then(|v| v.as_obj())
                .ok_or("config missing programs")?;
            for (tag, p) in progs {
                let file = p
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or("program missing file")?
                    .to_string();
                let inputs = p
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("program missing inputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?;
                let outputs = p
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("program missing outputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?;
                programs.insert(tag.clone(), ProgramSpec { file, inputs, outputs });
            }
            configs.insert(
                name.clone(),
                ConfigEntry {
                    layers,
                    batch,
                    gather_dout,
                    quant,
                    act,
                    programs,
                },
            );
        }
        Ok(Manifest { configs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"configs": {"tiny": {
        "layers": [32, 16, 8], "batch": 16, "gather_dout": [4, 4],
        "programs": {"train": {"file": "tiny_train.hlo.txt",
            "inputs": [{"name": "w1", "shape": [16, 32], "dtype": "f32"},
                       {"name": "y", "shape": [16], "dtype": "i32"},
                       {"name": "t", "shape": [], "dtype": "f32"}],
            "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}}}}}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let tiny = &m.configs["tiny"];
        assert_eq!(tiny.layers, vec![32, 16, 8]);
        assert_eq!(tiny.batch, 16);
        assert_eq!(tiny.gather_dout, Some(vec![4, 4]));
        let train = &tiny.programs["train"];
        assert_eq!(train.file, "tiny_train.hlo.txt");
        assert_eq!(train.inputs.len(), 3);
        assert_eq!(train.inputs[1].dtype, Dtype::I32);
        assert_eq!(train.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(train.outputs[0].name, "loss");
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("i32", "f64");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn builtin_configs_follow_signature_convention() {
        let m = Manifest::builtin();
        for name in ["tiny", "mnist_fc2", "mnist_fc4", "timit"] {
            let c = &m.configs[name];
            let l = c.layers.len() - 1;
            // train signature: 6L params/opt + L masks + x,y,t,lr,l2
            let train = &c.programs["train"];
            assert_eq!(train.inputs.len(), 7 * l + 5, "{name} train inputs");
            assert_eq!(train.outputs.len(), 6 * l + 3, "{name} train outputs");
            assert_eq!(train.inputs[7 * l + 1].dtype, Dtype::I32, "{name} y dtype");
            let fwd = &c.programs["forward"];
            assert_eq!(fwd.inputs.len(), 3 * l + 1, "{name} forward inputs");
            assert_eq!(fwd.outputs.len(), 1);
            assert_eq!(fwd.outputs[0].shape, vec![c.batch, c.layers[l]]);
            // all built-in configs have admissible gather degrees
            let g = &c.programs["gather_forward"];
            assert_eq!(g.inputs.len(), 3 * l + 1, "{name} gather inputs");
            assert_eq!(g.inputs[l].dtype, Dtype::I32, "{name} idx dtype");
            // every built-in config carries the quantized path
            assert_eq!(c.quant, Some(QuantSpec::default()), "{name} quant");
            let fq = &c.programs["forward_quantized"];
            assert_eq!(fq.inputs, fwd.inputs, "{name} quant inputs");
            assert_eq!(fq.outputs.len(), 2, "{name} quant outputs");
            assert_eq!(fq.outputs[1].name, "saturations");
            assert_eq!(fq.outputs[1].shape, Vec::<usize>::new());
        }
    }

    #[test]
    fn parses_and_rejects_quant_field() {
        let with_quant = SAMPLE.replace(
            "\"batch\": 16,",
            "\"batch\": 16, \"quant\": \"Q4.12\",",
        );
        let m = Manifest::parse(&with_quant).unwrap();
        let q = m.configs["tiny"].quant.unwrap();
        assert_eq!((q.format.int_bits, q.format.frac_bits), (4, 12));
        // absent => None
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.configs["tiny"].quant, None);
        // malformed => parse error, not a silent fallback
        let bad = SAMPLE.replace("\"batch\": 16,", "\"batch\": 16, \"quant\": \"4.12\",");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_and_rejects_act_sparsity_field() {
        use crate::nn::actsparse::{ActMode, ActSpec};
        let topk = SAMPLE.replace(
            "\"batch\": 16,",
            "\"batch\": 16, \"act_sparsity\": {\"mode\": \"topk\", \"k\": 8},",
        );
        let m = Manifest::parse(&topk).unwrap();
        assert_eq!(m.configs["tiny"].act, Some(ActSpec::top_k(8)));
        let thr = SAMPLE.replace(
            "\"batch\": 16,",
            "\"batch\": 16, \"act_sparsity\": {\"mode\": \"threshold\", \"threshold\": 0.5},",
        );
        let m = Manifest::parse(&thr).unwrap();
        assert_eq!(
            m.configs["tiny"].act,
            Some(ActSpec { mode: ActMode::Threshold(0.5) })
        );
        // absent => None (and every builtin stays weight-sparse-only)
        assert_eq!(Manifest::parse(SAMPLE).unwrap().configs["tiny"].act, None);
        for c in Manifest::builtin().configs.values() {
            assert_eq!(c.act, None);
        }
        // malformed specs are errors, not silent fallbacks
        for bad in [
            "{\"mode\": \"topk\"}",
            "{\"mode\": \"threshold\"}",
            "{\"mode\": \"softmax\"}",
            "{\"k\": 8}",
            "{\"mode\": \"threshold\", \"threshold\": -1.0}",
        ] {
            let doc = SAMPLE.replace(
                "\"batch\": 16,",
                &format!("\"batch\": 16, \"act_sparsity\": {bad},"),
            );
            assert!(Manifest::parse(&doc).is_err(), "must reject {bad}");
        }
        // the builder attaches a spec without touching program arity
        let entry = ConfigEntry::synthesize(vec![8, 4, 2], 4, None, None);
        let fwd_inputs = entry.programs["forward"].inputs.len();
        let entry = entry.with_act(ActSpec::top_k(2));
        assert_eq!(entry.act, Some(ActSpec::top_k(2)));
        assert_eq!(entry.programs["forward"].inputs.len(), fwd_inputs);
    }

    #[test]
    fn probe_falls_back_to_builtin() {
        let p = Manifest::probe("/nonexistent/dir", "tiny").unwrap();
        assert_eq!(p.layers, vec![32, 16, 8]);
        assert_eq!(p.batch, 16);
        assert!(Manifest::probe("/nonexistent/dir", "nope").is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.configs.contains_key("tiny"));
            let tiny = &m.configs["tiny"];
            // train signature: 6L params + L masks + x,y,t,lr,l2
            let train = &tiny.programs["train"];
            let l = tiny.layers.len() - 1;
            assert_eq!(train.inputs.len(), 7 * l + 5);
            assert_eq!(train.outputs.len(), 6 * l + 3);
        }
    }
}
