//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`).

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub layers: Vec<usize>,
    pub batch: usize,
    pub gather_dout: Option<Vec<usize>>,
    pub programs: BTreeMap<String, ProgramSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec, String> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or("spec missing name")?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or("spec missing shape")?
        .iter()
        .map(|v| v.as_usize().ok_or("bad dim"))
        .collect::<Result<Vec<_>, _>>()?;
    let dtype = match j.get("dtype").and_then(|v| v.as_str()) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        other => return Err(format!("unsupported dtype {other:?}")),
    };
    Ok(TensorSpec { name, shape, dtype })
}

/// Cheap host-side config probe (no PJRT involvement).
pub struct ProbeInfo {
    pub layers: Vec<usize>,
    pub batch: usize,
}

impl Manifest {
    /// Read just one config's shape info from `<dir>/manifest.json`.
    pub fn probe(
        dir: impl AsRef<std::path::Path>,
        config: &str,
    ) -> anyhow::Result<ProbeInfo> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} — run `make artifacts`", path.display()))?;
        let m = Manifest::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?;
        let entry = m
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?;
        Ok(ProbeInfo {
            layers: entry.layers.clone(),
            batch: entry.batch,
        })
    }

    pub fn parse(text: &str) -> Result<Manifest, String> {
        let root = Json::parse(text)?;
        let mut configs = BTreeMap::new();
        let cfgs = root
            .get("configs")
            .and_then(|v| v.as_obj())
            .ok_or("manifest missing configs")?;
        for (name, entry) in cfgs {
            let layers = entry
                .get("layers")
                .and_then(|v| v.as_arr())
                .ok_or("config missing layers")?
                .iter()
                .map(|v| v.as_usize().ok_or("bad layer"))
                .collect::<Result<Vec<_>, _>>()?;
            let batch = entry
                .get("batch")
                .and_then(|v| v.as_usize())
                .ok_or("config missing batch")?;
            let gather_dout = entry.get("gather_dout").and_then(|v| v.as_arr()).map(|a| {
                a.iter()
                    .filter_map(|v| v.as_usize())
                    .collect::<Vec<usize>>()
            });
            let mut programs = BTreeMap::new();
            let progs = entry
                .get("programs")
                .and_then(|v| v.as_obj())
                .ok_or("config missing programs")?;
            for (tag, p) in progs {
                let file = p
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or("program missing file")?
                    .to_string();
                let inputs = p
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("program missing inputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?;
                let outputs = p
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("program missing outputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?;
                programs.insert(tag.clone(), ProgramSpec { file, inputs, outputs });
            }
            configs.insert(
                name.clone(),
                ConfigEntry {
                    layers,
                    batch,
                    gather_dout,
                    programs,
                },
            );
        }
        Ok(Manifest { configs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"configs": {"tiny": {
        "layers": [32, 16, 8], "batch": 16, "gather_dout": [4, 4],
        "programs": {"train": {"file": "tiny_train.hlo.txt",
            "inputs": [{"name": "w1", "shape": [16, 32], "dtype": "f32"},
                       {"name": "y", "shape": [16], "dtype": "i32"},
                       {"name": "t", "shape": [], "dtype": "f32"}],
            "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}}}}}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let tiny = &m.configs["tiny"];
        assert_eq!(tiny.layers, vec![32, 16, 8]);
        assert_eq!(tiny.batch, 16);
        assert_eq!(tiny.gather_dout, Some(vec![4, 4]));
        let train = &tiny.programs["train"];
        assert_eq!(train.file, "tiny_train.hlo.txt");
        assert_eq!(train.inputs.len(), 3);
        assert_eq!(train.inputs[1].dtype, Dtype::I32);
        assert_eq!(train.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(train.outputs[0].name, "loss");
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("i32", "f64");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.configs.contains_key("tiny"));
            let tiny = &m.configs["tiny"];
            // train signature: 6L params + L masks + x,y,t,lr,l2
            let train = &tiny.programs["train"];
            let l = tiny.layers.len() - 1;
            assert_eq!(train.inputs.len(), 7 * l + 5);
            assert_eq!(train.outputs.len(), 6 * l + 3);
        }
    }
}
