//! Micro-bench harness (criterion is unavailable offline).
//!
//! Warmup + N timed iterations, reports median / mean / p95 and a derived
//! throughput. Used by every target under rust/benches/.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations run.
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// Mean iteration time.
    pub mean: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
}

impl BenchResult {
    /// Iterations per second at the median time.
    pub fn per_sec(&self) -> f64 {
        if self.median.as_secs_f64() > 0.0 {
            1.0 / self.median.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }

    /// Print the one-line median/mean/p95 summary.
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} median  {:>10} mean  {:>10} p95  {:>12.1}/s  ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.per_sec(),
            self.iters
        );
    }

    /// One-line report with a unit count per iteration (e.g. edges, requests).
    pub fn report_throughput(&self, unit: &str, units_per_iter: f64) {
        println!(
            "{:<44} {:>10} median  {:>14.3e} {unit}/s  ({} iters)",
            self.name,
            fmt_dur(self.median),
            units_per_iter * self.per_sec(),
            self.iters
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean,
        p95,
        min: samples[0],
    }
}

/// Auto-pick an iteration count so each bench takes ~`target` of wall time.
pub fn bench_auto<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (target.as_secs_f64() / once.as_secs_f64()).clamp(5.0, 10_000.0) as usize;
    bench(name, (iters / 10).max(1), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports_sane_stats() {
        let mut count = 0u64;
        let r = bench("noop", 2, 50, || {
            count += 1;
        });
        assert_eq!(r.iters, 50);
        assert_eq!(count, 52);
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(50)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
