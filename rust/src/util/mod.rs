//! In-tree replacements for crates unavailable in the offline build
//! (see DESIGN.md §Dependencies): deterministic PRNG, minimal JSON,
//! micro-bench harness, scoped fork-join parallelism, a property-test
//! driver, and poison-tolerant lock helpers.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod sync;

/// Greatest common divisor (Appendix A density-set math).
pub fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Ceiling division, used throughout the hardware cycle math.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Sample standard deviation (0.0 for < 2 elements).
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / (xs.len() - 1) as f32).sqrt()
}

/// Half-width of a 90% confidence interval on the mean (the paper reports
/// 90% CIs over >= 5 runs, Sec. IV-A). Uses the normal approximation.
pub fn ci90(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.645 * std_dev(xs) / (xs.len() as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basic() {
        assert_eq!(gcd(800, 100), 100);
        assert_eq!(gcd(117, 390), 39);
        assert_eq!(gcd(390, 13), 13);
        assert_eq!(gcd(7, 1), 1);
        assert_eq!(gcd(0, 5), 5);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(6, 3), 2);
        assert_eq!(ceil_div(7, 3), 3);
        assert_eq!(ceil_div(1, 3), 1);
    }

    #[test]
    fn stats_basic() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-5);
        assert!(ci90(&xs) > 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }
}
