//! Minimal fork-join parallelism over `std::thread::scope` (rayon is
//! unavailable in the offline build; see DESIGN.md §Dependencies).
//!
//! Two shapes cover every hot path in the crate:
//! - [`par_rows`]: split a row-major output buffer into contiguous
//!   per-thread chunks of whole rows — each row is written by exactly one
//!   thread (FF / BP, batched over the batch dimension),
//! - [`par_batch_reduce`]: fold a batch range into an accumulator with
//!   per-thread partial buffers merged serially (UP / weight gradients).
//!
//! Threading only engages when the estimated work amortizes thread spawn
//! (~tens of microseconds); below the threshold everything runs inline on
//! the caller's thread, so tiny unit-test problems stay deterministic and
//! fast. The thread count is `PDS_THREADS` if set, else
//! `available_parallelism`, and can be overridden at runtime with
//! [`set_threads`] (used by the benches to measure parallel speedup
//! against the single-threaded kernels).

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = auto-detect; anything else is an explicit override.
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// Cached auto-detected count (0 = not yet detected).
static AUTO: AtomicUsize = AtomicUsize::new(0);

/// Minimum estimated scalar operations per worker before threading pays
/// for itself. Threads are spawned per call (scoped, no persistent pool),
/// so each worker must amortize a ~10-50us spawn: 128k f32 ops is ~50us+
/// of compute, comfortably above the spawn cost while still engaging all
/// cores on real batched workloads (e.g. a batch-256 800x100 junction is
/// ~20M ops).
const MIN_WORK_PER_THREAD: usize = 1 << 17;

/// Override the worker-thread count (`set_threads(1)` forces the serial
/// path, `set_threads(0)` restores auto-detection).
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// The current explicit override (0 = auto-detection) — what a caller
/// that temporarily pins the budget must save and restore.
pub fn thread_override() -> usize {
    OVERRIDE.load(Ordering::Relaxed)
}

/// Detected machine budget: `PDS_THREADS` if set, else
/// `available_parallelism`, ignoring any [`set_threads`] override.
fn auto_threads() -> usize {
    let cached = AUTO.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("PDS_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, 64);
    AUTO.store(n, Ordering::Relaxed);
    n
}

/// Current maximum number of worker threads.
pub fn max_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    auto_threads()
}

/// The detected machine budget itself (`PDS_THREADS` if set, else
/// `available_parallelism`), independent of any [`set_threads`]
/// override — the quantity [`worker_thread_budget`] divides.
pub fn machine_threads() -> usize {
    auto_threads()
}

/// Kernel-thread budget for each of `workers` concurrent batch-serving
/// threads: the detected machine budget (`PDS_THREADS` or
/// `available_parallelism`, not any [`set_threads`] override) divided
/// evenly, so that worker count × per-batch kernel threads does not
/// oversubscribe the cores. Always at least 1. The inference service
/// applies this via [`set_threads`] when its `tune_kernel_threads`
/// config flag is set.
pub fn worker_thread_budget(workers: usize) -> usize {
    (auto_threads() / workers.max(1)).max(1)
}

/// Thread count worth using for `items` units of `work_per_item` scalar
/// operations each (1 = run inline). Public so callers can pick a
/// zero-copy serial path when threading will not engage.
pub fn threads_for(items: usize, work_per_item: usize) -> usize {
    let total = items.saturating_mul(work_per_item);
    let by_work = (total / MIN_WORK_PER_THREAD).max(1);
    max_threads().min(by_work).min(items.max(1))
}

/// Serializes tests that mutate the global thread override (cargo runs
/// unit tests concurrently in one process, so unsynchronized
/// `set_threads` calls from different tests race).
#[cfg(test)]
pub(crate) fn override_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Process `out` (row-major, `row_width` elements per row) in parallel:
/// `f(first_row, chunk)` receives a contiguous chunk of whole rows
/// starting at global row index `first_row`. Rows must be independent.
/// `work_per_row` is an estimate of scalar operations per row, used to
/// decide whether threading pays. Generic over the element type so the
/// f32 kernels and the fixed-point (`i32` raw word) kernels share one
/// fork-join shape.
pub fn par_rows<T: Send, F>(out: &mut [T], row_width: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_width > 0 && out.len() % row_width == 0);
    let rows = out.len() / row_width;
    let threads = threads_for(rows, work_per_row);
    if threads <= 1 {
        f(0, out);
        return;
    }
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let mut first_row = 0usize;
        while !rest.is_empty() {
            let take = (rows_per * row_width).min(rest.len());
            // move `rest` out so the split halves keep the outer lifetime
            let tmp = rest;
            let (head, tail) = tmp.split_at_mut(take);
            rest = tail;
            let row0 = first_row;
            first_row += take / row_width;
            if rest.is_empty() {
                // run the last chunk on the calling thread
                f(row0, head);
            } else {
                s.spawn(move || f(row0, head));
            }
        }
    });
}

/// Fold the batch range `0..batch` into `acc`: `f(range, partial)` must
/// *add* its contribution for `range` into `partial`. Parallel execution
/// gives each thread a zeroed partial buffer and merges by element-wise
/// addition, so existing contents of `acc` are preserved (accumulate
/// semantics, like the serial path).
pub fn par_batch_reduce<F>(batch: usize, work_per_item: usize, acc: &mut [f32], f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let threads = threads_for(batch, work_per_item);
    if threads <= 1 {
        f(0..batch, acc);
        return;
    }
    let per = batch.div_ceil(threads);
    let n_chunks = batch.div_ceil(per);
    let mut partials: Vec<Vec<f32>> = (1..n_chunks).map(|_| vec![0f32; acc.len()]).collect();
    std::thread::scope(|s| {
        let f = &f;
        for (ci, buf) in partials.iter_mut().enumerate() {
            let lo = (ci + 1) * per;
            let hi = (lo + per).min(batch);
            s.spawn(move || f(lo..hi, buf));
        }
        f(0..per.min(batch), acc);
    });
    for buf in &partials {
        for (a, b) in acc.iter_mut().zip(buf) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rows_covers_every_row_once() {
        // large enough to engage threading regardless of core count
        let rows = 257;
        let width = 3;
        let mut out = vec![0f32; rows * width];
        par_rows(&mut out, width, MIN_WORK_PER_THREAD, |row0, chunk| {
            for (i, r) in chunk.chunks_mut(width).enumerate() {
                for v in r.iter_mut() {
                    *v += (row0 + i) as f32;
                }
            }
        });
        for (i, r) in out.chunks(width).enumerate() {
            assert!(r.iter().all(|&v| v == i as f32), "row {i}: {r:?}");
        }
    }

    #[test]
    fn par_batch_reduce_matches_serial_sum_and_accumulates() {
        let batch = 1000;
        let mut acc = vec![1f32; 8];
        par_batch_reduce(batch, MIN_WORK_PER_THREAD, &mut acc, |range, part| {
            for i in range {
                for (j, p) in part.iter_mut().enumerate() {
                    *p += (i * (j + 1)) as f32;
                }
            }
        });
        for (j, &v) in acc.iter().enumerate() {
            let want = 1.0 + ((batch * (batch - 1) / 2) * (j + 1)) as f32;
            assert!((v - want).abs() < want * 1e-6, "j={j}: {v} vs {want}");
        }
    }

    #[test]
    fn small_work_stays_serial() {
        // threads_for must return 1 for tiny problems
        assert_eq!(threads_for(4, 10), 1);
        assert_eq!(threads_for(0, 100), 1);
    }

    #[test]
    fn worker_budget_divides_without_oversubscribing() {
        let _guard = override_guard();
        // the budget ignores the override: it divides the machine's
        // detected parallelism, not whatever a bench pinned
        set_threads(1);
        let full = worker_thread_budget(1);
        assert!(full >= 1);
        assert!(worker_thread_budget(2) <= full);
        assert_eq!(worker_thread_budget(usize::MAX), 1);
        // workers * per-worker budget never exceeds the machine budget
        for workers in [1usize, 2, 3, 8, 64] {
            assert!(worker_thread_budget(workers) * workers <= full.max(workers));
        }
        set_threads(0);
    }

    #[test]
    fn set_threads_overrides_and_resets() {
        let _guard = override_guard();
        set_threads(1);
        assert_eq!(max_threads(), 1);
        set_threads(0);
        assert!(max_threads() >= 1);
    }
}
