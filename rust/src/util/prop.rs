//! Property-test driver (proptest is unavailable offline).
//!
//! `for_all` runs a closure over `cases` generated inputs from a seeded
//! generator and panics with the failing seed + case index, so failures
//! are reproducible by pinning the seed. No shrinking — generators are kept
//! small enough that raw cases are readable.

use super::rng::Rng;

/// Default generated-case count for property tests.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` over `cases` inputs drawn by `gen`. Panics on first failure
/// with the reproducing seed.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case_idx} (seed {case_seed:#x}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all(
            "x < x + 1",
            7,
            64,
            |r| r.below(1000),
            |&x| {
                if x < x + 1 {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_seed() {
        for_all("always fails", 7, 4, |r| r.below(10), |_| Err("nope".into()));
    }
}
