//! Minimal JSON parser/writer for the artifact manifest (serde is
//! unavailable in the offline build). Supports the full JSON grammar the
//! manifest uses: objects, arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so output is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The object's map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The array's elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 scalar as-is
                    let s = &self.bytes[self.pos..];
                    let ch_len = match s[0] {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    out.push_str(
                        std::str::from_utf8(&s[..ch_len.min(s.len())])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.pos += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"configs": {"tiny": {"layers": [32, 16, 8], "batch": 16,
            "programs": {"train": {"file": "tiny_train.hlo.txt",
            "inputs": [{"name": "w1", "shape": [16, 32], "dtype": "f32"}]}}}}}"#;
        let j = Json::parse(text).unwrap();
        let tiny = j.get("configs").unwrap().get("tiny").unwrap();
        let layers: Vec<usize> = tiny
            .get("layers")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(layers, vec![32, 16, 8]);
        assert_eq!(tiny.get("batch").unwrap().as_usize(), Some(16));
        let train = tiny.get("programs").unwrap().get("train").unwrap();
        assert_eq!(train.get("file").unwrap().as_str(), Some("tiny_train.hlo.txt"));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""aA\nb""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\nb"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.5e2").unwrap().as_f64(), Some(350.0));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
