//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every experiment in the repo threads explicit seeds through this so the
//! paper-reproduction tables are bit-reproducible run to run.

/// Deterministic xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator (any seed value is fine, including 0).
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased via rejection sampling.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.uniform64().max(1e-300)) as f32;
        let u2 = self.uniform() ;
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct values sampled from 0..n (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    /// A fresh independent stream (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let m = sum / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(4);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 12);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 12);
            assert!(s.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
