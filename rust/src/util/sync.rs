//! Poison-tolerant synchronization helpers.
//!
//! `std::sync::Mutex` poisons itself when a holder panics, and the
//! idiomatic `.lock().unwrap()` then converts *one* panicked thread
//! into a panic cascade across every other thread that touches the
//! same lock — in the serving stack that means a single failing
//! connection or responder could take down the whole server. The data
//! guarded by the crate's locks (response byte queues, counters,
//! dirty-token lists) stays structurally valid at every await-free
//! step, so recovering the guard is always safe here.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `Condvar::wait` with the same poison recovery.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `Condvar::wait_timeout` with the same poison recovery.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, timeout)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
