//! Degree-of-parallelism configuration z_net (Sec. III-A, Appendix B).
//!
//! The z values are chosen so every junction finishes any operation in the
//! same junction cycle `C = |W_i| / z_i`, which is what makes the L-stage
//! pipeline stall-free; eq. (9) additionally bounds the right-bank access
//! rate (`z_{i+1} >= ceil(z_i / d_in_i)`).

use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::util::ceil_div;

/// A validated degree-of-parallelism configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ZConfig {
    /// Degree of parallelism per junction (edge processors clocked each
    /// cycle).
    pub z: Vec<usize>,
    /// Junction cycle C = max_i |W_i|/z_i: the pipeline advances at the
    /// pace of the slowest junction; faster junctions idle (the paper's
    /// published Table-II z_nets are *approximately* balanced — e.g. the
    /// MNIST L=4 row gives C = (320, 320, 320, 250)).
    pub junction_cycle: usize,
    /// Per-junction operation cycles |W_i|/z_i.
    pub cycles: Vec<usize>,
    /// True when C_i is identical across junctions (the ideal of
    /// Sec. III-A, zero idle cycles).
    pub balanced: bool,
}

impl ZConfig {
    /// Fraction of edge-processor cycles spent idle waiting for the
    /// slowest junction (0.0 when perfectly balanced).
    pub fn idle_fraction(&self) -> f64 {
        let c = self.junction_cycle as f64;
        let idle: f64 = self.cycles.iter().map(|&ci| c - ci as f64).sum();
        idle / (c * self.cycles.len() as f64)
    }
}

/// Why a z_net is rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ZConfigError {
    /// z_net length differs from the junction count.
    WrongLength { got: usize, want: usize },
    /// `z_i` does not divide the junction's edge count `|W_i|`.
    NotDividing { junction: usize, edges: usize, z: usize },
    /// `z_i` does not divide `N_{i-1}` (the Appendix B memory-depth rule).
    DepthNotIntegral { junction: usize, n_left: usize, z: usize },
    /// Junction cycles `C_i` are not all equal (only raised by
    /// [`validate_strict`]).
    Unbalanced { cycles: Vec<usize> },
    /// `z_{i+1}` cannot absorb junction i's right-neuron completion rate
    /// (eq. 9).
    RightBankOverrun { junction: usize, need: usize, have: usize },
}

impl std::fmt::Display for ZConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ZConfigError::WrongLength { got, want } => {
                write!(f, "z_net has {got} entries, want {want}")
            }
            ZConfigError::NotDividing { junction, edges, z } => {
                write!(f, "junction {junction}: z={z} does not divide |W|={edges}")
            }
            ZConfigError::DepthNotIntegral { junction, n_left, z } => {
                write!(f, "junction {junction}: z={z} does not divide N_left={n_left} (Appendix B)")
            }
            ZConfigError::Unbalanced { cycles } => {
                write!(f, "junction cycles unbalanced: {cycles:?} (need C_i = C for all i)")
            }
            ZConfigError::RightBankOverrun { junction, need, have } => {
                write!(
                    f,
                    "junction {junction}: right bank needs z >= {need} (= ceil(z_i/d_in_i), eq. 9) but has {have}"
                )
            }
        }
    }
}

/// Validate a hand-picked z_net against `net` + `dout` (the Table-II
/// experiments specify explicit z_net per hardware budget).
pub fn validate(
    net: &NetConfig,
    dout: &DoutConfig,
    z: &[usize],
) -> Result<ZConfig, ZConfigError> {
    let l = net.n_junctions();
    if z.len() != l {
        return Err(ZConfigError::WrongLength { got: z.len(), want: l });
    }
    let edges = net.edges(dout);
    let din = net.din(dout);
    let mut cycles = Vec::with_capacity(l);
    for i in 0..l {
        if z[i] == 0 || edges[i] % z[i] != 0 {
            return Err(ZConfigError::NotDividing { junction: i, edges: edges[i], z: z[i] });
        }
        if net.layers[i] % z[i] != 0 {
            return Err(ZConfigError::DepthNotIntegral {
                junction: i,
                n_left: net.layers[i],
                z: z[i],
            });
        }
        cycles.push(edges[i] / z[i]);
    }
    // eq. (9): right-bank parallelism of junction i must absorb the rate at
    // which junction i finishes right neurons.
    for i in 0..l - 1 {
        let need = ceil_div(z[i], din[i]);
        if z[i + 1] < need {
            return Err(ZConfigError::RightBankOverrun { junction: i, need, have: z[i + 1] });
        }
    }
    let junction_cycle = *cycles.iter().max().unwrap();
    let balanced = cycles.iter().all(|&c| c == junction_cycle);
    Ok(ZConfig {
        z: z.to_vec(),
        junction_cycle,
        cycles,
        balanced,
    })
}

/// Nearest-balanced z_net for raw per-junction edge counts.
///
/// The [`validate`]/[`derive`] pair works from a `(NetConfig, DoutConfig)`
/// pair, i.e. uniform in-degrees. The software pipelined trainer
/// (`nn::pipeline`) instead starts from a *generated* pattern whose edge
/// counts are whatever the pattern produced, so this helper picks, per
/// junction, the largest operation-cycle count `C_i = |W_i| / z_i` that
/// divides `|W_i|` while not exceeding `c_target` — giving near-equal
/// stage times (the Sec. III-A balance rule) with exact division
/// guaranteed. The returned [`ZConfig`] reports whether perfect balance
/// was achieved.
pub fn balanced_for_edges(edges: &[usize], c_target: usize) -> ZConfig {
    assert!(!edges.is_empty() && edges.iter().all(|&e| e > 0), "empty junction");
    let c_target = c_target.max(1);
    let mut z = Vec::with_capacity(edges.len());
    let mut cycles = Vec::with_capacity(edges.len());
    for &e in edges {
        let mut c = c_target.min(e);
        while e % c != 0 {
            c -= 1;
        }
        z.push(e / c);
        cycles.push(c);
    }
    let junction_cycle = *cycles.iter().max().unwrap();
    let balanced = cycles.iter().all(|&c| c == junction_cycle);
    ZConfig {
        z,
        junction_cycle,
        cycles,
        balanced,
    }
}

/// Like [`validate`] but additionally requires perfectly balanced junction
/// cycles (C_i = C for all i, the Sec. III-A ideal).
pub fn validate_strict(
    net: &NetConfig,
    dout: &DoutConfig,
    z: &[usize],
) -> Result<ZConfig, ZConfigError> {
    let cfg = validate(net, dout, z)?;
    if !cfg.balanced {
        return Err(ZConfigError::Unbalanced { cycles: cfg.cycles });
    }
    Ok(cfg)
}

/// Derive a balanced z_net given the parallelism budget for junction 0
/// (`z_0`): z_i = |W_i| * z_0 / |W_0|, i.e. C_i = C_0 for all junctions.
/// Fails if the implied z values are fractional or violate Appendix B.
pub fn derive(net: &NetConfig, dout: &DoutConfig, z0: usize) -> Result<ZConfig, ZConfigError> {
    let edges = net.edges(dout);
    if edges[0] % z0 != 0 {
        return Err(ZConfigError::NotDividing { junction: 0, edges: edges[0], z: z0 });
    }
    let c = edges[0] / z0;
    let z: Vec<usize> = edges
        .iter()
        .map(|&e| if e % c == 0 { e / c } else { 0 })
        .collect();
    if let Some(i) = z.iter().position(|&zi| zi == 0) {
        return Err(ZConfigError::NotDividing { junction: i, edges: edges[i], z: c });
    }
    validate(net, dout, &z)
}

/// Largest z_net whose total parallel-MAC count fits `budget` logic units
/// (the "given FPGA supports some largest z" sizing rule from the intro).
pub fn derive_for_budget(
    net: &NetConfig,
    dout: &DoutConfig,
    budget: usize,
) -> Option<ZConfig> {
    let mut best: Option<ZConfig> = None;
    let edges0 = net.edges(dout)[0];
    for z0 in 1..=edges0 {
        if edges0 % z0 != 0 {
            continue;
        }
        if let Ok(cfg) = derive(net, dout, z0) {
            let total: usize = cfg.z.iter().sum();
            if total <= budget {
                best = Some(cfg);
            } else {
                break;
            }
        }
    }
    best
}

/// Throughput in inputs per clock cycle: one input completes per junction
/// cycle in steady state (Sec. III-A).
pub fn throughput(cfg: &ZConfig) -> f64 {
    1.0 / cfg.junction_cycle as f64
}

/// Left-bank of neuron `n` under the Appendix-B z-regular banking: the
/// activation memory is `z` banks of depth `N_left / z`, neuron `n`
/// living in bank `n mod z`. This is the structural fact the activation-
/// sparsity packed layout ([`crate::nn::actsparse::PackedRow`]) rides
/// on: within any aligned window of `z` consecutive neurons every bank
/// appears exactly once, so a wave drawn from one window can never
/// claim a bank twice.
#[inline]
pub fn bank_of(n: usize, z: usize) -> usize {
    n % z
}

/// Number of z-regular activation waves for a layer of width `n_left`
/// banked `z` ways — `Err` with the same Appendix-B diagnostic as
/// [`validate`] when `z` does not divide the width.
pub fn act_waves(n_left: usize, z: usize) -> Result<usize, ZConfigError> {
    if z == 0 || n_left % z != 0 {
        return Err(ZConfigError::DepthNotIntegral { junction: 0, n_left, z });
    }
    Ok(n_left / z)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist() -> (NetConfig, DoutConfig) {
        (NetConfig::new(vec![800, 100, 10]), DoutConfig(vec![20, 10]))
    }

    #[test]
    fn validates_balanced_config() {
        let (net, dout) = mnist();
        // |W| = (16000, 1000); z = (160, 10) -> C = 100 both
        let cfg = validate(&net, &dout, &[160, 10]).unwrap();
        assert_eq!(cfg.junction_cycle, 100);
    }

    #[test]
    fn unbalanced_configs_run_at_max_cycle() {
        let (net, dout) = mnist();
        let cfg = validate(&net, &dout, &[160, 20]).unwrap();
        assert!(!cfg.balanced);
        assert_eq!(cfg.cycles, vec![100, 50]);
        assert_eq!(cfg.junction_cycle, 100);
        assert!((cfg.idle_fraction() - 0.25).abs() < 1e-9);
        assert!(matches!(
            validate_strict(&net, &dout, &[160, 20]),
            Err(ZConfigError::Unbalanced { .. })
        ));
    }

    #[test]
    fn rejects_non_dividing_and_bad_depth() {
        let (net, dout) = mnist();
        assert!(matches!(
            validate(&net, &dout, &[3, 10]),
            Err(ZConfigError::NotDividing { .. })
        ));
        // z=32 divides |W1|=16000? 16000/32=500, but 800 % 32 = 0, so pick
        // one that breaks Appendix B: z=64 -> 16000%64=0, 800%64=32 != 0
        assert!(matches!(
            validate(&net, &dout, &[64, 4]),
            Err(ZConfigError::DepthNotIntegral { .. })
        ));
    }

    #[test]
    fn eq9_right_bank_constraint() {
        // junction 0: z=200, d_in=160 -> ceil(200/160)=2 right writes per
        // cycle; z_2 = 1 would overrun. Need C equal: |W|=(16000,1000):
        // z=(200,?) -> C=80 -> z2 = 12.5, not integral; use the paper's
        // Table II MNIST row instead: N=(800,100,...) is L=4; simpler toy:
        let net = NetConfig::new(vec![8, 4, 8]);
        let dout = DoutConfig(vec![4, 4]);
        // edges = (32, 16); d_in = (8, 2); z=(8,4): C=(4,4) ok; eq9: ceil(8/8)=1 <= 4 ok
        assert!(validate(&net, &dout, &[8, 4]).is_ok());
        let net2 = NetConfig::new(vec![4, 4, 2]);
        let dout2 = DoutConfig(vec![1, 1]);
        // edges=(4,2), din=(1,2); z=(2,1): C=(2,2); eq9: ceil(2/1)=2 > 1 -> overrun
        assert!(matches!(
            validate(&net2, &dout2, &[2, 1]),
            Err(ZConfigError::RightBankOverrun { need: 2, have: 1, .. })
        ));
    }

    #[test]
    fn derive_balances_cycles() {
        let (net, dout) = mnist();
        let cfg = derive(&net, &dout, 160).unwrap();
        assert_eq!(cfg.z, vec![160, 10]);
        assert_eq!(cfg.junction_cycle, 100);
    }

    #[test]
    fn derive_for_budget_is_maximal() {
        let (net, dout) = mnist();
        let cfg = derive_for_budget(&net, &dout, 250).unwrap();
        let total: usize = cfg.z.iter().sum();
        assert!(total <= 250);
        // the next valid config up must exceed the budget
        let next = derive(&net, &dout, cfg.z[0] * 2);
        if let Ok(n) = next {
            assert!(n.z.iter().sum::<usize>() > 250);
        }
    }

    #[test]
    fn table2_z_configs_validate() {
        // Paper Table II rows (z_net column) — these are real, published
        // hardware configurations and must pass our validator.
        let cases: Vec<(Vec<usize>, Vec<usize>, Vec<usize>)> = vec![
            // MNIST L=4: N_net, d_out, z_net
            (vec![800, 100, 100, 100, 10], vec![80, 80, 80, 10], vec![200, 25, 25, 4]),
            (vec![800, 100, 100, 100, 10], vec![20, 20, 20, 10], vec![200, 25, 25, 10]),
            (vec![800, 100, 100, 100, 10], vec![1, 2, 2, 10], vec![80, 20, 20, 100]),
            // Reuters
            (vec![2000, 50, 50], vec![25, 25], vec![1000, 25]),
            (vec![2000, 50, 50], vec![1, 1], vec![40, 1]),
            // TIMIT
            (vec![39, 390, 39], vec![90, 9], vec![13, 13]),
            // CIFAR-100 MLP
            (vec![4000, 500, 100], vec![100, 100], vec![2000, 250]),
            (vec![4000, 500, 100], vec![2, 2], vec![80, 10]),
        ];
        for (layers, dout, z) in cases {
            let net = NetConfig::new(layers.clone());
            let cfg = validate(&net, &DoutConfig(dout.clone()), &z)
                .unwrap_or_else(|e| panic!("paper config {layers:?}/{dout:?}/{z:?}: {e}"));
            assert!(cfg.junction_cycle > 0);
            // paper configs are nearly balanced: < 20% idle
            assert!(cfg.idle_fraction() < 0.20, "{layers:?}: idle {}", cfg.idle_fraction());
        }
    }

    #[test]
    fn balanced_for_edges_divides_exactly_and_balances() {
        // equal edge counts balance perfectly at any target
        let cfg = balanced_for_edges(&[3510, 3510], 110);
        assert!(cfg.balanced);
        assert_eq!(cfg.cycles, vec![90, 90]);
        assert_eq!(cfg.z, vec![39, 39]);
        for (z, e) in cfg.z.iter().zip([3510usize, 3510]) {
            assert_eq!(e % z, 0);
        }
        // uneven counts: every cycle count divides its edges and stays
        // within the target
        let cfg = balanced_for_edges(&[16000, 1000, 7], 100);
        for ((&z, &c), &e) in cfg.z.iter().zip(&cfg.cycles).zip(&[16000usize, 1000, 7]) {
            assert_eq!(z * c, e);
            assert!(c <= 100);
        }
        assert_eq!(cfg.junction_cycle, *cfg.cycles.iter().max().unwrap());
        // degenerate target clamps to 1 cycle
        let cfg = balanced_for_edges(&[12], 0);
        assert_eq!(cfg.cycles, vec![1]);
        assert_eq!(cfg.z, vec![12]);
    }

    #[test]
    fn balanced_for_edges_prime_counts() {
        // prime |W|: the only admissible cycle counts are 1 and |W|, so
        // any target below |W| collapses to C = 1 (fully parallel) —
        // exact division must still hold and nothing may panic
        for e in [7usize, 13, 101, 997] {
            let cfg = balanced_for_edges(&[e], e / 2);
            assert_eq!(cfg.cycles, vec![1], "prime {e}");
            assert_eq!(cfg.z, vec![e]);
            assert!(cfg.balanced);
            // target >= |W| keeps the fully serial z = 1 view
            let cfg = balanced_for_edges(&[e], e);
            assert_eq!(cfg.cycles, vec![e]);
            assert_eq!(cfg.z, vec![1]);
        }
    }

    #[test]
    fn balanced_for_edges_single_junction_and_unit_edges() {
        // single-junction nets (L = 1), down to the 1-edge degenerate
        let cfg = balanced_for_edges(&[1], 100);
        assert_eq!((cfg.z[0], cfg.cycles[0]), (1, 1));
        assert!(cfg.balanced);
        assert_eq!(cfg.idle_fraction(), 0.0);
        let cfg = balanced_for_edges(&[42], 1);
        assert_eq!((cfg.z[0], cfg.cycles[0]), (42, 1));
    }

    #[test]
    fn balanced_for_edges_mixed_prime_invariants() {
        // mixing primes with composites: every junction still divides
        // exactly, the junction cycle is the max, idle fraction in [0, 1)
        let edges = [17usize, 4, 97, 3510];
        let cfg = balanced_for_edges(&edges, 10);
        for ((&z, &c), &e) in cfg.z.iter().zip(&cfg.cycles).zip(&edges) {
            assert_eq!(z * c, e);
            assert!(c <= 10);
        }
        assert_eq!(cfg.junction_cycle, *cfg.cycles.iter().max().unwrap());
        assert!((0.0..1.0).contains(&cfg.idle_fraction()));
        // banked views built from the config must audit clean, z = 1
        // included
        for (&e, &z) in edges.iter().zip(&cfg.z) {
            let wc: Vec<f32> = (0..e).map(|x| x as f32 * 0.5 - 1.0).collect();
            crate::hw::banked::BankedWeights::new(e, z).audit(&wc).unwrap();
        }
    }

    #[test]
    fn bank_mapping_is_z_regular() {
        // within any aligned window of z neurons each bank appears once
        let z = 8;
        for w in 0..4 {
            let mut seen = vec![false; z];
            for n in w * z..(w + 1) * z {
                let b = bank_of(n, z);
                assert!(!seen[b], "bank {b} repeated in window {w}");
                seen[b] = true;
            }
        }
        assert_eq!(act_waves(800, 200), Ok(4));
        assert!(matches!(
            act_waves(800, 64),
            Err(ZConfigError::DepthNotIntegral { n_left: 800, z: 64, .. })
        ));
        assert!(act_waves(8, 0).is_err());
    }

    #[test]
    fn timit_junction_cycle_scaling() {
        // Sec. IV-B: TIMIT keeps z_net = (13, 13); junction cycle grows from
        // 90 cycles at rho=7.69% to 810 at rho=69.23%.
        let net = NetConfig::new(vec![39, 390, 39]);
        let lo = validate(&net, &DoutConfig(vec![30, 3]), &[13, 13]).unwrap();
        assert_eq!(lo.junction_cycle, 90);
        let hi = validate(&net, &DoutConfig(vec![270, 27]), &[13, 13]).unwrap();
        assert_eq!(hi.junction_cycle, 810);
    }
}
