//! Junction pipelining + operational parallelism (Sec. III-A, Fig. 2c).
//!
//! At junction-cycle granularity, input `n` flows through the schedule
//!   FF_i(n)  at tau = n + i                      (i = 1..L)
//!   BP_i(n)  at tau = n + 2L - i + 1             (i = 2..L; BP_1 does not
//!                                                 exist, footnote 3)
//!   UP_i(n)  at tau = n + 2L - i + 1             (i = 1..L)
//! which reproduces the Fig. 2c timeline (for L = 2: at the tau where
//! junction 1 runs FF(n+2), junction 2 runs FF(n+1), BP(n) and UP(n), and
//! junction 1 runs UP(n-1)).
//!
//! The scheduler also derives the *weight staleness* of Sec. III-D: FF_i
//! reads weights 2(L-i)+1 updates older than the ones BP_i reads for the
//! same input — which is exactly the activation queue depth of Table I.
//!
//! The same timetable carries a *context* dimension (see
//! [`crate::hw::context`]): under round-robin admission over `C` tenant
//! contexts, input `n` belongs to context `n mod C`, every context's op
//! pattern is the single-tenant schedule dilated by `C`, and the
//! staleness law specializes per context to `floor((2(L-i)+1)/C)` —
//! each tenant only counts its *own* weight updates between the FF and
//! BP reads of one input. [`Pipeline::audit_contexts`] proves both the
//! fetch discipline and that closed form against the schedule itself.

use std::collections::BTreeMap;

use crate::hw::context::ContextError;

/// One operation slot in the pipeline timetable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Op {
    /// Feedforward (eq. 2).
    Ff,
    /// Backpropagation of deltas (eq. 3).
    Bp,
    /// Weight/bias update (eq. 4).
    Up,
}

impl Op {
    /// Short display name ("FF" / "BP" / "UP").
    pub fn name(&self) -> &'static str {
        match self {
            Op::Ff => "FF",
            Op::Bp => "BP",
            Op::Up => "UP",
        }
    }
}

/// The pipeline schedule for an L-junction network.
#[derive(Clone, Debug)]
pub struct Pipeline {
    /// Number of junctions L.
    pub l: usize,
}

/// Everything scheduled in one junction cycle: (junction i in 1..=L, op,
/// input index). Negative input indices (warmup) are omitted.
pub type Slot = (usize, Op, i64);

impl Pipeline {
    /// Schedule for an `l`-junction network (`l >= 1`).
    pub fn new(l: usize) -> Self {
        assert!(l >= 1);
        Pipeline { l }
    }

    /// tau at which FF_i(n) runs.
    pub fn ff_time(&self, i: usize, n: i64) -> i64 {
        n + i as i64
    }

    /// tau at which BP_i(n) runs (i >= 2).
    pub fn bp_time(&self, i: usize, n: i64) -> i64 {
        n + 2 * self.l as i64 - i as i64 + 1
    }

    /// tau at which UP_i(n) runs.
    pub fn up_time(&self, i: usize, n: i64) -> i64 {
        n + 2 * self.l as i64 - i as i64 + 1
    }

    /// All operations scheduled in junction cycle `tau` for inputs >= 0.
    pub fn slots_at(&self, tau: i64) -> Vec<Slot> {
        let mut out = Vec::new();
        for i in 1..=self.l {
            let n_ff = tau - i as i64;
            if n_ff >= 0 {
                out.push((i, Op::Ff, n_ff));
            }
            let n_bpup = tau - (2 * self.l as i64 - i as i64 + 1);
            if n_bpup >= 0 {
                if i >= 2 {
                    out.push((i, Op::Bp, n_bpup));
                }
                out.push((i, Op::Up, n_bpup));
            }
        }
        out
    }

    /// Steady-state operations per junction cycle: 3L - 1 (no BP_1) once
    /// the pipe is full; the combined speedup over one-op-at-a-time
    /// processing is ~3L (Sec. III-A).
    pub fn steady_state_ops(&self) -> usize {
        3 * self.l - 1
    }

    /// FF latency of one input in junction cycles (input to logits).
    pub fn ff_latency(&self) -> usize {
        self.l
    }

    /// Full train latency: UP_1(n) is the last op of input n.
    pub fn train_latency(&self) -> usize {
        2 * self.l
    }

    /// Weight-version staleness at junction i: number of UP_i steps between
    /// the weights FF_i(n) reads and the ones BP_i(n) reads (Sec. III-D).
    pub fn staleness(&self, i: usize) -> usize {
        2 * (self.l - i) + 1
    }

    /// Left-activation queue depth at junction i: a_{i-1}(m) is written at
    /// tau = m+i-1 (layer i-1's FF, or the input load for i=1) and last
    /// read by UP_i(m) at tau = m+2L-i+1, so 2(L-i)+3 banks are live —
    /// the paper's layer-indexed 2(L-j)+1 with j = i-1 (Table I: 5 banks
    /// for a_0 and 3 for a_1 when L = 2).
    pub fn queue_banks(&self, i: usize) -> usize {
        (self.up_time(i, 0) - (self.ff_time(i, 0) - 1)) as usize + 1
    }

    /// Simulate `taus` junction cycles, tracking per-junction weight
    /// versions, and *measure* the staleness to validate the closed form.
    pub fn measured_staleness(&self, i: usize, taus: i64) -> Option<usize> {
        // weight version at junction i just before tau = number of UP_i
        // with up_time < tau, i.e. #[n >= 0 : n + 2L - i + 1 < tau]
        let version_before = |tau: i64| -> i64 {
            let bound = tau - (2 * self.l as i64 - i as i64 + 1);
            bound.max(0)
        };
        let mut result = None;
        let warmup = (2 * (self.l - i) + 1) as i64; // clamp-free region
        for n in warmup..taus {
            if self.bp_time(i, n) >= taus {
                break;
            }
            let ff_v = version_before(self.ff_time(i, n));
            let bp_v = version_before(self.bp_time(i, n));
            let s = (bp_v - ff_v) as usize;
            if let Some(prev) = result {
                assert_eq!(prev, s, "staleness not constant in steady state");
            }
            result = Some(s);
        }
        result
    }

    /// The tenant context that owns input `n` under round-robin
    /// admission over `contexts` tenants (negative `n` wraps, matching
    /// the warmup convention of [`Pipeline::slots_at`]).
    pub fn context_of(&self, n: i64, contexts: usize) -> usize {
        assert!(contexts >= 1, "need at least one context");
        n.rem_euclid(contexts as i64) as usize
    }

    /// Per-context weight staleness at junction `i` under round-robin
    /// admission over `contexts` tenants: of the `2(L-i)+1` global
    /// updates between FF_i(n) and BP_i(n), only every `contexts`-th
    /// belongs to `n`'s own tenant, so each tenant observes
    /// `floor((2(L-i)+1)/C)` of *its* updates (the Sec. III-D closed
    /// form, `C = 1`).
    pub fn context_staleness(&self, i: usize, contexts: usize) -> usize {
        assert!(contexts >= 1, "need at least one context");
        self.staleness(i) / contexts
    }

    /// Simulate `taus` junction cycles tracking *per-context* weight
    /// versions and measure the per-context staleness, validating the
    /// [`Pipeline::context_staleness`] closed form (`None` if the
    /// window never reaches steady state).
    pub fn measured_context_staleness(
        &self,
        i: usize,
        taus: i64,
        contexts: usize,
    ) -> Option<usize> {
        assert!(contexts >= 1, "need at least one context");
        let c64 = contexts as i64;
        // context-c weight version at junction i just before tau:
        // #[m >= 0, m ≡ c (mod C) : m + 2L - i + 1 < tau]
        let version_before = |tau: i64, c: i64| -> i64 {
            let bound = tau - (2 * self.l as i64 - i as i64 + 1);
            if bound <= c {
                0
            } else {
                (bound - 1 - c) / c64 + 1
            }
        };
        let mut result = None;
        // clamp-free region: past every context's warmup
        let warmup = (self.staleness(i) + 1) as i64 * c64;
        for n in warmup..taus {
            if self.bp_time(i, n) >= taus {
                break;
            }
            let c = n % c64;
            let ff_v = version_before(self.ff_time(i, n), c);
            let bp_v = version_before(self.bp_time(i, n), c);
            let s = (bp_v - ff_v) as usize;
            if let Some(prev) = result {
                assert_eq!(prev, s, "per-context staleness not constant in steady state");
            }
            result = Some(s);
        }
        result
    }

    /// Prove the multi-tenant fetch discipline and the per-context
    /// staleness law over `taus` cycles with the correct round-robin
    /// context fetch (input `n` fetches bank `n mod contexts`). See
    /// [`Pipeline::audit_contexts_with`] for the general form the
    /// mutation tests drive with faulted fetches.
    pub fn audit_contexts(&self, taus: i64, contexts: usize) -> Result<(), ContextError> {
        self.audit_contexts_with(taus, contexts, |n| Some(self.context_of(n, contexts)))
    }

    /// Replay `taus` cycles of the timetable against an explicit context
    /// fetch function (`fetch(n)` = the bank cycle ops for input `n`
    /// actually read, `None` = fetch dropped) and prove, per context:
    /// - every fetch lands on the owning tenant's bank (no aliasing),
    /// - no tenant's fetch is dropped and every tenant is served at
    ///   least once in the window (no skipped context),
    /// - the measured per-context staleness matches the
    ///   [`Pipeline::context_staleness`] closed form.
    ///
    /// The error names the offending context ([`ContextError`]), which
    /// `analysis::clash` surfaces as a typed finding coordinate.
    pub fn audit_contexts_with<F>(
        &self,
        taus: i64,
        contexts: usize,
        fetch: F,
    ) -> Result<(), ContextError>
    where
        F: Fn(i64) -> Option<usize>,
    {
        assert!(contexts >= 1, "need at least one context");
        let mut served = vec![false; contexts];
        for tau in 0..taus {
            for (_i, _op, n) in self.slots_at(tau) {
                let requested = self.context_of(n, contexts);
                let effective = match fetch(n) {
                    Some(e) => e,
                    None => return Err(ContextError::Skipped { context: requested }),
                };
                if effective >= contexts {
                    return Err(ContextError::OutOfRange {
                        context: effective,
                        contexts,
                    });
                }
                if effective != requested {
                    return Err(ContextError::Aliased {
                        requested,
                        effective,
                    });
                }
                served[requested] = true;
            }
        }
        if taus >= (2 * self.l + contexts) as i64 {
            // window long enough that every tenant must have been served
            for (context, hit) in served.iter().enumerate() {
                if !hit {
                    return Err(ContextError::Skipped { context });
                }
            }
            // the per-context staleness closed form must hold wherever
            // the window reaches steady state
            for i in 1..=self.l {
                if let Some(measured) = self.measured_context_staleness(i, taus, contexts) {
                    let expected = self.context_staleness(i, contexts);
                    if measured != expected {
                        return Err(ContextError::StalenessLaw {
                            junction: i,
                            measured,
                            expected,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Validate the structural resource claims of Sec. III-A against the
    /// schedule itself (used by property tests):
    /// - every junction runs at most one FF, one BP and one UP per tau,
    /// - FF and UP of a junction never process the same input at one tau,
    /// - the a-queue depth needed at junction i (distance between FF_i(n)
    ///   reading a_{i-1}(n) and UP_i(n) re-reading it) is 2(L-i)+1.
    pub fn audit(&self, taus: i64) -> Result<(), String> {
        for tau in 0..taus {
            let slots = self.slots_at(tau);
            let mut per_junction: BTreeMap<(usize, Op), i64> = BTreeMap::new();
            for (i, op, n) in &slots {
                if per_junction.insert((*i, *op), *n).is_some() {
                    return Err(format!("junction {i} runs two {op:?} at tau {tau}"));
                }
            }
            for i in 1..=self.l {
                if let (Some(ff), Some(up)) =
                    (per_junction.get(&(i, Op::Ff)), per_junction.get(&(i, Op::Up)))
                {
                    if ff == up {
                        return Err(format!("junction {i} FF and UP same input at tau {tau}"));
                    }
                }
            }
        }
        for i in 1..=self.l {
            // Table I consistency: queue depth = 2(L-(i-1))+1
            if self.queue_banks(i) != 2 * (self.l - (i - 1)) + 1 {
                return Err(format!("queue depth mismatch at junction {i}"));
            }
        }
        Ok(())
    }
}

/// Throughput model: inputs/second for a clock frequency and junction
/// cycle (plus per-junction pipeline flush overhead c, footnote 2).
pub fn throughput_inputs_per_sec(clock_hz: f64, junction_cycle: usize, flush: usize) -> f64 {
    clock_hz / (junction_cycle + flush) as f64
}

/// Cycle count for processing `n_inputs` through training: pipeline depth
/// 2L junction cycles of latency plus one junction cycle per input.
pub fn training_cycles(l: usize, junction_cycle: usize, flush: usize, n_inputs: usize) -> usize {
    (2 * l + n_inputs) * (junction_cycle + flush)
}

/// Speedup of the pipelined/parallel schedule over sequential processing
/// (one op, one junction, one input at a time): asymptotically 3L - 1/…
/// ~= 3L (Sec. III-A).
pub fn speedup(l: usize, n_inputs: usize) -> f64 {
    // sequential: every input does L FF + (L-1) BP + L UP junction cycles
    let seq = n_inputs * (3 * l - 1);
    let pipe = 2 * l + n_inputs;
    seq as f64 / pipe as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2c_timeline_for_l2() {
        // Paper's worked example (Sec. III-A): while input n+3 loads,
        // junction 1: FF(n+2), junction 2: FF(n+1), BP(n), UP(n),
        // junction 1: UP(n-1).
        let p = Pipeline::new(2);
        let tau = p.ff_time(1, 3); // junction 1 processing FF for input 3 = n+2 with n=1
        let slots = p.slots_at(tau);
        let n = 1i64; // so n+2 = 3
        assert!(slots.contains(&(1, Op::Ff, n + 2)));
        assert!(slots.contains(&(2, Op::Ff, n + 1)));
        assert!(slots.contains(&(2, Op::Bp, n)));
        assert!(slots.contains(&(2, Op::Up, n)));
        assert!(slots.contains(&(1, Op::Up, n - 1)));
        assert_eq!(slots.len(), 5); // = 3L - 1
    }

    #[test]
    fn steady_state_op_count() {
        for l in 1..6 {
            let p = Pipeline::new(l);
            let tau = (3 * l + 5) as i64;
            assert_eq!(p.slots_at(tau).len(), p.steady_state_ops());
        }
    }

    #[test]
    fn staleness_matches_closed_form_and_queue_depths() {
        for l in 1..6 {
            let p = Pipeline::new(l);
            for i in 1..=l {
                assert_eq!(p.measured_staleness(i, 200), Some(p.staleness(i)));
            }
            p.audit(100).unwrap();
        }
    }

    #[test]
    fn l2_queue_depth_matches_paper() {
        // Sec. III-A: 2L+1 = 5 banks for a_0, 2(L-1)+1 = 3 for a_1
        let p = Pipeline::new(2);
        assert_eq!(p.queue_banks(1), 5);
        assert_eq!(p.queue_banks(2), 3);
        assert_eq!(p.staleness(1), 3);
        assert_eq!(p.staleness(2), 1);
        // L=4 (Table I second config): a_0 needs 2L+1 = 9 banks
        assert_eq!(Pipeline::new(4).queue_banks(1), 9);
    }

    #[test]
    fn per_context_staleness_matches_closed_form() {
        for l in 1..5 {
            let p = Pipeline::new(l);
            for contexts in 1..=4 {
                for i in 1..=l {
                    assert_eq!(
                        p.measured_context_staleness(i, 400, contexts),
                        Some(p.context_staleness(i, contexts)),
                        "l={l} i={i} contexts={contexts}"
                    );
                }
                p.audit_contexts(200, contexts).unwrap();
            }
            // one context is exactly the single-tenant law
            for i in 1..=l {
                assert_eq!(p.context_staleness(i, 1), p.staleness(i));
            }
        }
    }

    #[test]
    fn context_round_robin_ownership() {
        let p = Pipeline::new(2);
        assert_eq!(p.context_of(0, 3), 0);
        assert_eq!(p.context_of(5, 3), 2);
        // warmup inputs wrap instead of going negative
        assert_eq!(p.context_of(-1, 3), 2);
    }

    #[test]
    fn faulted_context_fetches_fail_the_audit() {
        use crate::hw::context::ContextError;
        let p = Pipeline::new(3);
        // aliasing context 1 onto bank 0 names context 1
        let err = p
            .audit_contexts_with(60, 4, |n| {
                let c = p.context_of(n, 4);
                Some(if c == 1 { 0 } else { c })
            })
            .unwrap_err();
        assert_eq!(
            err,
            ContextError::Aliased {
                requested: 1,
                effective: 0
            }
        );
        // dropping context 2's fetches names context 2
        let err = p
            .audit_contexts_with(60, 4, |n| {
                let c = p.context_of(n, 4);
                if c == 2 {
                    None
                } else {
                    Some(c)
                }
            })
            .unwrap_err();
        assert_eq!(err, ContextError::Skipped { context: 2 });
        // fetching a bank beyond the configured count is out of range
        let err = p.audit_contexts_with(60, 2, |_| Some(7)).unwrap_err();
        assert_eq!(
            err,
            ContextError::OutOfRange {
                context: 7,
                contexts: 2
            }
        );
    }

    #[test]
    fn speedup_approaches_3l() {
        for l in [1usize, 2, 4] {
            let s = speedup(l, 100_000);
            assert!((s - (3 * l - 1) as f64).abs() < 0.1, "l={l}: {s}");
        }
    }

    #[test]
    fn latency_and_throughput() {
        let p = Pipeline::new(4);
        assert_eq!(p.ff_latency(), 4);
        assert_eq!(p.train_latency(), 8);
        // initial FPGA implementation [40]: C = 32+2 flush; at 100 MHz
        let tput = throughput_inputs_per_sec(100e6, 32, 2);
        assert!((tput - 100e6 / 34.0).abs() < 1.0);
        assert_eq!(training_cycles(2, 32, 2, 10), (4 + 10) * 34);
    }
}
