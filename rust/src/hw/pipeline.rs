//! Junction pipelining + operational parallelism (Sec. III-A, Fig. 2c).
//!
//! At junction-cycle granularity, input `n` flows through the schedule
//!   FF_i(n)  at tau = n + i                      (i = 1..L)
//!   BP_i(n)  at tau = n + 2L - i + 1             (i = 2..L; BP_1 does not
//!                                                 exist, footnote 3)
//!   UP_i(n)  at tau = n + 2L - i + 1             (i = 1..L)
//! which reproduces the Fig. 2c timeline (for L = 2: at the tau where
//! junction 1 runs FF(n+2), junction 2 runs FF(n+1), BP(n) and UP(n), and
//! junction 1 runs UP(n-1)).
//!
//! The scheduler also derives the *weight staleness* of Sec. III-D: FF_i
//! reads weights 2(L-i)+1 updates older than the ones BP_i reads for the
//! same input — which is exactly the activation queue depth of Table I.

use std::collections::BTreeMap;

/// One operation slot in the pipeline timetable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Op {
    /// Feedforward (eq. 2).
    Ff,
    /// Backpropagation of deltas (eq. 3).
    Bp,
    /// Weight/bias update (eq. 4).
    Up,
}

impl Op {
    /// Short display name ("FF" / "BP" / "UP").
    pub fn name(&self) -> &'static str {
        match self {
            Op::Ff => "FF",
            Op::Bp => "BP",
            Op::Up => "UP",
        }
    }
}

/// The pipeline schedule for an L-junction network.
#[derive(Clone, Debug)]
pub struct Pipeline {
    /// Number of junctions L.
    pub l: usize,
}

/// Everything scheduled in one junction cycle: (junction i in 1..=L, op,
/// input index). Negative input indices (warmup) are omitted.
pub type Slot = (usize, Op, i64);

impl Pipeline {
    /// Schedule for an `l`-junction network (`l >= 1`).
    pub fn new(l: usize) -> Self {
        assert!(l >= 1);
        Pipeline { l }
    }

    /// tau at which FF_i(n) runs.
    pub fn ff_time(&self, i: usize, n: i64) -> i64 {
        n + i as i64
    }

    /// tau at which BP_i(n) runs (i >= 2).
    pub fn bp_time(&self, i: usize, n: i64) -> i64 {
        n + 2 * self.l as i64 - i as i64 + 1
    }

    /// tau at which UP_i(n) runs.
    pub fn up_time(&self, i: usize, n: i64) -> i64 {
        n + 2 * self.l as i64 - i as i64 + 1
    }

    /// All operations scheduled in junction cycle `tau` for inputs >= 0.
    pub fn slots_at(&self, tau: i64) -> Vec<Slot> {
        let mut out = Vec::new();
        for i in 1..=self.l {
            let n_ff = tau - i as i64;
            if n_ff >= 0 {
                out.push((i, Op::Ff, n_ff));
            }
            let n_bpup = tau - (2 * self.l as i64 - i as i64 + 1);
            if n_bpup >= 0 {
                if i >= 2 {
                    out.push((i, Op::Bp, n_bpup));
                }
                out.push((i, Op::Up, n_bpup));
            }
        }
        out
    }

    /// Steady-state operations per junction cycle: 3L - 1 (no BP_1) once
    /// the pipe is full; the combined speedup over one-op-at-a-time
    /// processing is ~3L (Sec. III-A).
    pub fn steady_state_ops(&self) -> usize {
        3 * self.l - 1
    }

    /// FF latency of one input in junction cycles (input to logits).
    pub fn ff_latency(&self) -> usize {
        self.l
    }

    /// Full train latency: UP_1(n) is the last op of input n.
    pub fn train_latency(&self) -> usize {
        2 * self.l
    }

    /// Weight-version staleness at junction i: number of UP_i steps between
    /// the weights FF_i(n) reads and the ones BP_i(n) reads (Sec. III-D).
    pub fn staleness(&self, i: usize) -> usize {
        2 * (self.l - i) + 1
    }

    /// Left-activation queue depth at junction i: a_{i-1}(m) is written at
    /// tau = m+i-1 (layer i-1's FF, or the input load for i=1) and last
    /// read by UP_i(m) at tau = m+2L-i+1, so 2(L-i)+3 banks are live —
    /// the paper's layer-indexed 2(L-j)+1 with j = i-1 (Table I: 5 banks
    /// for a_0 and 3 for a_1 when L = 2).
    pub fn queue_banks(&self, i: usize) -> usize {
        (self.up_time(i, 0) - (self.ff_time(i, 0) - 1)) as usize + 1
    }

    /// Simulate `taus` junction cycles, tracking per-junction weight
    /// versions, and *measure* the staleness to validate the closed form.
    pub fn measured_staleness(&self, i: usize, taus: i64) -> Option<usize> {
        // weight version at junction i just before tau = number of UP_i
        // with up_time < tau, i.e. #[n >= 0 : n + 2L - i + 1 < tau]
        let version_before = |tau: i64| -> i64 {
            let bound = tau - (2 * self.l as i64 - i as i64 + 1);
            bound.max(0)
        };
        let mut result = None;
        let warmup = (2 * (self.l - i) + 1) as i64; // clamp-free region
        for n in warmup..taus {
            if self.bp_time(i, n) >= taus {
                break;
            }
            let ff_v = version_before(self.ff_time(i, n));
            let bp_v = version_before(self.bp_time(i, n));
            let s = (bp_v - ff_v) as usize;
            if let Some(prev) = result {
                assert_eq!(prev, s, "staleness not constant in steady state");
            }
            result = Some(s);
        }
        result
    }

    /// Validate the structural resource claims of Sec. III-A against the
    /// schedule itself (used by property tests):
    /// - every junction runs at most one FF, one BP and one UP per tau,
    /// - FF and UP of a junction never process the same input at one tau,
    /// - the a-queue depth needed at junction i (distance between FF_i(n)
    ///   reading a_{i-1}(n) and UP_i(n) re-reading it) is 2(L-i)+1.
    pub fn audit(&self, taus: i64) -> Result<(), String> {
        for tau in 0..taus {
            let slots = self.slots_at(tau);
            let mut per_junction: BTreeMap<(usize, Op), i64> = BTreeMap::new();
            for (i, op, n) in &slots {
                if per_junction.insert((*i, *op), *n).is_some() {
                    return Err(format!("junction {i} runs two {op:?} at tau {tau}"));
                }
            }
            for i in 1..=self.l {
                if let (Some(ff), Some(up)) =
                    (per_junction.get(&(i, Op::Ff)), per_junction.get(&(i, Op::Up)))
                {
                    if ff == up {
                        return Err(format!("junction {i} FF and UP same input at tau {tau}"));
                    }
                }
            }
        }
        for i in 1..=self.l {
            // Table I consistency: queue depth = 2(L-(i-1))+1
            if self.queue_banks(i) != 2 * (self.l - (i - 1)) + 1 {
                return Err(format!("queue depth mismatch at junction {i}"));
            }
        }
        Ok(())
    }
}

/// Throughput model: inputs/second for a clock frequency and junction
/// cycle (plus per-junction pipeline flush overhead c, footnote 2).
pub fn throughput_inputs_per_sec(clock_hz: f64, junction_cycle: usize, flush: usize) -> f64 {
    clock_hz / (junction_cycle + flush) as f64
}

/// Cycle count for processing `n_inputs` through training: pipeline depth
/// 2L junction cycles of latency plus one junction cycle per input.
pub fn training_cycles(l: usize, junction_cycle: usize, flush: usize, n_inputs: usize) -> usize {
    (2 * l + n_inputs) * (junction_cycle + flush)
}

/// Speedup of the pipelined/parallel schedule over sequential processing
/// (one op, one junction, one input at a time): asymptotically 3L - 1/…
/// ~= 3L (Sec. III-A).
pub fn speedup(l: usize, n_inputs: usize) -> f64 {
    // sequential: every input does L FF + (L-1) BP + L UP junction cycles
    let seq = n_inputs * (3 * l - 1);
    let pipe = 2 * l + n_inputs;
    seq as f64 / pipe as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2c_timeline_for_l2() {
        // Paper's worked example (Sec. III-A): while input n+3 loads,
        // junction 1: FF(n+2), junction 2: FF(n+1), BP(n), UP(n),
        // junction 1: UP(n-1).
        let p = Pipeline::new(2);
        let tau = p.ff_time(1, 3); // junction 1 processing FF for input 3 = n+2 with n=1
        let slots = p.slots_at(tau);
        let n = 1i64; // so n+2 = 3
        assert!(slots.contains(&(1, Op::Ff, n + 2)));
        assert!(slots.contains(&(2, Op::Ff, n + 1)));
        assert!(slots.contains(&(2, Op::Bp, n)));
        assert!(slots.contains(&(2, Op::Up, n)));
        assert!(slots.contains(&(1, Op::Up, n - 1)));
        assert_eq!(slots.len(), 5); // = 3L - 1
    }

    #[test]
    fn steady_state_op_count() {
        for l in 1..6 {
            let p = Pipeline::new(l);
            let tau = (3 * l + 5) as i64;
            assert_eq!(p.slots_at(tau).len(), p.steady_state_ops());
        }
    }

    #[test]
    fn staleness_matches_closed_form_and_queue_depths() {
        for l in 1..6 {
            let p = Pipeline::new(l);
            for i in 1..=l {
                assert_eq!(p.measured_staleness(i, 200), Some(p.staleness(i)));
            }
            p.audit(100).unwrap();
        }
    }

    #[test]
    fn l2_queue_depth_matches_paper() {
        // Sec. III-A: 2L+1 = 5 banks for a_0, 2(L-1)+1 = 3 for a_1
        let p = Pipeline::new(2);
        assert_eq!(p.queue_banks(1), 5);
        assert_eq!(p.queue_banks(2), 3);
        assert_eq!(p.staleness(1), 3);
        assert_eq!(p.staleness(2), 1);
        // L=4 (Table I second config): a_0 needs 2L+1 = 9 banks
        assert_eq!(Pipeline::new(4).queue_banks(1), 9);
    }

    #[test]
    fn speedup_approaches_3l() {
        for l in [1usize, 2, 4] {
            let s = speedup(l, 100_000);
            assert!((s - (3 * l - 1) as f64).abs() < 0.1, "l={l}: {s}");
        }
    }

    #[test]
    fn latency_and_throughput() {
        let p = Pipeline::new(4);
        assert_eq!(p.ff_latency(), 4);
        assert_eq!(p.train_latency(), 8);
        // initial FPGA implementation [40]: C = 32+2 flush; at 100 MHz
        let tput = throughput_inputs_per_sec(100e6, 32, 2);
        assert!((tput - 100e6 / 34.0).abs() < 1.0);
        assert_eq!(training_cycles(2, 32, 2, 10), (4 + 10) * 34);
    }
}
