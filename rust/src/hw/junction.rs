//! Numeric, cycle-accurate execution of one junction's FF / BP / UP
//! (Sec. III-B, Fig. 3/4) against banked memories.
//!
//! This simulator is deliberately serial: it models *one* junction unit
//! clocking `z` edge processors per cycle, so cycle counts and clash
//! checks stay exact. Throughput in software comes from the batched
//! [`crate::nn`] kernels (parallel over [`crate::util::parallel`]) and
//! from the multi-worker inference service in [`crate::coordinator`];
//! here, concurrency is *modeled* (pipelining across junction units
//! lives in [`crate::hw::pipeline`]), not executed.
//!
//! Layout contract (Fig. 4):
//! - weights: edge `e` (numbered sequentially by right neuron) lives in
//!   weight memory `e % z` at address `e / z`; read in natural order, one
//!   row (z edges) per cycle; the bank is simple dual-ported so UP can
//!   write back while the shared read feeds all three operations,
//! - left activations / a-dot / left deltas: neuron `n` at memory `n % z`
//!   address `n / z`, accessed in *interleaved* order via the clash-free
//!   [`AccessSchedule`],
//! - right-side parameters: neuron `j` at memory `j % z_next`; at most
//!   `ceil(z / d_in)` right neurons are touched per cycle (Sec. III-B),
//!   which `z_next` must cover (eq. 9).

use crate::hw::memory::{Bank, Clash, Port};
use crate::nn::fixed::QFormat;
use crate::sparsity::clash_free::AccessSchedule;
use crate::sparsity::config::JunctionShape;
use crate::sparsity::pattern::Pattern;
use crate::util::ceil_div;

/// Activation applied by the FF logic as right neurons complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    /// max(0, h) — hidden junctions.
    Relu,
    /// Identity — the output junction (softmax lives host-side).
    Linear,
}

impl Act {
    /// The activation value a(h).
    pub fn apply(&self, h: f32) -> f32 {
        match self {
            Act::Relu => h.max(0.0),
            Act::Linear => h,
        }
    }

    /// The derivative a'(h) stored in the a-dot memories.
    pub fn derivative(&self, h: f32) -> f32 {
        match self {
            Act::Relu => {
                if h > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Linear => 1.0,
        }
    }
}

/// Cycle/access statistics for one operation pass.
#[derive(Clone, Debug, Default)]
pub struct OpStats {
    /// Clock cycles the pass took.
    pub cycles: usize,
    /// Weight-memory reads issued.
    pub weight_reads: usize,
    /// Weight-memory writes issued (UP only).
    pub weight_writes: usize,
    /// Left-bank (activation / a-dot / delta) reads issued.
    pub left_reads: usize,
    /// Right-bank accesses issued.
    pub right_accesses: usize,
    /// Most distinct right neurons touched in any one cycle (bounded by
    /// eq. 9's `z_next`).
    pub max_rights_per_cycle: usize,
}

/// FF outputs: pre-activations, activations and their derivatives
/// (eq. 2a-2c), plus the pass statistics.
#[derive(Clone, Debug)]
pub struct FfOut {
    /// Pre-activations h (eq. 2a).
    pub h: Vec<f32>,
    /// Activations a(h) (eq. 2b).
    pub a: Vec<f32>,
    /// Activation derivatives a'(h) (eq. 2c).
    pub adot: Vec<f32>,
    /// Cycle/access statistics of the pass.
    pub stats: OpStats,
}

/// FF outputs of the fixed-point pass: raw Qm.n words plus the pass
/// statistics (the quantized twin of [`FfOut`]).
#[derive(Clone, Debug)]
pub struct QFfOut {
    /// Raw pre-activations h (eq. 2a) in Qm.n words.
    pub h_raw: Vec<i32>,
    /// Raw activations a(h).
    pub a_raw: Vec<i32>,
    /// ReLU derivative bits (0/1 per right neuron — the single bit the
    /// hardware's a-dot memories store per word for ReLU; all 1 for the
    /// linear output junction).
    pub adot_bits: Vec<i32>,
    /// Outputs that saturated at the Qm.n range.
    pub saturations: usize,
    /// Weights / biases / input activations that clipped at the Qm.n
    /// range while being quantized into the banks (a clipped word voids
    /// the forward error bound just like a saturated MAC).
    pub clipped_words: usize,
    /// Cycle/access statistics of the pass.
    pub stats: OpStats,
}

/// One junction's processing unit: `z` edge processors, the weight bank,
/// and the clash-free left access schedule.
pub struct JunctionUnit {
    /// Left/right layer widths.
    pub shape: JunctionShape,
    /// In-degree per right neuron.
    pub d_in: usize,
    /// Out-degree per left neuron.
    pub d_out: usize,
    /// Edge processors clocked per cycle.
    pub z: usize,
    /// Right-bank parallelism (eq. 9).
    pub z_next: usize,
    /// Cycles per operation pass: `|W| / z`.
    pub junction_cycle: usize,
    sched: AccessSchedule,
    weights: Bank,
}

impl JunctionUnit {
    /// Exact number of distinct right neurons any single cycle touches:
    /// `ceil(z/d_in)` when the d_in-edge groups align with cycle
    /// boundaries (z | d_in or d_in | z), one more when a group straddles
    /// a boundary (footnote 5 / Appendix B: practical designs pick
    /// integral ratios precisely to avoid this extra port).
    pub fn required_z_next(n_edges: usize, z: usize, d_in: usize) -> usize {
        let mut max_rights = 1;
        for t in 0..n_edges / z {
            let first = (t * z) / d_in;
            let last = ((t + 1) * z - 1) / d_in;
            max_rights = max_rights.max(last - first + 1);
        }
        max_rights
    }

    /// Build from a clash-free access schedule. `z_next` is the right
    /// bank's parallelism (z of the next junction, or any value >=
    /// [`Self::required_z_next`] for the output layer).
    pub fn new(shape: JunctionShape, d_in: usize, sched: AccessSchedule, z_next: usize) -> Self {
        let n_edges = shape.n_right * d_in;
        let z = sched.z;
        assert_eq!(n_edges % z, 0, "z must divide |W|");
        let junction_cycle = n_edges / z;
        assert_eq!(sched.cycles.len(), junction_cycle, "schedule covers one junction cycle");
        let d_out = n_edges / shape.n_left;
        let need = Self::required_z_next(n_edges, z, d_in);
        assert!(
            z_next >= need,
            "z_next {z_next} violates the right-bank bound {need} (eq. 9)"
        );
        let weights = Bank::new("W", z, junction_cycle, Port::SimpleDual);
        Self {
            shape,
            d_in,
            d_out,
            z,
            z_next,
            junction_cycle,
            sched,
            weights,
        }
    }

    /// The connection pattern this unit implements.
    pub fn pattern(&self) -> Pattern {
        let mut in_edges: Vec<Vec<u32>> = vec![Vec::with_capacity(self.d_in); self.shape.n_right];
        for t in 0..self.junction_cycle {
            for m in 0..self.z {
                let e = t * self.z + m;
                in_edges[e / self.d_in].push(self.sched.neuron(t, m) as u32);
            }
        }
        Pattern { shape: self.shape, in_edges }
    }

    /// Load weights from a dense row-major `[n_right, n_left]` matrix
    /// (host DMA; untimed).
    pub fn load_weights_dense(&mut self, dense: &[f32]) {
        assert_eq!(dense.len(), self.shape.n_right * self.shape.n_left);
        let mut flat = vec![0f32; self.shape.n_right * self.d_in];
        for t in 0..self.junction_cycle {
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let k = self.sched.neuron(t, m);
                flat[e] = dense[j * self.shape.n_left + k];
            }
        }
        self.load_weights_edge_order(&flat);
    }

    /// Load weights already in edge order (the compacted Fig. 4 layout).
    pub fn load_weights_edge_order(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.shape.n_right * self.d_in);
        self.weights.load(flat);
    }

    /// Dump weights to dense row-major `[n_right, n_left]` (untimed).
    pub fn dump_weights_dense(&self) -> Vec<f32> {
        let flat = self.weights.dump(self.shape.n_right * self.d_in);
        let mut dense = vec![0f32; self.shape.n_right * self.shape.n_left];
        for t in 0..self.junction_cycle {
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let k = self.sched.neuron(t, m);
                dense[j * self.shape.n_left + k] = flat[e];
            }
        }
        dense
    }

    /// Feedforward (eq. 2): one junction cycle, `z` edges per clock.
    pub fn feedforward(&mut self, a_prev: &[f32], bias: &[f32], act: Act) -> Result<FfOut, Clash> {
        assert_eq!(a_prev.len(), self.shape.n_left);
        assert_eq!(bias.len(), self.shape.n_right);
        let mut left = Bank::new("a", self.z, self.sched.depth, Port::Single);
        left.load(a_prev);
        let mut right = Bank::new("a'", self.z_next, ceil_div(self.shape.n_right, self.z_next), Port::Single);

        let mut acc = vec![0f32; self.shape.n_right];
        let mut cnt = vec![0usize; self.shape.n_right];
        let mut h = vec![0f32; self.shape.n_right];
        let mut adot = vec![0f32; self.shape.n_right];
        let mut stats = OpStats::default();

        for t in 0..self.junction_cycle {
            let mut completed: Vec<usize> = Vec::new();
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let (wm, wa) = (e % self.z, e / self.z);
                let w = self.weights.read(wm, wa)?;
                let (lm, la) = self.sched.cycles[t][m];
                let a = left.read(lm, la)?;
                acc[j] += w * a;
                cnt[j] += 1;
                if cnt[j] == self.d_in {
                    completed.push(j);
                }
            }
            // completed right neurons: apply bias + activation, write bank
            for &j in &completed {
                let hv = acc[j] + bias[j];
                h[j] = hv;
                adot[j] = act.derivative(hv);
                right.write_entity(j, act.apply(hv))?;
            }
            stats.max_rights_per_cycle = stats.max_rights_per_cycle.max(completed.len());
            self.weights.tick();
            left.tick();
            right.tick();
            stats.cycles += 1;
        }
        debug_assert!(cnt.iter().all(|&c| c == self.d_in));
        stats.weight_reads = self.junction_cycle * self.z;
        stats.left_reads = self.junction_cycle * self.z;
        stats.right_accesses = self.shape.n_right;
        let a_out = right.dump(self.shape.n_right);
        Ok(FfOut { h, a: a_out, adot, stats })
    }

    /// Fixed-point feedforward: the same one-junction-cycle schedule as
    /// [`JunctionUnit::feedforward`], executed in Qm.n arithmetic against
    /// `i32`-word banks — the arithmetic the paper's FPGA companion
    /// (arXiv:1806.01087) actually computes in. The current f32 weight
    /// bank contents are quantized into a fixed-point weight bank (the
    /// DMA step that loads integer words into the BRAMs), activations
    /// stream through quantized left banks under the identical clash-free
    /// access schedule and port discipline, and each right neuron folds
    /// its wide MAC accumulator once via [`QFormat::fold_mac`] on
    /// completion.
    ///
    /// This makes `hw` the executable source of truth for the
    /// *arithmetic*, not just the scheduling: the batch kernel
    /// [`crate::nn::fixed::FixedSparseLayer::forward`] must produce
    /// bit-identical raw words (`i64` accumulation is exact, so the edge
    /// order cannot change the sum) — `tests/prop_fixed.rs` pins that.
    pub fn feedforward_quantized(
        &mut self,
        a_prev: &[f32],
        bias: &[f32],
        act: Act,
        fmt: QFormat,
    ) -> Result<QFfOut, Clash> {
        assert_eq!(a_prev.len(), self.shape.n_left);
        assert_eq!(bias.len(), self.shape.n_right);
        let n_edges = self.shape.n_right * self.d_in;
        // quantize the weight bank contents into the fixed-point bank
        // (untimed host DMA, like load_weights_*), counting range clips
        let mut clipped_words = 0usize;
        let wq = fmt.quantize_slice_counted(&self.weights.dump(n_edges), &mut clipped_words);
        let mut wbank: Bank<i32> = Bank::new("Wq", self.z, self.junction_cycle, Port::SimpleDual);
        wbank.load(&wq);
        let mut left: Bank<i32> = Bank::new("aq", self.z, self.sched.depth, Port::Single);
        left.load(&fmt.quantize_slice_counted(a_prev, &mut clipped_words));
        let mut right: Bank<i32> = Bank::new(
            "aq'",
            self.z_next,
            ceil_div(self.shape.n_right, self.z_next),
            Port::Single,
        );
        let bq = fmt.quantize_slice_counted(bias, &mut clipped_words);

        // wide per-neuron MAC accumulators (the DSP accumulator chain)
        let mut acc = vec![0i64; self.shape.n_right];
        let mut cnt = vec![0usize; self.shape.n_right];
        let mut h_raw = vec![0i32; self.shape.n_right];
        let mut adot_bits = vec![0i32; self.shape.n_right];
        let mut saturations = 0usize;
        let mut stats = OpStats::default();

        for t in 0..self.junction_cycle {
            let mut completed: Vec<usize> = Vec::new();
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let (wm, wa) = (e % self.z, e / self.z);
                let w = wbank.read(wm, wa)?;
                let (lm, la) = self.sched.cycles[t][m];
                let a = left.read(lm, la)?;
                acc[j] += w as i64 * a as i64;
                cnt[j] += 1;
                if cnt[j] == self.d_in {
                    completed.push(j);
                }
            }
            for &j in &completed {
                let hv = fmt.fold_mac(acc[j], bq[j], &mut saturations);
                h_raw[j] = hv;
                let av = match act {
                    Act::Relu => hv.max(0),
                    Act::Linear => hv,
                };
                adot_bits[j] = match act {
                    Act::Relu => i32::from(hv > 0),
                    Act::Linear => 1,
                };
                right.write_entity(j, av)?;
            }
            stats.max_rights_per_cycle = stats.max_rights_per_cycle.max(completed.len());
            wbank.tick();
            left.tick();
            right.tick();
            stats.cycles += 1;
        }
        debug_assert!(cnt.iter().all(|&c| c == self.d_in));
        stats.weight_reads = self.junction_cycle * self.z;
        stats.left_reads = self.junction_cycle * self.z;
        stats.right_accesses = self.shape.n_right;
        let a_raw = right.dump(self.shape.n_right);
        Ok(QFfOut {
            h_raw,
            a_raw,
            adot_bits,
            saturations,
            clipped_words,
            stats,
        })
    }

    /// Backprop (eq. 3b): compute delta for the *left* layer from the right
    /// layer's delta, folding the a-dot multiply into the final sweep.
    pub fn backprop(
        &mut self,
        delta_right: &[f32],
        adot_left: &[f32],
    ) -> Result<(Vec<f32>, OpStats), Clash> {
        assert_eq!(delta_right.len(), self.shape.n_right);
        assert_eq!(adot_left.len(), self.shape.n_left);
        // left delta partials: dual-ported (footnote 4) for read-modify-write
        let mut dleft = Bank::new("d", self.z, self.sched.depth, Port::SimpleDual);
        dleft.load(&vec![0f32; self.shape.n_left]);
        let mut adot_bank = Bank::new("adot", self.z, self.sched.depth, Port::Single);
        adot_bank.load(adot_left);
        let mut dright = Bank::new("d'", self.z_next, ceil_div(self.shape.n_right, self.z_next), Port::SimpleDual);
        dright.load(delta_right);
        let mut stats = OpStats::default();

        // read-modify-write accumulators kept in registers per lane; the
        // delta bank is written once per (neuron, sweep) — model the
        // accumulate in host f32 and count one read + one write per access,
        // which is what the dual-ported delta memories provide.
        let mut partial = vec![0f32; self.shape.n_left];
        for t in 0..self.junction_cycle {
            let sweep = t / self.sched.depth;
            let last_sweep = sweep == self.d_out - 1;
            // distinct right neurons whose delta feeds this cycle (a single
            // read per memory, broadcast to the lanes that need it)
            let mut rights: Vec<usize> = (0..self.z)
                .map(|m| (t * self.z + m) / self.d_in)
                .collect();
            rights.dedup();
            stats.max_rights_per_cycle = stats.max_rights_per_cycle.max(rights.len());
            let mut dvals = std::collections::BTreeMap::new();
            for &j in &rights {
                dvals.insert(j, dright.read_entity(j)?);
                stats.right_accesses += 1;
            }
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let (wm, wa) = (e % self.z, e / self.z);
                let w = self.weights.read(wm, wa)?;
                let (lm, la) = self.sched.cycles[t][m];
                let k = la * self.z + lm;
                // dual-port RMW: one read + one write on the delta memory
                let prev = if sweep == 0 {
                    0.0
                } else {
                    let stored = dleft.read(lm, la)?;
                    debug_assert!((stored - partial[k]).abs() < 1e-6);
                    partial[k]
                };
                let mut next = prev + w * dvals[&j];
                if last_sweep {
                    // fold eq. (3b)'s a-dot product into the final write
                    let ad = adot_bank.read(lm, la)?;
                    next *= ad;
                }
                partial[k] = next;
                dleft.write(lm, la, next)?;
            }
            self.weights.tick();
            dleft.tick();
            adot_bank.tick();
            dright.tick();
            stats.cycles += 1;
        }
        stats.weight_reads = self.junction_cycle * self.z;
        stats.left_reads = self.junction_cycle * self.z;
        let out = dleft.dump(self.shape.n_left);
        Ok((out, stats))
    }

    /// Update (eq. 4): stochastic gradient step on weights (in the weight
    /// bank, via its write port) and biases, using the *queued* left
    /// activations of the input being updated.
    pub fn update(
        &mut self,
        a_prev_old: &[f32],
        delta_right: &[f32],
        bias: &mut [f32],
        lr: f32,
    ) -> Result<OpStats, Clash> {
        assert_eq!(a_prev_old.len(), self.shape.n_left);
        assert_eq!(delta_right.len(), self.shape.n_right);
        let mut left = Bank::new("a_q", self.z, self.sched.depth, Port::Single);
        left.load(a_prev_old);
        let mut dright = Bank::new("d'", self.z_next, ceil_div(self.shape.n_right, self.z_next), Port::SimpleDual);
        dright.load(delta_right);
        let mut stats = OpStats::default();
        let mut cnt = vec![0usize; self.shape.n_right];

        for t in 0..self.junction_cycle {
            let mut rights: Vec<usize> = (0..self.z)
                .map(|m| (t * self.z + m) / self.d_in)
                .collect();
            rights.dedup();
            stats.max_rights_per_cycle = stats.max_rights_per_cycle.max(rights.len());
            let mut dvals = std::collections::BTreeMap::new();
            for &j in &rights {
                dvals.insert(j, dright.read_entity(j)?);
                stats.right_accesses += 1;
            }
            for m in 0..self.z {
                let e = t * self.z + m;
                let j = e / self.d_in;
                let (wm, wa) = (e % self.z, e / self.z);
                let w = self.weights.read(wm, wa)?;
                let (lm, la) = self.sched.cycles[t][m];
                let a = left.read(lm, la)?;
                // eq. (4b): dual-port write-back in the same cycle
                self.weights.write(wm, wa, w - lr * dvals[&j] * a)?;
                cnt[j] += 1;
                if cnt[j] == self.d_in {
                    // eq. (4a), once per right neuron as it completes
                    bias[j] -= lr * dvals[&j];
                }
            }
            self.weights.tick();
            left.tick();
            dright.tick();
            stats.cycles += 1;
        }
        stats.weight_reads = self.junction_cycle * self.z;
        stats.weight_writes = self.junction_cycle * self.z;
        stats.left_reads = self.junction_cycle * self.z;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::clash_free::{schedule, Flavor};
    use crate::util::rng::Rng;

    fn reference_ff(p: &Pattern, w: &[f32], a: &[f32], bias: &[f32]) -> Vec<f32> {
        let nl = p.shape.n_left;
        (0..p.shape.n_right)
            .map(|j| {
                p.in_edges[j]
                    .iter()
                    .map(|&k| w[j * nl + k as usize] * a[k as usize])
                    .sum::<f32>()
                    + bias[j]
            })
            .collect()
    }

    fn setup(nl: usize, nr: usize, d_out: usize, z: usize, seed: u64) -> (JunctionUnit, Vec<f32>) {
        let shape = JunctionShape { n_left: nl, n_right: nr };
        let d_in = nl * d_out / nr;
        let mut rng = Rng::new(seed);
        let sched = schedule(nl, z, d_out, Flavor::Type1 { dither: false }, &mut rng);
        let z_next = JunctionUnit::required_z_next(nr * d_in, z, d_in);
        let mut unit = JunctionUnit::new(shape, d_in, sched, z_next);
        let dense: Vec<f32> = (0..nr * nl).map(|_| rng.normal()).collect();
        unit.load_weights_dense(&dense);
        (unit, dense)
    }

    #[test]
    fn ff_matches_reference_and_counts_cycles() {
        for (nl, nr, dout, z) in [(12, 8, 2, 4), (800, 100, 20, 200), (40, 10, 2, 8)] {
            let (mut unit, dense) = setup(nl, nr, dout, z, 1);
            let mut rng = Rng::new(2);
            let a: Vec<f32> = (0..nl).map(|_| rng.normal()).collect();
            let bias: Vec<f32> = (0..nr).map(|_| rng.normal()).collect();
            let out = unit.feedforward(&a, &bias, Act::Relu).unwrap();
            let pattern = unit.pattern();
            pattern.audit().unwrap();
            // masked dense weights equal what the unit dumped
            let masked: Vec<f32> = {
                let m = pattern.mask();
                dense.iter().zip(&m).map(|(w, mm)| w * mm).collect()
            };
            let want_h = reference_ff(&pattern, &masked, &a, &bias);
            for (g, w) in out.h.iter().zip(&want_h) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w} at ({nl},{nr},{dout},{z})");
            }
            for (j, (av, hv)) in out.a.iter().zip(&out.h).enumerate() {
                assert_eq!(*av, hv.max(0.0), "act mismatch at {j}");
                assert_eq!(out.adot[j], if *hv > 0.0 { 1.0 } else { 0.0 });
            }
            assert_eq!(out.stats.cycles, nl * dout / z);
            assert!(out.stats.max_rights_per_cycle <= unit.z_next);
        }
    }

    #[test]
    fn bp_matches_reference() {
        let (mut unit, dense) = setup(24, 12, 3, 8, 3);
        let pattern = unit.pattern();
        let mask = pattern.mask();
        let mut rng = Rng::new(4);
        let dr: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let adot: Vec<f32> = (0..24).map(|_| if rng.uniform() > 0.5 { 1.0 } else { 0.0 }).collect();
        let (dl, stats) = unit.backprop(&dr, &adot).unwrap();
        // reference: dl[k] = adot[k] * sum_j mask[j,k] w[j,k] dr[j]
        for k in 0..24 {
            let want: f32 = (0..12)
                .map(|j| mask[j * 24 + k] * dense[j * 24 + k] * dr[j])
                .sum::<f32>()
                * adot[k];
            assert!((dl[k] - want).abs() < 1e-4, "k={k}: {} vs {want}", dl[k]);
        }
        assert_eq!(stats.cycles, unit.junction_cycle);
    }

    #[test]
    fn up_matches_reference_sgd() {
        let (mut unit, dense) = setup(24, 12, 3, 8, 5);
        let pattern = unit.pattern();
        let mask = pattern.mask();
        let mut rng = Rng::new(6);
        let a_old: Vec<f32> = (0..24).map(|_| rng.normal()).collect();
        let dr: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let mut bias: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let bias0 = bias.clone();
        let lr = 0.05;
        unit.update(&a_old, &dr, &mut bias, lr).unwrap();
        let got = unit.dump_weights_dense();
        for j in 0..12 {
            for k in 0..24 {
                let idx = j * 24 + k;
                let want = if mask[idx] == 1.0 {
                    dense[idx] - lr * dr[j] * a_old[k]
                } else {
                    0.0
                };
                assert!((got[idx] - want).abs() < 1e-5, "({j},{k}): {} vs {want}", got[idx]);
            }
            assert!((bias[j] - (bias0[j] - lr * dr[j])).abs() < 1e-6);
        }
    }

    #[test]
    fn excluded_edges_never_touched() {
        // hardware stores only connected edges: dump of a sparse unit has
        // zeros exactly off-pattern
        let (mut unit, _) = setup(40, 10, 2, 8, 7);
        let pattern = unit.pattern();
        let mask = pattern.mask();
        let mut rng = Rng::new(8);
        for _ in 0..3 {
            let a: Vec<f32> = (0..40).map(|_| rng.normal()).collect();
            let dr: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
            let mut bias = vec![0f32; 10];
            unit.update(&a, &dr, &mut bias, 0.1).unwrap();
        }
        let w = unit.dump_weights_dense();
        for (idx, (wv, mv)) in w.iter().zip(&mask).enumerate() {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0, "excluded edge {idx} modified");
            }
        }
    }

    #[test]
    fn fc_junction_flexibility() {
        // Sec. III-E: same junction FC with the same z takes d_in/z-fold
        // longer; with bigger z, the same junction cycle.
        let shape = JunctionShape { n_left: 12, n_right: 8 };
        let mut rng = Rng::new(9);
        let sched_small = schedule(12, 4, 8, Flavor::Type1 { dither: false }, &mut rng);
        let unit_small = JunctionUnit::new(shape, 12, sched_small, 1);
        assert_eq!(unit_small.junction_cycle, 24);
        let mut rng2 = Rng::new(10);
        let sched_big = schedule(12, 4, 2, Flavor::Type1 { dither: false }, &mut rng2);
        let unit_sparse = JunctionUnit::new(shape, 3, sched_big, 2);
        assert_eq!(unit_sparse.junction_cycle, 6);
    }

    #[test]
    fn quantized_ff_tracks_f32_ff() {
        use crate::nn::fixed::QFormat;
        let fmt = QFormat::default();
        for (nl, nr, dout, z) in [(12, 8, 2, 4), (40, 10, 2, 8)] {
            let (mut unit, _) = setup(nl, nr, dout, z, 21);
            let mut rng = Rng::new(22);
            let a: Vec<f32> = (0..nl).map(|_| rng.uniform() * 2.0 - 1.0).collect();
            let bias: Vec<f32> = (0..nr).map(|_| rng.uniform() - 0.5).collect();
            let f32_out = unit.feedforward(&a, &bias, Act::Relu).unwrap();
            let q_out = unit
                .feedforward_quantized(&a, &bias, Act::Relu, fmt)
                .unwrap();
            assert_eq!(q_out.saturations, 0, "toy junction must not saturate");
            assert_eq!(q_out.stats.cycles, f32_out.stats.cycles);
            // single layer: d_in quantized products + bias + one rounding
            let d_in = unit.d_in as f32;
            let amax = a.iter().fold(0f32, |m, v| m.max(v.abs()));
            let wmax = unit
                .dump_weights_dense()
                .iter()
                .fold(0f32, |m, v| m.max(v.abs()));
            let bound = d_in * (amax + wmax) * 0.5 * fmt.ulp() + fmt.ulp() + 1e-5;
            for (j, (&hq, &hf)) in q_out.h_raw.iter().zip(&f32_out.h).enumerate() {
                let got = fmt.dequantize(hq);
                assert!(
                    (got - hf).abs() <= bound,
                    "({nl},{nr}) neuron {j}: {got} vs {hf} (bound {bound})"
                );
            }
            // activation and derivative bits agree with the raw sign
            for (j, &hq) in q_out.h_raw.iter().enumerate() {
                assert_eq!(q_out.a_raw[j], hq.max(0));
                assert_eq!(q_out.adot_bits[j], i32::from(hq > 0));
            }
        }
    }

    #[test]
    fn weight_roundtrip_dense() {
        let (mut unit, dense) = setup(12, 8, 2, 4, 11);
        let mask = unit.pattern().mask();
        let got = unit.dump_weights_dense();
        for i in 0..dense.len() {
            let want = dense[i] * mask[i];
            assert!((got[i] - want).abs() < 1e-6);
        }
        // edge-order load roundtrip
        let flat: Vec<f32> = (0..24).map(|x| x as f32).collect();
        unit.load_weights_edge_order(&flat);
        let dense2 = unit.dump_weights_dense();
        let flat2 = unit.pattern().compact_weights(&dense2);
        assert_eq!(flat, flat2);
    }
}
