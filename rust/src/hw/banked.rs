//! Clash-free banked view of one junction's compacted weight memory
//! (Fig. 4), shared between the cycle-accurate simulator and the
//! software pipelined trainer.
//!
//! The hardware stores edge `e` (numbered sequentially by right neuron)
//! in weight memory `e % z` at address `e / z`, and streams one address
//! row — `z` edges — per clock. Flattened address-major, that layout is
//! the *identity* permutation over the kernel's edge order: the
//! `nn::sparse` CSR buffers already hold the weights exactly as the
//! banked memories would. This module makes that contract executable
//! rather than implicit: [`BankedWeights`] derives the banked geometry
//! from a junction's edge count and a z from
//! [`crate::hw::zconfig::balanced_for_edges`], and [`BankedWeights::audit`]
//! replays a full junction cycle of FF/BP reads plus UP write-backs
//! through a real [`crate::hw::memory::Bank`] — so a refactor that broke
//! the edge order or the port discipline fails the audit instead of
//! silently diverging from the hardware model.

use crate::hw::memory::{Bank, Clash, Port};

/// Banked geometry of one junction's weight memory: `z` simple
/// dual-ported memories of `depth` words each, edge `e` at memory
/// `e % z`, address `e / z` (the Fig. 4 layout).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BankedWeights {
    /// Parallel weight memories (= edge processors fed per cycle).
    pub z: usize,
    /// Words per memory = the junction cycle `C = |W| / z`.
    pub depth: usize,
}

impl BankedWeights {
    /// View over `n_edges` compacted weights with parallelism `z`
    /// (`z` must divide `n_edges`, the [`crate::hw::zconfig`] contract).
    pub fn new(n_edges: usize, z: usize) -> Self {
        assert!(z > 0 && n_edges > 0, "empty banked view");
        assert!(
            n_edges % z == 0,
            "z = {z} does not divide |W| = {n_edges}"
        );
        BankedWeights {
            z,
            depth: n_edges / z,
        }
    }

    /// Total edges the view covers.
    pub fn n_edges(&self) -> usize {
        self.z * self.depth
    }

    /// (memory, address) of edge `e` — the Fig. 4 placement.
    pub fn location_of(&self, e: usize) -> (usize, usize) {
        (e % self.z, e / self.z)
    }

    /// The `z` edges streamed in operation cycle `t` (one per memory).
    pub fn lanes(&self, t: usize) -> std::ops::Range<usize> {
        t * self.z..(t + 1) * self.z
    }

    /// Replay one junction cycle of weight traffic through a real
    /// [`Bank`] with the hardware's port discipline — every cycle issues
    /// one read (the shared FF/BP/UP read) and one UP write-back per
    /// memory, which simple dual porting must absorb clash-free — then
    /// verify the bank's entity-ordered dump equals `wc`, proving the
    /// kernel's edge order *is* the banked layout.
    pub fn audit(&self, wc: &[f32]) -> Result<(), Clash> {
        self.replay(wc)
    }

    /// The fixed-point variant of [`BankedWeights::audit`]: the same
    /// replay over raw Qm.n words (`crate::nn::fixed`), because the
    /// weight memories of the quantized hardware hold integer words —
    /// banked weight replay carries whatever word type the execution
    /// path uses, the geometry and port discipline are identical.
    pub fn audit_fixed(&self, wq: &[i32]) -> Result<(), Clash> {
        self.replay(wq)
    }

    /// Word-type-generic replay behind [`BankedWeights::audit`] /
    /// [`BankedWeights::audit_fixed`].
    fn replay<T: Copy + Default + PartialEq>(&self, wc: &[T]) -> Result<(), Clash> {
        if wc.len() != self.n_edges() {
            return Err(Clash {
                memory: 0,
                cycle: 0,
                what: "weight buffer length does not match the banked geometry",
            });
        }
        let mut bank: Bank<T> = Bank::new("W", self.z, self.depth, Port::SimpleDual);
        bank.load(wc);
        for t in 0..self.depth {
            for e in self.lanes(t) {
                let (m, a) = self.location_of(e);
                let w = bank.read(m, a)?;
                // UP writes back through the second port in the same cycle
                bank.write(m, a, w)?;
            }
            bank.tick();
        }
        if bank.dump(self.n_edges()) != wc {
            return Err(Clash {
                memory: 0,
                cycle: self.depth,
                what: "banked dump diverges from the kernel edge order",
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::zconfig::balanced_for_edges;

    #[test]
    fn layout_matches_fig4_placement() {
        let b = BankedWeights::new(12, 4);
        assert_eq!(b.depth, 3);
        assert_eq!(b.n_edges(), 12);
        assert_eq!(b.location_of(0), (0, 0));
        assert_eq!(b.location_of(5), (1, 1));
        assert_eq!(b.location_of(11), (3, 2));
        assert_eq!(b.lanes(2), 8..12);
    }

    #[test]
    fn audit_passes_for_balanced_views() {
        // the shapes the pipelined trainer actually derives
        let edges = [16usize * 20, 100 * 10];
        let zcfg = balanced_for_edges(&edges, 40);
        for (&e, &z) in edges.iter().zip(&zcfg.z) {
            let view = BankedWeights::new(e, z);
            let wc: Vec<f32> = (0..e).map(|x| x as f32 * 0.5).collect();
            view.audit(&wc).unwrap();
        }
    }

    #[test]
    fn audit_rejects_wrong_buffer_length() {
        let view = BankedWeights::new(8, 2);
        let err = view.audit(&[0.0; 7]).unwrap_err();
        assert!(err.what.contains("length"));
    }

    #[test]
    #[should_panic(expected = "does not divide")]
    fn non_dividing_z_is_rejected() {
        BankedWeights::new(10, 3);
    }

    #[test]
    fn z_equals_one_serial_view_audits_clean() {
        // z = 1 is the fully serial hardware: one memory, depth = |W|,
        // every cycle a 1R+1W pair on the same memory — legal on a
        // simple dual port, and the layout is trivially the identity
        let view = BankedWeights::new(13, 1);
        assert_eq!(view.depth, 13);
        assert_eq!(view.location_of(7), (0, 7));
        assert_eq!(view.lanes(5), 5..6);
        let wc: Vec<f32> = (0..13).map(|x| x as f32 - 6.0).collect();
        view.audit(&wc).unwrap();
    }

    #[test]
    fn prime_edge_counts_only_admit_trivial_z() {
        // a prime |W| only divides by 1 and itself; both extremes must
        // audit clean (z = |W| is the fully parallel single-cycle view)
        for e in [7usize, 13, 101] {
            let wc: Vec<f32> = (0..e).map(|x| x as f32 * 0.25).collect();
            BankedWeights::new(e, 1).audit(&wc).unwrap();
            let full = BankedWeights::new(e, e);
            assert_eq!(full.depth, 1);
            full.audit(&wc).unwrap();
        }
    }

    #[test]
    fn single_junction_single_edge_view() {
        // the degenerate single-junction, single-edge net (L = 1 with a
        // 1x1 junction): z = depth = 1
        let view = BankedWeights::new(1, 1);
        assert_eq!(view.n_edges(), 1);
        view.audit(&[0.5]).unwrap();
        view.audit_fixed(&[512]).unwrap();
    }

    #[test]
    fn fixed_word_replay_matches_f32_geometry() {
        // audit and audit_fixed run the identical schedule; quantized
        // words must replay clash-free through the same ports
        let edges = [12usize, 7, 100];
        for &e in &edges {
            let zcfg = balanced_for_edges(&[e], 5);
            let view = BankedWeights::new(e, zcfg.z[0]);
            let wq: Vec<i32> = (0..e as i32).map(|x| x * 17 - 40).collect();
            view.audit_fixed(&wq).unwrap();
        }
        // length mismatch is reported, not panicked
        let err = BankedWeights::new(8, 2).audit_fixed(&[0i32; 7]).unwrap_err();
        assert!(err.what.contains("length"));
    }
}
