//! Table-I storage cost model (Sec. III-A).
//!
//! Junction pipelining needs queued banks for layer parameters:
//! - `a`   (activations):   2(L-i)+1 banks of N_i words, i = 0..L-1,
//! - `a'`  (derivatives):   2(L-i)+1 banks of N_i words, i = 1..L-1,
//! - `d`   (deltas):        2 banks of N_i words, i = 1..L,
//! - `b`   (biases):        N_i words, i = 1..L,
//! - `W`   (weights):       N_i * d_in_i words, i = 1..L (the only banks
//!                          whose size shrinks with pre-defined sparsity).

use crate::sparsity::config::{DoutConfig, NetConfig};

/// Word counts per parameter type for a network + out-degree config.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StorageCost {
    /// Queued activation banks (`a`).
    pub activations: usize,
    /// Queued activation-derivative banks (`a-dot`).
    pub act_derivatives: usize,
    /// Delta banks (`d`).
    pub deltas: usize,
    /// Bias words (`b`).
    pub biases: usize,
    /// Weight words (`W` — the only banks pre-defined sparsity shrinks).
    pub weights: usize,
}

impl StorageCost {
    /// Total words across every parameter type.
    pub fn total(&self) -> usize {
        self.activations + self.act_derivatives + self.deltas + self.biases + self.weights
    }

    /// Inference-only variant: BP/UP logic removed (Sec. III intro), so no
    /// delta banks, no a-dot banks, and single (unqueued) activation banks.
    pub fn inference_only(net: &NetConfig, dout: &DoutConfig) -> StorageCost {
        let din = net.din(dout);
        StorageCost {
            activations: net.layers[..net.layers.len() - 1].iter().sum(),
            act_derivatives: 0,
            deltas: 0,
            biases: net.layers[1..].iter().sum(),
            weights: din.iter().zip(&net.layers[1..]).map(|(d, n)| d * n).sum(),
        }
    }
}

/// Training-mode storage (the Table-I expressions).
pub fn training_storage(net: &NetConfig, dout: &DoutConfig) -> StorageCost {
    let l = net.n_junctions();
    let din = net.din(dout);
    let activations = (0..l).map(|i| (2 * (l - i) + 1) * net.layers[i]).sum();
    let act_derivatives = (1..l).map(|i| (2 * (l - i) + 1) * net.layers[i]).sum();
    let deltas = 2 * net.layers[1..].iter().sum::<usize>();
    let biases = net.layers[1..].iter().sum::<usize>();
    let weights = din.iter().zip(&net.layers[1..]).map(|(d, n)| d * n).sum();
    StorageCost {
        activations,
        act_derivatives,
        deltas,
        biases,
        weights,
    }
}

/// The Table-I comparison row: FC vs a sparse out-degree config.
pub struct StorageComparison {
    /// Training-mode storage of the fully-connected network.
    pub fc: StorageCost,
    /// Training-mode storage at the sparse out-degrees.
    pub sparse: StorageCost,
}

impl StorageComparison {
    /// Compare FC against `dout` for the same neuronal configuration.
    pub fn new(net: &NetConfig, dout: &DoutConfig) -> Self {
        StorageComparison {
            fc: training_storage(net, &net.fc_dout()),
            sparse: training_storage(net, dout),
        }
    }

    /// Memory reduction factor (paper: 3.9X for the Table-I config).
    pub fn memory_reduction(&self) -> f64 {
        self.fc.total() as f64 / self.sparse.total() as f64
    }

    /// Computational reduction factor — MLP compute is proportional to the
    /// number of weights (paper: 4.8X for the Table-I config).
    pub fn compute_reduction(&self) -> f64 {
        self.fc.weights as f64 / self.sparse.weights as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_fc_column() {
        // N_net = (800, 100, 10), FC
        let net = NetConfig::new(vec![800, 100, 10]);
        let c = training_storage(&net, &net.fc_dout());
        assert_eq!(c.activations, 4300); // 5*800 + 3*100
        assert_eq!(c.act_derivatives, 300); // 3*100
        assert_eq!(c.deltas, 220);
        assert_eq!(c.biases, 110);
        assert_eq!(c.weights, 81_000);
        assert_eq!(c.total(), 85_930);
    }

    #[test]
    fn table1_sparse_column() {
        // d_out = (20, 10) -> rho_net = 21%
        let net = NetConfig::new(vec![800, 100, 10]);
        let c = training_storage(&net, &DoutConfig(vec![20, 10]));
        assert_eq!(c.activations, 4300);
        assert_eq!(c.act_derivatives, 300);
        assert_eq!(c.deltas, 220);
        assert_eq!(c.biases, 110);
        assert_eq!(c.weights, 17_000);
        assert_eq!(c.total(), 21_930);
    }

    #[test]
    fn table1_reduction_factors() {
        let net = NetConfig::new(vec![800, 100, 10]);
        let cmp = StorageComparison::new(&net, &DoutConfig(vec![20, 10]));
        assert!((cmp.memory_reduction() - 3.9).abs() < 0.05, "{}", cmp.memory_reduction());
        assert!((cmp.compute_reduction() - 81.0 / 17.0).abs() < 1e-6);
    }

    #[test]
    fn four_junction_queue_depths() {
        // L=4: a banks for layer 0 need 2L+1 = 9 copies
        let net = NetConfig::new(vec![800, 100, 100, 100, 10]);
        let c = training_storage(&net, &net.fc_dout());
        assert_eq!(c.activations, 9 * 800 + 7 * 100 + 5 * 100 + 3 * 100);
        assert_eq!(c.act_derivatives, 7 * 100 + 5 * 100 + 3 * 100);
        assert_eq!(c.deltas, 2 * 310);
    }

    #[test]
    fn inference_only_drops_training_banks() {
        let net = NetConfig::new(vec![800, 100, 10]);
        let dout = DoutConfig(vec![20, 10]);
        let inf = StorageCost::inference_only(&net, &dout);
        assert_eq!(inf.act_derivatives, 0);
        assert_eq!(inf.deltas, 0);
        assert_eq!(inf.activations, 900);
        assert_eq!(inf.weights, 17_000);
        assert!(inf.total() < training_storage(&net, &dout).total());
    }
}
