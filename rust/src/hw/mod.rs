//! Cycle-accurate simulator of the paper's edge-based hardware
//! architecture (Sec. III) — the substitution for the authors' FPGA
//! implementation [40] (see DESIGN.md §Substitutions).
//!
//! - [`memory`]: single/dual-port memories and banks with per-cycle clash
//!   detection (footnote 6's definition of a clash),
//! - [`banked`]: the Fig. 4 banked weight-memory geometry as an auditable
//!   view — shared with the software pipelined trainer (`nn::pipeline`),
//!   which replays its weight traffic through it; carries f32 *or* raw
//!   fixed-point words (the quantized path's integer weight memories),
//! - [`zconfig`]: degree-of-parallelism selection, the `C_i = |W_i|/z_i = C`
//!   balance rule and the eq. (9) stall-freedom constraint,
//! - [`junction`]: numeric FF / BP / UP execution of one junction against
//!   the banked memories, replaying the clash-free access schedule — in
//!   f32 and, via `feedforward_quantized`, in saturating Qm.n fixed
//!   point (bit-identical to the `nn::fixed` batch kernels),
//! - [`pipeline`]: L-stage junction pipelining + FF/BP/UP operational
//!   parallelism (Fig. 2c), throughput/latency/staleness accounting,
//!   including the per-context (multi-tenant) schedule audit,
//! - [`context`]: per-context state banks (the multi-tenant context RAM:
//!   C tenants interleave through one junction schedule, each cycle
//!   fetching its tenant's bank), with an audited fetch log,
//! - [`storage`]: the Table-I storage cost model.

pub mod banked;
pub mod context;
pub mod junction;
pub mod memory;
pub mod pipeline;
pub mod storage;
pub mod zconfig;
