//! Per-context state banks: the multi-tenant context RAM.
//!
//! The paper's junction pipeline (Sec. III-A) time-multiplexes one set of
//! arithmetic units across junction cycles; this module pushes the same
//! idea one axis further, the way micro-blossom's `contextId` /
//! `contextDepth` RAM does for its dual-stage pipeline: every piece of
//! mutable pipeline state (weights, optimizer accumulators, version
//! counters) is held in `C` banks indexed by a [`ContextId`], and each
//! cycle *fetches* the bank of the context that owns the cycle's input
//! instead of swapping state in and out. `C` independent tenants then
//! interleave through one junction schedule with zero idle cycles
//! between them.
//!
//! Correctness of everything built on top reduces to one invariant: a
//! fetch for context `c` must hit bank `c`, every time. [`ContextBank`]
//! therefore keeps a log of `(requested, effective)` bank pairs and
//! [`ContextBank::audit`] replays it, returning a typed
//! [`ContextError`] that names the offending context on the first
//! violation. The `#[doc(hidden)]` fault hooks ([`ContextFault`]) exist
//! so the isolation test battery can prove the audit is non-vacuous:
//! aliasing two contexts onto one bank, or dropping a context's
//! fetches, must be *caught*, not survived.

use std::fmt;

/// Identifier of a tenant context: dense, 0-based, `< contexts`.
pub type ContextId = usize;

/// A deliberately injected context-fetch defect (test-only hook; see the
/// module docs). Installed via [`ContextBank::inject_fault`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContextFault {
    /// Fetches for `from` silently land on `to`'s bank — two tenants
    /// aliased onto one set of weights.
    Alias {
        /// The context whose fetches are misrouted.
        from: ContextId,
        /// The bank that absorbs them.
        to: ContextId,
    },
    /// Fetches for `context` are dropped entirely — the tenant's cycles
    /// never reach its bank.
    Skip {
        /// The context whose fetches are dropped.
        context: ContextId,
    },
}

/// Typed context-isolation violation. The fetch-discipline variants
/// name the offending context, so audits can point at the tenant whose
/// state was corrupted (or starved) rather than just failing globally.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContextError {
    /// A fetch for `requested` hit bank `effective` instead.
    Aliased {
        /// The context that issued the fetch.
        requested: ContextId,
        /// The bank the fetch actually landed on.
        effective: ContextId,
    },
    /// A fetch for `context` was dropped (the bank was never reached).
    Skipped {
        /// The context whose fetch was dropped.
        context: ContextId,
    },
    /// A context id outside the configured bank count was used.
    OutOfRange {
        /// The offending context id.
        context: ContextId,
        /// The configured number of banks.
        contexts: usize,
    },
    /// The measured per-context staleness diverged from the
    /// `floor((2(L-i)+1)/C)` closed form (a schedule defect, not a
    /// single tenant's).
    StalenessLaw {
        /// Junction (1-based) where the divergence appeared.
        junction: usize,
        /// Measured per-context staleness.
        measured: usize,
        /// Closed-form expectation.
        expected: usize,
    },
}

impl ContextError {
    /// The context this violation indicts (for `Aliased`, the
    /// requester); `None` for schedule-wide defects.
    pub fn context(&self) -> Option<ContextId> {
        match *self {
            ContextError::Aliased { requested, .. } => Some(requested),
            ContextError::Skipped { context } => Some(context),
            ContextError::OutOfRange { context, .. } => Some(context),
            ContextError::StalenessLaw { .. } => None,
        }
    }
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ContextError::Aliased {
                requested,
                effective,
            } => write!(
                f,
                "context {requested} aliased onto bank {effective}: tenant isolation violated"
            ),
            ContextError::Skipped { context } => {
                write!(f, "context {context} fetch was skipped: tenant starved")
            }
            ContextError::OutOfRange { context, contexts } => {
                write!(f, "context {context} out of range (contexts = {contexts})")
            }
            ContextError::StalenessLaw {
                junction,
                measured,
                expected,
            } => write!(
                f,
                "per-context staleness at junction {junction} measured {measured}, \
                 closed form says {expected}"
            ),
        }
    }
}

impl std::error::Error for ContextError {}

/// `C` banks of per-context pipeline state, fetched per cycle.
///
/// The bank is deliberately dumb: it owns the state, routes each fetch,
/// and remembers where every fetch went. Whoever drives the pipeline
/// (e.g. [`crate::nn::pipeline::MultiPipelinedTrainer`]) calls
/// [`ContextBank::fetch_mut`] once per context cycle and
/// [`ContextBank::audit`] at the end of a run.
#[derive(Debug)]
pub struct ContextBank<T> {
    banks: Vec<T>,
    faults: Vec<ContextFault>,
    /// Every *distinct* route a fetch took: (requested, effective).
    /// Bounded by contexts², so the log survives arbitrarily long runs.
    routes: Vec<(ContextId, ContextId)>,
    /// Distinct requested ids whose fetch was dropped.
    skipped: Vec<ContextId>,
    fetches: u64,
}

impl<T> ContextBank<T> {
    /// Wrap per-context state, one entry per context (must be non-empty).
    pub fn new(banks: Vec<T>) -> ContextBank<T> {
        assert!(!banks.is_empty(), "context bank needs at least one bank");
        ContextBank {
            banks,
            faults: Vec::new(),
            routes: Vec::new(),
            skipped: Vec::new(),
            fetches: 0,
        }
    }

    /// Number of contexts (= banks).
    pub fn contexts(&self) -> usize {
        self.banks.len()
    }

    /// Read-only view of bank `ctx` (no routing, no logging; for
    /// inspection and end-of-run readout).
    pub fn peek(&self, ctx: ContextId) -> Option<&T> {
        self.banks.get(ctx)
    }

    /// Mutable view of bank `ctx` without the fetch path (setup only).
    pub fn peek_mut(&mut self, ctx: ContextId) -> Option<&mut T> {
        self.banks.get_mut(ctx)
    }

    /// Iterate all banks in context order (inspection).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.banks.iter()
    }

    /// The per-cycle fetch: route `ctx` through the (possibly faulted)
    /// selector, log where it landed, and hand out that bank. Returns
    /// `None` when the fetch is dropped (a [`ContextFault::Skip`]) or
    /// `ctx` is out of range — both recorded for [`ContextBank::audit`].
    pub fn fetch_mut(&mut self, ctx: ContextId) -> Option<&mut T> {
        let mut effective = ctx;
        for fault in &self.faults {
            match *fault {
                ContextFault::Alias { from, to } if from == effective => effective = to,
                ContextFault::Skip { context } if context == ctx => {
                    if !self.skipped.contains(&ctx) {
                        self.skipped.push(ctx);
                    }
                    return None;
                }
                _ => {}
            }
        }
        self.fetches += 1;
        if !self.routes.contains(&(ctx, effective)) {
            self.routes.push((ctx, effective));
        }
        self.banks.get_mut(effective)
    }

    /// Fetches routed so far (skipped fetches not included).
    pub fn fetches(&self) -> u64 {
        self.fetches
    }

    /// Replay the fetch log: every fetch must have hit its own bank and
    /// none may have been dropped. Returns a violation, naming the
    /// offending context.
    pub fn audit(&self) -> Result<(), ContextError> {
        if let Some(&context) = self.skipped.first() {
            return Err(ContextError::Skipped { context });
        }
        for &(requested, effective) in &self.routes {
            if requested >= self.banks.len() {
                return Err(ContextError::OutOfRange {
                    context: requested,
                    contexts: self.banks.len(),
                });
            }
            if requested != effective {
                return Err(ContextError::Aliased {
                    requested,
                    effective,
                });
            }
        }
        Ok(())
    }

    /// Install a context-fetch defect (test-only hook, kept out of the
    /// rendered docs; see the module docs on non-vacuity).
    #[doc(hidden)]
    pub fn inject_fault(&mut self, fault: ContextFault) {
        self.faults.push(fault);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_fetches_audit_clean() {
        let mut bank = ContextBank::new(vec![0u32, 0, 0]);
        for cycle in 0..12 {
            let ctx = cycle % 3;
            *bank.fetch_mut(ctx).unwrap() += 1;
        }
        assert_eq!(bank.fetches(), 12);
        bank.audit().unwrap();
        for c in 0..3 {
            assert_eq!(*bank.peek(c).unwrap(), 4, "each bank fetched equally");
        }
    }

    #[test]
    fn alias_fault_is_caught_and_names_the_context() {
        let mut bank = ContextBank::new(vec![0u32, 0]);
        bank.inject_fault(ContextFault::Alias { from: 1, to: 0 });
        *bank.fetch_mut(0).unwrap() += 1;
        *bank.fetch_mut(1).unwrap() += 1; // lands on bank 0
        assert_eq!(*bank.peek(0).unwrap(), 2, "bank 0 absorbed both");
        assert_eq!(*bank.peek(1).unwrap(), 0, "bank 1 starved");
        let err = bank.audit().unwrap_err();
        assert_eq!(
            err,
            ContextError::Aliased {
                requested: 1,
                effective: 0
            }
        );
        assert_eq!(err.context(), Some(1));
    }

    #[test]
    fn skip_fault_is_caught_and_names_the_context() {
        let mut bank = ContextBank::new(vec![(), (), ()]);
        bank.inject_fault(ContextFault::Skip { context: 2 });
        assert!(bank.fetch_mut(0).is_some());
        assert!(bank.fetch_mut(2).is_none());
        let err = bank.audit().unwrap_err();
        assert_eq!(err, ContextError::Skipped { context: 2 });
        assert_eq!(err.context(), Some(2));
    }

    #[test]
    fn out_of_range_fetch_is_reported() {
        let mut bank = ContextBank::new(vec![0u8]);
        assert!(bank.fetch_mut(3).is_none());
        // the fetch was logged (requested 3, routed to nothing valid)
        let err = bank.audit().unwrap_err();
        assert_eq!(
            err,
            ContextError::OutOfRange {
                context: 3,
                contexts: 1
            }
        );
    }
}
