//! Memory and bank models with clash detection.
//!
//! Footnote 6 of the paper defines clashes: for single-ported memories any
//! two operations in a cycle clash; for simple dual-ported memories (one
//! read port + one write port) a read and a write may share a cycle but
//! two reads or two writes clash.
//!
//! Memories are generic over their word type (default `f32`): the same
//! clash-checked banks carry f32 words for the reference simulator and
//! raw `i32` Qm.n words for the fixed-point execution path
//! ([`crate::nn::fixed`]) — the port discipline is a property of the
//! BRAM, not of what the words mean.

/// Port discipline of a memory (footnote 4: weight and delta memories are
/// simple dual-ported; a and a-dot memories are single-ported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Port {
    /// One access of any kind per cycle (a / a-dot memories).
    Single,
    /// One read + one write may share a cycle (weight / delta memories).
    SimpleDual,
}

/// One memory (a BRAM column in Fig. 2b / Fig. 4), generic over its word
/// type (`f32` reference words by default, raw `i32` fixed-point words
/// for the quantized path).
#[derive(Clone, Debug)]
pub struct Memory<T = f32> {
    /// The memory's port discipline.
    pub port: Port,
    data: Vec<T>,
    reads_this_cycle: usize,
    writes_this_cycle: usize,
}

/// Error raised when an access pattern violates the port discipline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Clash {
    /// Index of the memory within its bank.
    pub memory: usize,
    /// Cycle at which the clash occurred.
    pub cycle: usize,
    /// What discipline was violated.
    pub what: &'static str,
}

impl std::fmt::Display for Clash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "clash on memory {} at cycle {}: {}", self.memory, self.cycle, self.what)
    }
}

impl<T: Copy + Default> Memory<T> {
    /// A zeroed memory of `depth` words with the given port discipline.
    pub fn new(depth: usize, port: Port) -> Self {
        Self {
            port,
            data: vec![T::default(); depth],
            reads_this_cycle: 0,
            writes_this_cycle: 0,
        }
    }

    /// Words the memory holds.
    pub fn depth(&self) -> usize {
        self.data.len()
    }

    fn check_read(&self) -> Result<(), &'static str> {
        match self.port {
            Port::Single if self.reads_this_cycle + self.writes_this_cycle >= 1 => {
                Err("second access to single-ported memory")
            }
            Port::SimpleDual if self.reads_this_cycle >= 1 => {
                Err("second read on dual-ported memory")
            }
            _ => Ok(()),
        }
    }

    fn check_write(&self) -> Result<(), &'static str> {
        match self.port {
            Port::Single if self.reads_this_cycle + self.writes_this_cycle >= 1 => {
                Err("second access to single-ported memory")
            }
            Port::SimpleDual if self.writes_this_cycle >= 1 => {
                Err("second write on dual-ported memory")
            }
            _ => Ok(()),
        }
    }
}

/// A bank of `z` memories accessed in parallel each cycle (Fig. 2b).
/// Tracks the cycle counter and enforces clash-freedom on every access.
/// Generic over the word type like [`Memory`].
#[derive(Clone, Debug)]
pub struct Bank<T = f32> {
    /// Label used in diagnostics (`"W"`, `"a"`, `"d"`...).
    pub name: &'static str,
    mems: Vec<Memory<T>>,
    cycle: usize,
    /// Reads issued across all cycles.
    pub total_reads: usize,
    /// Writes issued across all cycles.
    pub total_writes: usize,
    /// Most accesses observed in any completed cycle.
    pub max_accesses_in_cycle: usize,
    accesses_this_cycle: usize,
}

impl<T: Copy + Default> Bank<T> {
    /// A bank of `z` zeroed memories, each `depth` words.
    pub fn new(name: &'static str, z: usize, depth: usize, port: Port) -> Self {
        Self {
            name,
            mems: (0..z).map(|_| Memory::new(depth, port)).collect(),
            cycle: 0,
            total_reads: 0,
            total_writes: 0,
            max_accesses_in_cycle: 0,
            accesses_this_cycle: 0,
        }
    }

    /// Memories in the bank (the degree of parallelism).
    pub fn z(&self) -> usize {
        self.mems.len()
    }

    /// Words per memory.
    pub fn depth(&self) -> usize {
        self.mems[0].depth()
    }

    /// Current clock cycle.
    pub fn cycle(&self) -> usize {
        self.cycle
    }

    /// Advance to the next clock cycle (resets per-cycle access tracking).
    pub fn tick(&mut self) {
        self.max_accesses_in_cycle = self.max_accesses_in_cycle.max(self.accesses_this_cycle);
        self.accesses_this_cycle = 0;
        for m in &mut self.mems {
            m.reads_this_cycle = 0;
            m.writes_this_cycle = 0;
        }
        self.cycle += 1;
    }

    /// Read `addr` of memory `mem` this cycle (clash-checked).
    pub fn read(&mut self, mem: usize, addr: usize) -> Result<T, Clash> {
        let m = &mut self.mems[mem];
        m.check_read().map_err(|what| Clash {
            memory: mem,
            cycle: self.cycle,
            what,
        })?;
        m.reads_this_cycle += 1;
        self.total_reads += 1;
        self.accesses_this_cycle += 1;
        Ok(m.data[addr])
    }

    /// Write `v` to `addr` of memory `mem` this cycle (clash-checked).
    pub fn write(&mut self, mem: usize, addr: usize, v: T) -> Result<(), Clash> {
        let m = &mut self.mems[mem];
        m.check_write().map_err(|what| Clash {
            memory: mem,
            cycle: self.cycle,
            what,
        })?;
        m.writes_this_cycle += 1;
        m.data[addr] = v;
        self.total_writes += 1;
        self.accesses_this_cycle += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Neuron-indexed helpers: value for entity `n` lives in memory `n % z`
    // at address `n / z` (the Fig. 4 layout, used for both neurons and
    // sequentially-numbered edges).
    // ------------------------------------------------------------------

    /// (memory, address) of entity `n` in the Fig. 4 layout.
    pub fn location_of(&self, n: usize) -> (usize, usize) {
        (n % self.z(), n / self.z())
    }

    /// Read entity `n` through its Fig. 4 location.
    pub fn read_entity(&mut self, n: usize) -> Result<T, Clash> {
        let (m, a) = self.location_of(n);
        self.read(m, a)
    }

    /// Write entity `n` through its Fig. 4 location.
    pub fn write_entity(&mut self, n: usize, v: T) -> Result<(), Clash> {
        let (m, a) = self.location_of(n);
        self.write(m, a, v)
    }

    /// Bulk-load contents outside of timed simulation (e.g. DMA from host).
    pub fn load(&mut self, values: &[T]) {
        assert!(values.len() <= self.z() * self.depth());
        for (n, &v) in values.iter().enumerate() {
            let (m, a) = self.location_of(n);
            self.mems[m].data[a] = v;
        }
    }

    /// Dump contents (entity-ordered) outside of timed simulation.
    pub fn dump(&self, n: usize) -> Vec<T> {
        (0..n)
            .map(|i| {
                let (m, a) = self.location_of(i);
                self.mems[m].data[a]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_port_clash_rules() {
        let mut b = Bank::new("a", 2, 4, Port::Single);
        assert!(b.read(0, 0).is_ok());
        assert!(b.read(0, 1).is_err(), "two reads clash");
        assert!(b.read(1, 0).is_ok(), "other memory fine");
        b.tick();
        assert!(b.write(0, 0, 1.0).is_ok());
        assert!(b.read(0, 0).is_err(), "read after write clashes on single port");
    }

    #[test]
    fn dual_port_allows_read_plus_write() {
        let mut b = Bank::new("w", 1, 4, Port::SimpleDual);
        assert!(b.read(0, 0).is_ok());
        assert!(b.write(0, 1, 2.0).is_ok(), "1R+1W legal on simple dual port");
        assert!(b.read(0, 2).is_err(), "second read clashes");
        assert!(b.write(0, 3, 1.0).is_err(), "second write clashes");
        b.tick();
        assert_eq!(b.read(0, 1).unwrap(), 2.0);
    }

    #[test]
    fn entity_layout_matches_fig4() {
        // neuron n -> memory n % z, address n / z; Fig. 2b: with z=4,
        // address row 1 of memory 0 holds neuron 4.
        let mut b = Bank::new("a", 4, 3, Port::Single);
        b.load(&(0..12).map(|x| x as f32).collect::<Vec<_>>());
        assert_eq!(b.location_of(4), (0, 1));
        assert_eq!(b.read_entity(4).unwrap(), 4.0);
        b.tick();
        assert_eq!(b.read_entity(11).unwrap(), 11.0);
        assert_eq!(b.location_of(11), (3, 2));
    }

    #[test]
    fn stats_accumulate() {
        let mut b = Bank::new("w", 2, 2, Port::SimpleDual);
        b.read(0, 0).unwrap();
        b.read(1, 0).unwrap();
        b.write(0, 1, 1.0).unwrap();
        b.tick();
        b.read(0, 1).unwrap();
        b.tick();
        assert_eq!(b.total_reads, 3);
        assert_eq!(b.total_writes, 1);
        assert_eq!(b.cycle(), 2);
        assert_eq!(b.max_accesses_in_cycle, 3);
    }

    #[test]
    fn dump_roundtrip() {
        let mut b = Bank::new("a", 3, 4, Port::Single);
        let vals: Vec<f32> = (0..10).map(|x| x as f32 * 0.5).collect();
        b.load(&vals);
        assert_eq!(b.dump(10), vals);
    }

    #[test]
    fn fixed_word_bank_keeps_port_discipline() {
        // the same bank model carries raw i32 fixed-point words; the
        // clash rules are unchanged because they never look at the data
        let mut b: Bank<i32> = Bank::new("Wq", 2, 3, Port::SimpleDual);
        let vals: Vec<i32> = (0..6).map(|x| x * 37 - 50).collect();
        b.load(&vals);
        assert_eq!(b.read(0, 0).unwrap(), vals[0]);
        assert!(b.write(0, 1, 99).is_ok(), "1R+1W legal on simple dual port");
        assert!(b.read(0, 2).is_err(), "second read clashes");
        b.tick();
        // the write landed at memory 0, address 1 = entity 2
        assert_eq!(b.read_entity(2).unwrap(), 99);
        assert_eq!(b.dump(6)[2], 99);
        assert_eq!(b.dump(6)[1], vals[1]);
    }
}
