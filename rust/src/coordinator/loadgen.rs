//! Closed-loop load generation for the [`InferenceService`], shared by
//! the `pds serve` / `pds serve-bench` CLI commands, the `serve_load`
//! bench target, and the service integration tests.
//!
//! A *closed-loop* client submits one request, waits for the reply, then
//! submits the next — so total in-flight load equals the client count
//! and a saturated service slows the clients down instead of building an
//! unbounded backlog. [`ServeError::Busy`] rejections are retried after
//! a short backoff and counted via the model's
//! [`crate::coordinator::ModelMetrics::rejected`] counter. The arrival
//! pattern is shaped by [`LoadSpec::burst`] / [`LoadSpec::think_time`]:
//! bursty arrivals stress the shard router and the dynamic batcher's
//! partial-flush path.
//!
//! The *socket* mode ([`run_socket_load`]) drives the same closed loop
//! through a real TCP connection per client against a
//! [`crate::net::NetServer`], with pipelined multi-sample groups — the
//! traffic shape the network micro-batcher coalesces. It backs
//! `benches/net_load/` (the `net` section of `BENCH_serve.json`,
//! including the achieved mean coalesced batch size) and the `pds
//! serve --listen` end-to-end tests.
//!
//! The *soak* mode ([`run_soak_load`]) holds a large mostly-idle
//! connection population open against the server's single reactor
//! thread with a heavy-tailed request mix, reporting tail latency
//! (p99/p999) and the server's shed rate — the reactor scale-out
//! numbers in `BENCH_serve.json`'s `net.soak` subsection.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::server::{InferenceService, LatencyHistogram, ModelSpec, ServeError, ServerConfig};
use crate::net::{NetClient, NetClientError};
use crate::runtime::Manifest;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::sparsity::{generate, Method};
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Backoff between retries of a [`ServeError::Busy`] rejection.
const BUSY_BACKOFF: Duration = Duration::from_micros(200);

/// Shape of the offered load, per model.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    /// Concurrent closed-loop client threads per model.
    pub clients: usize,
    /// Requests each client submits.
    pub requests: usize,
    /// Pause a client inserts after every `burst` responses (zero =
    /// submit back-to-back; the classic closed loop).
    pub think_time: Duration,
    /// Responses between pauses; 1 with a nonzero `think_time` is a
    /// uniform paced arrival, larger values are bursty arrivals.
    pub burst: usize,
    /// Tenant contexts to spread the load across, round-robin per
    /// request (clamped to what each model actually hosts). 1 = the
    /// single-tenant load of earlier revisions.
    pub contexts: usize,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            clients: 8,
            requests: 100,
            think_time: Duration::ZERO,
            burst: 1,
            contexts: 1,
        }
    }
}

/// What one model sustained under a [`LoadSpec`].
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Model (manifest config) name.
    pub model: String,
    /// Workers per model the service ran with.
    pub workers: usize,
    /// Closed-loop clients that drove this model.
    pub clients: usize,
    /// Tenant contexts the offered load was spread across.
    pub contexts: usize,
    /// Requests served.
    pub served: u64,
    /// Submit attempts rejected with [`ServeError::Busy`] (each was
    /// retried by the load generator).
    pub rejected: u64,
    /// Wall-clock time of the whole load run.
    pub wall: Duration,
    /// Sustained requests per second (served / wall).
    pub throughput: f64,
    /// Median request latency (submit to reply).
    pub p50: Duration,
    /// 95th-percentile latency.
    pub p95: Duration,
    /// 99th-percentile latency.
    pub p99: Duration,
    /// Batches executed.
    pub batches: u64,
    /// Mean live rows per batch.
    pub mean_occupancy: f64,
    /// Requests served by a worker that stole them from a sibling shard.
    pub stolen: u64,
    /// Achieved activation density over the run
    /// ([`ModelMetrics::act_density`]): 1.0 when the model served
    /// without an activation mask.
    pub act_density: f64,
}

impl LoadReport {
    /// One-line human-readable summary.
    pub fn print(&self) {
        println!(
            "{:<12} workers {:>2}, clients {:>2}: {:>8.0} req/s | p50 {:>9.2?} p95 {:>9.2?} \
             p99 {:>9.2?} | occupancy {:>5.1} | {} batches, {} rejected, {} stolen",
            self.model,
            self.workers,
            self.clients,
            self.throughput,
            self.p50,
            self.p95,
            self.p99,
            self.mean_occupancy,
            self.batches,
            self.rejected,
            self.stolen,
        );
    }

    /// JSON object for `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("contexts".to_string(), Json::Num(self.contexts as f64));
        m.insert("served".to_string(), Json::Num(self.served as f64));
        m.insert("rejected".to_string(), Json::Num(self.rejected as f64));
        m.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        m.insert("throughput_rps".to_string(), Json::Num(self.throughput));
        m.insert("p50_us".to_string(), Json::Num(self.p50.as_secs_f64() * 1e6));
        m.insert("p95_us".to_string(), Json::Num(self.p95.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), Json::Num(self.p99.as_secs_f64() * 1e6));
        m.insert("batches".to_string(), Json::Num(self.batches as f64));
        m.insert(
            "mean_occupancy".to_string(),
            Json::Num(self.mean_occupancy),
        );
        m.insert("stolen".to_string(), Json::Num(self.stolen as f64));
        m.insert("act_density".to_string(), Json::Num(self.act_density));
        Json::Obj(m)
    }
}

/// Build a [`ModelSpec`] for `config` with a clash-free pattern at
/// roughly `density` (snapped to the admissible degree set), the shape
/// every serve surface (CLI, bench, example, tests) uses.
pub fn model_spec(
    artifacts_dir: impl AsRef<Path>,
    config: &str,
    density: f64,
    seed: u64,
) -> Result<ModelSpec> {
    let probe = Manifest::probe(artifacts_dir, config)?;
    let netc = NetConfig::new(probe.layers.clone());
    let dout = DoutConfig(
        (0..netc.n_junctions())
            .map(|i| netc.junction(i).dout_for_density(density))
            .collect(),
    );
    let mut rng = Rng::new(seed);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    Ok(ModelSpec::new(config, pattern))
}

/// Drive `spec` against every model in `models` concurrently and return
/// one report per model. Counters are read from the service's metrics,
/// so this expects a freshly started service (cumulative counters would
/// fold earlier traffic into the report).
pub fn run_load(
    svc: &InferenceService,
    models: &[String],
    spec: &LoadSpec,
    seed: u64,
) -> Result<Vec<LoadReport>> {
    anyhow::ensure!(spec.clients > 0 && spec.requests > 0, "empty load spec");
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (mi, model) in models.iter().enumerate() {
            let client = svc.client(model)?;
            // spread requests across tenant contexts, clamped to what
            // the model actually hosts
            let ctxs = spec.contexts.clamp(1, client.contexts());
            for c in 0..spec.clients {
                let client = client.clone();
                handles.push(s.spawn(move || -> Result<()> {
                    let mut rng = Rng::new(seed ^ ((mi as u64) << 32) ^ c as u64);
                    let mut since_pause = 0usize;
                    for i in 0..spec.requests {
                        let ctx = (c + i) % ctxs;
                        let x: Vec<f32> =
                            (0..client.features()).map(|_| rng.normal()).collect();
                        loop {
                            match client.classify_ctx(x.clone(), ctx) {
                                Ok(p) => {
                                    anyhow::ensure!(
                                        p.class < client.classes(),
                                        "class {} out of range for {}",
                                        p.class,
                                        client.model()
                                    );
                                    break;
                                }
                                Err(ServeError::Busy) => std::thread::sleep(BUSY_BACKOFF),
                                Err(e) => anyhow::bail!("classify failed: {e}"),
                            }
                        }
                        since_pause += 1;
                        if !spec.think_time.is_zero() && since_pause >= spec.burst.max(1) {
                            std::thread::sleep(spec.think_time);
                            since_pause = 0;
                        }
                    }
                    Ok(())
                }));
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("load client panicked"))??;
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    let workers = svc.config().workers.max(1);
    // one registry snapshot covers every model's counters coherently —
    // the same view the CLI dump and the wire Metrics frame report
    let reg = svc.registry().snapshot();
    models
        .iter()
        .map(|m| Ok(snapshot(m, workers, spec, &reg, wall)))
        .collect()
}

fn snapshot(
    model: &str,
    workers: usize,
    spec: &LoadSpec,
    reg: &crate::obs::Snapshot,
    wall: Duration,
) -> LoadReport {
    let labels: &[(&str, &str)] = &[("model", model)];
    let served = reg.counter("serve.requests", labels).unwrap_or(0);
    let hist = reg.histogram("serve.latency", labels).unwrap_or_default();
    LoadReport {
        model: model.to_string(),
        workers,
        clients: spec.clients,
        contexts: spec.contexts.max(1),
        served,
        rejected: reg.counter("serve.rejected", labels).unwrap_or(0),
        wall,
        throughput: served as f64 / wall.as_secs_f64().max(1e-9),
        p50: Duration::from_micros(hist.p50_us),
        p95: Duration::from_micros(hist.p95_us),
        p99: Duration::from_micros(hist.p99_us),
        batches: reg.counter("serve.batches", labels).unwrap_or(0),
        mean_occupancy: reg.gauge("serve.occupancy_mean", labels).unwrap_or(0.0),
        stolen: reg.counter("serve.stolen", labels).unwrap_or(0),
        act_density: reg.gauge("serve.act_density", labels).unwrap_or(0.0),
    }
}

/// Start a fresh service for `models` with `workers` workers per model,
/// drive `load` against every model concurrently, shut down, and return
/// the per-model reports. The unit of comparison for the serve bench:
/// same load, varying worker count — and, with `quant` set, f32 vs
/// fixed-point execution of the same models under the same load
/// (`quant_exec` bench, `serve-bench --quant`); with `act` set, the
/// sparse-sparse execution of the same models (`actsparse` bench,
/// `serve-bench --act-topk`).
#[allow(clippy::too_many_arguments)]
pub fn bench_service(
    artifacts_dir: impl AsRef<Path>,
    models: &[String],
    workers: usize,
    queue_depth: usize,
    max_wait: Duration,
    load: &LoadSpec,
    seed: u64,
    quant: Option<crate::nn::fixed::QFormat>,
    act: Option<crate::nn::actsparse::ActSpec>,
) -> Result<Vec<LoadReport>> {
    let dir = artifacts_dir.as_ref();
    let specs = models
        .iter()
        .map(|m| {
            // host as many parameter banks as the load will spread over
            model_spec(dir, m, 0.25, seed).map(|s| ModelSpec {
                quant,
                contexts: load.contexts.max(1),
                act,
                ..s
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let svc = InferenceService::start(
        dir,
        specs,
        ServerConfig {
            max_wait,
            workers,
            queue_depth,
            tune_kernel_threads: true,
        },
    )?;
    let reports = run_load(&svc, models, load, seed ^ 0x5EED)?;
    svc.shutdown()?;
    Ok(reports)
}

/// Assemble the `BENCH_serve.json` document from `(workers, reports)`
/// scenarios; includes the sustained-throughput speedup of the largest
/// worker count over the single-worker baseline when both are present.
pub fn bench_json(scenarios: &[(usize, Vec<LoadReport>)]) -> Json {
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serve_load".to_string()));
    root.insert("recorded".to_string(), Json::Bool(true));
    root.insert(
        "kernel_threads_total".to_string(),
        Json::Num(parallel::machine_threads() as f64),
    );
    let mut arr = Vec::new();
    let mut base: Option<f64> = None;
    let mut best: Option<(usize, f64)> = None;
    for (workers, reports) in scenarios {
        let total: f64 = reports.iter().map(|r| r.throughput).sum();
        if *workers == 1 {
            base = Some(total);
        }
        let replace = match best {
            Some((w, _)) => *workers > w,
            None => true,
        };
        if replace {
            best = Some((*workers, total));
        }
        let mut obj = BTreeMap::new();
        obj.insert("workers".to_string(), Json::Num(*workers as f64));
        obj.insert(
            "contexts".to_string(),
            Json::Num(reports.first().map_or(1, |r| r.contexts) as f64),
        );
        obj.insert("total_throughput_rps".to_string(), Json::Num(total));
        obj.insert(
            "models".to_string(),
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        );
        arr.push(Json::Obj(obj));
    }
    root.insert("scenarios".to_string(), Json::Arr(arr));
    // always emit the speedup keys — Null when the sweep had no
    // single-worker baseline or no multi-worker scenario — so a
    // key-wise merge over an older file can never leave stale values
    let (sw, sv) = match (base, best) {
        (Some(b), Some((w, t))) if w > 1 && b > 0.0 => {
            (Json::Num(w as f64), Json::Num(t / b))
        }
        _ => (Json::Null, Json::Null),
    };
    root.insert("speedup_workers".to_string(), sw);
    root.insert("speedup_vs_single_worker".to_string(), sv);
    Json::Obj(root)
}

/// Write a serve-bench document to `path`, merging over whatever the
/// file already holds so unrelated top-level sections survive — the
/// `serve_load` and `quant_exec` benches both record into
/// `BENCH_serve.json`, each owning different keys. When `doc` refreshes
/// the main scenario section (it carries a `recorded` flag), the
/// placeholder `note` is dropped. A missing file is written fresh; an
/// *unparsable* existing file is an error, never silently replaced —
/// losing the sibling bench's recorded section would be worse than
/// failing.
pub fn write_bench_json(path: impl AsRef<Path>, doc: Json) -> std::io::Result<()> {
    let path = path.as_ref();
    let merged = match std::fs::read_to_string(path) {
        Ok(text) => match (Json::parse(&text), doc) {
            (Ok(Json::Obj(mut base)), Json::Obj(new)) => {
                if new.contains_key("recorded") {
                    base.remove("note");
                }
                for (k, v) in new {
                    base.insert(k, v);
                }
                Json::Obj(base)
            }
            (Ok(_), _) | (Err(_), _) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "existing {} is not a JSON object — refusing to overwrite it \
                         (fix or delete the file, then rerun the bench)",
                        path.display()
                    ),
                ));
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => doc,
        Err(e) => return Err(e),
    };
    std::fs::write(path, format!("{merged}\n"))
}

/// Shape of the offered *socket* load, per model: closed-loop clients
/// over real TCP connections, each submitting pipelined groups.
#[derive(Clone, Copy, Debug)]
pub struct SocketLoadSpec {
    /// Concurrent closed-loop TCP clients per model (one connection
    /// each).
    pub clients: usize,
    /// Total samples each client submits.
    pub requests: usize,
    /// Samples per pipelined group ([`NetClient::classify_pipelined`]):
    /// the client writes the whole group before reading any response,
    /// which is the concurrency the server-side micro-batcher coalesces.
    pub pipeline: usize,
    /// Tenant contexts to spread the pipelined groups across,
    /// round-robin per group (clamped to what the server advertises for
    /// each model in its health frame). 1 = single-tenant load.
    pub contexts: usize,
}

impl Default for SocketLoadSpec {
    fn default() -> Self {
        SocketLoadSpec {
            clients: 4,
            requests: 96,
            pipeline: 8,
            contexts: 1,
        }
    }
}

/// What one model sustained under a [`SocketLoadSpec`], end to end
/// through the TCP front-end.
#[derive(Clone, Debug)]
pub struct SocketLoadReport {
    /// Model (manifest config) name.
    pub model: String,
    /// Closed-loop TCP clients that drove this model.
    pub clients: usize,
    /// Samples per pipelined group actually driven (the requested
    /// [`SocketLoadSpec::pipeline`] clamped to this model's engine
    /// batch size).
    pub pipeline: usize,
    /// Tenant contexts the groups were spread across (the requested
    /// [`SocketLoadSpec::contexts`] clamped to what the server hosts).
    pub contexts: usize,
    /// Samples served (responses received by the clients).
    pub served: u64,
    /// Pipelined groups retried after a `Busy` shed.
    pub busy_retries: u64,
    /// Wall-clock time of the whole socket load run.
    pub wall: Duration,
    /// Sustained samples per second through the socket (served / wall).
    pub throughput: f64,
    /// Median client-observed *group* round-trip (connect-side wall
    /// time per pipelined group, recorded once per sample).
    pub p50: Duration,
    /// 95th-percentile group round-trip.
    pub p95: Duration,
    /// 99th-percentile group round-trip.
    pub p99: Duration,
    /// Micro-batcher flushes at the server for this model.
    pub net_flushes: u64,
    /// Samples those flushes coalesced.
    pub net_coalesced: u64,
    /// Achieved mean coalesced batch size (`net_coalesced /
    /// net_flushes`) — the number that proves socket traffic reaches the
    /// engine as batches, not batch-1 calls.
    pub mean_coalesced: f64,
}

impl SocketLoadReport {
    /// One-line human-readable summary.
    pub fn print(&self) {
        println!(
            "{:<12} clients {:>2} x pipeline {:>2}: {:>8.0} samp/s | group p50 {:>9.2?} \
             p95 {:>9.2?} p99 {:>9.2?} | coalesced {:>5.1}/flush ({} flushes), {} busy retries",
            self.model,
            self.clients,
            self.pipeline,
            self.throughput,
            self.p50,
            self.p95,
            self.p99,
            self.mean_coalesced,
            self.net_flushes,
            self.busy_retries,
        );
    }

    /// JSON object for the `net` section of `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("pipeline".to_string(), Json::Num(self.pipeline as f64));
        m.insert("contexts".to_string(), Json::Num(self.contexts as f64));
        m.insert("served".to_string(), Json::Num(self.served as f64));
        m.insert(
            "busy_retries".to_string(),
            Json::Num(self.busy_retries as f64),
        );
        m.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        m.insert("throughput_rps".to_string(), Json::Num(self.throughput));
        m.insert("p50_us".to_string(), Json::Num(self.p50.as_secs_f64() * 1e6));
        m.insert("p95_us".to_string(), Json::Num(self.p95.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), Json::Num(self.p99.as_secs_f64() * 1e6));
        m.insert(
            "net_flushes".to_string(),
            Json::Num(self.net_flushes as f64),
        );
        m.insert(
            "net_coalesced".to_string(),
            Json::Num(self.net_coalesced as f64),
        );
        m.insert(
            "mean_coalesced".to_string(),
            Json::Num(self.mean_coalesced),
        );
        Json::Obj(m)
    }
}

/// Submit one pipelined group with the standard `Busy` retry policy
/// ([`BUSY_BACKOFF`] between attempts, optional overall deadline),
/// returning the predictions and how many attempts were shed with
/// `Busy`. Shared by the socket load generator and the `pds client`
/// CLI so the two cannot drift apart on retry behavior.
pub fn classify_group_with_retry(
    net: &mut NetClient,
    model: &str,
    context: u32,
    group: &[Vec<f32>],
    deadline: Option<Instant>,
) -> Result<(Vec<crate::net::NetPrediction>, u64)> {
    let mut busy_retries = 0u64;
    loop {
        match net.classify_pipelined_ctx(model, context, group) {
            Ok(preds) => return Ok((preds, busy_retries)),
            Err(NetClientError::Busy) => {
                busy_retries += 1;
                if let Some(d) = deadline {
                    anyhow::ensure!(
                        Instant::now() < d,
                        "server still busy after {busy_retries} retries — giving up"
                    );
                }
                std::thread::sleep(BUSY_BACKOFF);
            }
            Err(e) => anyhow::bail!("socket classify failed: {e}"),
        }
    }
}

/// Drive `spec` against every model in `models` through the TCP
/// front-end at `addr`, one real connection per client, pipelined
/// groups of [`SocketLoadSpec::pipeline`] samples (clamped per model to
/// its engine batch size — a larger group cannot coalesce further and
/// could livelock the whole-group `Busy` retry against the server's
/// batcher queue cap). `Busy` sheds are retried after a short backoff
/// and counted; because a retry resubmits the whole group, the
/// server-side coalescing counters include any retried work, while the
/// report's `served` counts each sample once. Counters are read back
/// over the wire with a `MetricsRequest` at the end, so this works
/// against any server, not just an in-process one — but like
/// [`run_load`] it expects a freshly started server (cumulative
/// counters would fold earlier traffic in).
pub fn run_socket_load(
    addr: SocketAddr,
    models: &[String],
    spec: &SocketLoadSpec,
    seed: u64,
) -> Result<Vec<SocketLoadReport>> {
    anyhow::ensure!(
        spec.clients > 0 && spec.requests > 0 && spec.pipeline > 0,
        "empty socket load spec"
    );
    // resolve every model's shape once, up front
    let mut probe = NetClient::connect(addr)?;
    let health = probe.health().map_err(|e| anyhow::anyhow!("health: {e}"))?;
    drop(probe);
    // per model: feature dim, class count, and the pipelined group size
    // actually driven — the requested pipeline clamped to the engine
    // batch (a larger group cannot coalesce further and, since a Busy
    // shed retries the *whole* group, could livelock against the
    // server's batcher queue cap). Computed once here; the client
    // threads and the report both read this value.
    let mut dims: BTreeMap<&str, (usize, usize, usize, usize)> = BTreeMap::new();
    for m in models {
        let info = health
            .models
            .iter()
            .find(|i| &i.name == m)
            .ok_or_else(|| anyhow::anyhow!("model '{m}' not served at {addr}"))?;
        dims.insert(
            m.as_str(),
            (
                info.features as usize,
                info.classes as usize,
                spec.pipeline.min(info.batch as usize).max(1),
                // tenant contexts to round-robin the groups across,
                // clamped to what the server actually hosts
                spec.contexts.clamp(1, (info.contexts as usize).max(1)),
            ),
        );
    }
    let hists: BTreeMap<&str, LatencyHistogram> =
        models.iter().map(|m| (m.as_str(), LatencyHistogram::new())).collect();
    let served: BTreeMap<&str, AtomicU64> =
        models.iter().map(|m| (m.as_str(), AtomicU64::new(0))).collect();
    let busy: BTreeMap<&str, AtomicU64> =
        models.iter().map(|m| (m.as_str(), AtomicU64::new(0))).collect();
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (mi, model) in models.iter().enumerate() {
            let (features, classes, pipeline, ctxs) = dims[model.as_str()];
            for c in 0..spec.clients {
                let hist = &hists[model.as_str()];
                let served = &served[model.as_str()];
                let busy = &busy[model.as_str()];
                handles.push(s.spawn(move || -> Result<()> {
                    let mut net = NetClient::connect(addr)?;
                    let mut rng = Rng::new(seed ^ ((mi as u64) << 32) ^ c as u64);
                    let mut remaining = spec.requests;
                    let mut group_no = 0usize;
                    while remaining > 0 {
                        let k = pipeline.min(remaining);
                        // each pipelined group targets one tenant bank;
                        // successive groups rotate through the contexts
                        let ctx = ((c + group_no) % ctxs) as u32;
                        group_no += 1;
                        let group: Vec<Vec<f32>> = (0..k)
                            .map(|_| (0..features).map(|_| rng.normal()).collect())
                            .collect();
                        let t = Instant::now();
                        let (preds, retries) =
                            classify_group_with_retry(&mut net, model, ctx, &group, None)?;
                        for p in &preds {
                            anyhow::ensure!(
                                p.class < classes,
                                "class {} out of range for {model}",
                                p.class
                            );
                        }
                        let rt = t.elapsed();
                        for _ in 0..k {
                            hist.record(rt);
                        }
                        served.fetch_add(k as u64, Ordering::Relaxed);
                        busy.fetch_add(retries, Ordering::Relaxed);
                        remaining -= k;
                    }
                    Ok(())
                }));
            }
        }
        for h in handles {
            h.join()
                .map_err(|_| anyhow::anyhow!("socket load client panicked"))??;
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    // read the server-side coalescing counters back over the wire
    let mut probe = NetClient::connect(addr)?;
    models
        .iter()
        .map(|m| {
            let snap = probe
                .metrics(m)
                .map_err(|e| anyhow::anyhow!("metrics for '{m}': {e}"))?;
            let hist = &hists[m.as_str()];
            let served = served[m.as_str()].load(Ordering::Relaxed);
            Ok(SocketLoadReport {
                model: m.clone(),
                clients: spec.clients,
                // the group size actually driven (clamped once, in dims)
                pipeline: dims[m.as_str()].2,
                contexts: dims[m.as_str()].3,
                served,
                busy_retries: busy[m.as_str()].load(Ordering::Relaxed),
                wall,
                throughput: served as f64 / wall.as_secs_f64().max(1e-9),
                p50: hist.quantile(0.50),
                p95: hist.quantile(0.95),
                p99: hist.quantile(0.99),
                net_flushes: snap.net_flushes,
                net_coalesced: snap.net_coalesced,
                mean_coalesced: snap.mean_coalesced(),
            })
        })
        .collect()
}

/// Assemble the `net` section of `BENCH_serve.json` from socket-load
/// scenarios (merged over the existing file with [`write_bench_json`],
/// so the `serve_load` and `quant_exec` sections survive). The
/// top-level `mean_coalesced_batch` is the flush-weighted mean over
/// every scenario — the headline number for "socket traffic reaches the
/// engine as batches".
pub fn net_bench_json(
    scenarios: &[(SocketLoadSpec, Vec<SocketLoadReport>)],
    batch_window: Duration,
    soak: Option<&SoakReport>,
) -> Json {
    let mut net = BTreeMap::new();
    net.insert("recorded".to_string(), Json::Bool(true));
    net.insert(
        "kernel_threads_total".to_string(),
        Json::Num(parallel::machine_threads() as f64),
    );
    net.insert(
        "batch_window_us".to_string(),
        Json::Num(batch_window.as_secs_f64() * 1e6),
    );
    let mut arr = Vec::new();
    let (mut flushes, mut coalesced) = (0u64, 0u64);
    for (spec, reports) in scenarios {
        let total: f64 = reports.iter().map(|r| r.throughput).sum();
        let (f, c) = reports.iter().fold((0u64, 0u64), |(f, c), r| {
            (f + r.net_flushes, c + r.net_coalesced)
        });
        flushes += f;
        coalesced += c;
        let mut obj = BTreeMap::new();
        obj.insert("clients".to_string(), Json::Num(spec.clients as f64));
        obj.insert("pipeline".to_string(), Json::Num(spec.pipeline as f64));
        obj.insert("contexts".to_string(), Json::Num(spec.contexts.max(1) as f64));
        obj.insert("total_throughput_rps".to_string(), Json::Num(total));
        obj.insert(
            "mean_coalesced_batch".to_string(),
            if f == 0 {
                Json::Null
            } else {
                Json::Num(c as f64 / f as f64)
            },
        );
        obj.insert(
            "models".to_string(),
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        );
        arr.push(Json::Obj(obj));
    }
    net.insert("scenarios".to_string(), Json::Arr(arr));
    net.insert(
        "mean_coalesced_batch".to_string(),
        if flushes == 0 {
            Json::Null
        } else {
            Json::Num(coalesced as f64 / flushes as f64)
        },
    );
    if let Some(s) = soak {
        net.insert("soak".to_string(), s.to_json());
    }
    let mut root = BTreeMap::new();
    root.insert("net".to_string(), Json::Obj(net));
    Json::Obj(root)
}

/// Shape of the mostly-idle connection soak: `connections` open TCP
/// connections multiplexed by the server's single reactor thread, a
/// small sweeper pool driving a heavy-tailed request mix over them —
/// per connection per round: ~90% idle, ~9% one sample, ~0.9% a
/// pipelined group, ~0.1% a long pipelined group (both clamped to the
/// model's engine batch). The point is the reactor's scale-out claim:
/// idle connections must cost nothing, tail latency must stay bounded,
/// and anything the server sheds at its cap must be visible in the
/// report rather than hanging the run.
#[derive(Clone, Copy, Debug)]
pub struct SoakSpec {
    /// Open TCP connections held for the whole run.
    pub connections: usize,
    /// Sweeps over the connection pool; each sweep rolls the request
    /// mix once per live connection.
    pub rounds: usize,
    /// Sweeper threads the pool is partitioned across (the *server*
    /// side stays one reactor thread regardless).
    pub threads: usize,
    /// Samples in the rare long-tail group, pre-clamp.
    pub tail_pipeline: usize,
}

impl Default for SoakSpec {
    fn default() -> Self {
        SoakSpec {
            connections: 1000,
            rounds: 8,
            threads: 8,
            tail_pipeline: 16,
        }
    }
}

/// What one model sustained under a [`SoakSpec`], including the
/// server-side shed/accept-error counters read back over the wire
/// (protocol v3 carries them in every metrics frame).
#[derive(Clone, Debug)]
pub struct SoakReport {
    /// Model (manifest config) name.
    pub model: String,
    /// Connections the soak attempted to hold open.
    pub connections: usize,
    /// Samples served (responses received by the sweepers).
    pub served: u64,
    /// Pipelined groups retried after a per-request `Busy` shed.
    pub busy_retries: u64,
    /// Connections dropped mid-run by the sweepers (connection-level
    /// errors, e.g. a cap shed's `Busy` frame or a dead socket).
    pub dropped_connections: u64,
    /// Server-side count of connections shed at the cap
    /// (`net_shed_connections` over the wire).
    pub shed_connections: u64,
    /// Server-side transient `accept()` failures (`net_accept_errors`
    /// over the wire).
    pub accept_errors: u64,
    /// Wall-clock time of the whole soak.
    pub wall: Duration,
    /// Sustained samples per second (served / wall).
    pub throughput: f64,
    /// Median client-observed group round-trip.
    pub p50: Duration,
    /// 99th-percentile group round-trip — the tail the reactor's
    /// fairness budget is judged by.
    pub p99: Duration,
    /// 99.9th-percentile group round-trip.
    pub p999: Duration,
    /// `shed_connections / connections` — fraction of the offered
    /// population the server refused at its cap.
    pub shed_rate: f64,
}

impl SoakReport {
    /// One-line human-readable summary.
    pub fn print(&self) {
        println!(
            "{:<12} soak {:>5} conns: {:>8.0} samp/s | group p50 {:>9.2?} p99 {:>9.2?} \
             p999 {:>9.2?} | shed rate {:.4} ({} shed, {} dropped, {} accept errors), \
             {} busy retries",
            self.model,
            self.connections,
            self.throughput,
            self.p50,
            self.p99,
            self.p999,
            self.shed_rate,
            self.shed_connections,
            self.dropped_connections,
            self.accept_errors,
            self.busy_retries,
        );
    }

    /// JSON object for the `net.soak` subsection of `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("connections".to_string(), Json::Num(self.connections as f64));
        m.insert("served".to_string(), Json::Num(self.served as f64));
        m.insert(
            "busy_retries".to_string(),
            Json::Num(self.busy_retries as f64),
        );
        m.insert(
            "dropped_connections".to_string(),
            Json::Num(self.dropped_connections as f64),
        );
        m.insert(
            "shed_connections".to_string(),
            Json::Num(self.shed_connections as f64),
        );
        m.insert(
            "accept_errors".to_string(),
            Json::Num(self.accept_errors as f64),
        );
        m.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        m.insert("throughput_rps".to_string(), Json::Num(self.throughput));
        m.insert("p50_us".to_string(), Json::Num(self.p50.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), Json::Num(self.p99.as_secs_f64() * 1e6));
        m.insert(
            "p999_us".to_string(),
            Json::Num(self.p999.as_secs_f64() * 1e6),
        );
        m.insert("shed_rate".to_string(), Json::Num(self.shed_rate));
        Json::Obj(m)
    }
}

/// Drive a [`SoakSpec`] against `model` through the TCP front-end at
/// `addr`. Opens every connection up front (a server at its cap sheds
/// the excess with a `Busy` frame on first use — those connections are
/// dropped from the pool and counted, never retried), then runs the
/// heavy-tailed mix for `rounds` sweeps. Latencies are recorded per
/// sample from group round-trip time, like [`run_socket_load`].
/// Expects a freshly started server (the shed/accept counters read
/// back at the end are cumulative).
pub fn run_soak_load(
    addr: SocketAddr,
    model: &str,
    spec: &SoakSpec,
    seed: u64,
) -> Result<SoakReport> {
    anyhow::ensure!(
        spec.connections > 0 && spec.rounds > 0 && spec.threads > 0,
        "empty soak spec"
    );
    let mut probe = NetClient::connect(addr)?;
    let health = probe.health().map_err(|e| anyhow::anyhow!("health: {e}"))?;
    let info = health
        .models
        .iter()
        .find(|i| i.name == model)
        .ok_or_else(|| anyhow::anyhow!("model '{model}' not served at {addr}"))?;
    let features = info.features as usize;
    let classes = info.classes as usize;
    let batch = (info.batch as usize).max(1);
    let mid_group = 4.min(batch);
    let tail_group = spec.tail_pipeline.clamp(1, batch);
    // open the whole population up front; the pool is partitioned into
    // contiguous per-thread chunks so no connection is ever shared
    let mut pool: Vec<Option<NetClient>> = Vec::with_capacity(spec.connections);
    for _ in 0..spec.connections {
        pool.push(Some(NetClient::connect(addr)?));
    }
    let hist = LatencyHistogram::new();
    let served = AtomicU64::new(0);
    let busy = AtomicU64::new(0);
    let dropped = AtomicU64::new(0);
    let threads = spec.threads.min(spec.connections).max(1);
    let chunk = spec.connections.div_ceil(threads);
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (ti, slice) in pool.chunks_mut(chunk).enumerate() {
            let (hist, served, busy, dropped) = (&hist, &served, &busy, &dropped);
            handles.push(s.spawn(move || -> Result<()> {
                let mut rng = Rng::new(seed ^ ((ti as u64) << 24));
                for _ in 0..spec.rounds {
                    for slot in slice.iter_mut() {
                        let Some(net) = slot.as_mut() else { continue };
                        // heavy-tailed mix: mostly idle, rarely a burst
                        let k = match rng.below(1000) {
                            0..=899 => continue,
                            900..=989 => 1,
                            990..=998 => mid_group,
                            _ => tail_group,
                        };
                        let group: Vec<Vec<f32>> = (0..k)
                            .map(|_| (0..features).map(|_| rng.normal()).collect())
                            .collect();
                        let deadline = Instant::now() + Duration::from_secs(30);
                        let t = Instant::now();
                        match classify_group_with_retry(net, model, 0, &group, Some(deadline))
                        {
                            Ok((preds, retries)) => {
                                for p in &preds {
                                    anyhow::ensure!(
                                        p.class < classes,
                                        "class {} out of range for {model}",
                                        p.class
                                    );
                                }
                                let rt = t.elapsed();
                                for _ in 0..k {
                                    hist.record(rt);
                                }
                                served.fetch_add(k as u64, Ordering::Relaxed);
                                busy.fetch_add(retries, Ordering::Relaxed);
                            }
                            Err(_) => {
                                // connection-level failure (cap shed's
                                // Busy frame, dead socket): drop this
                                // connection from the pool, keep soaking
                                *slot = None;
                                dropped.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join()
                .map_err(|_| anyhow::anyhow!("soak sweeper panicked"))??;
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    drop(pool);
    let snap = probe
        .metrics(model)
        .map_err(|e| anyhow::anyhow!("metrics for '{model}': {e}"))?;
    let served = served.load(Ordering::Relaxed);
    Ok(SoakReport {
        model: model.to_string(),
        connections: spec.connections,
        served,
        busy_retries: busy.load(Ordering::Relaxed),
        dropped_connections: dropped.load(Ordering::Relaxed),
        shed_connections: snap.net_shed_connections,
        accept_errors: snap.net_accept_errors,
        wall,
        throughput: served as f64 / wall.as_secs_f64().max(1e-9),
        p50: hist.quantile(0.50),
        p99: hist.quantile(0.99),
        p999: hist.quantile(0.999),
        shed_rate: snap.net_shed_connections as f64 / spec.connections as f64,
    })
}
