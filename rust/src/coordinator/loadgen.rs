//! Closed-loop load generation for the [`InferenceService`], shared by
//! the `pds serve` / `pds serve-bench` CLI commands, the `serve_load`
//! bench target, and the service integration tests.
//!
//! A *closed-loop* client submits one request, waits for the reply, then
//! submits the next — so total in-flight load equals the client count
//! and a saturated service slows the clients down instead of building an
//! unbounded backlog. [`ServeError::Busy`] rejections are retried after
//! a short backoff and counted via the model's
//! [`crate::coordinator::ModelMetrics::rejected`] counter. The arrival
//! pattern is shaped by [`LoadSpec::burst`] / [`LoadSpec::think_time`]:
//! bursty arrivals stress the shard router and the dynamic batcher's
//! partial-flush path.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::server::{InferenceService, ModelMetrics, ModelSpec, ServeError, ServerConfig};
use crate::runtime::Manifest;
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::sparsity::{generate, Method};
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Backoff between retries of a [`ServeError::Busy`] rejection.
const BUSY_BACKOFF: Duration = Duration::from_micros(200);

/// Shape of the offered load, per model.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    /// Concurrent closed-loop client threads per model.
    pub clients: usize,
    /// Requests each client submits.
    pub requests: usize,
    /// Pause a client inserts after every `burst` responses (zero =
    /// submit back-to-back; the classic closed loop).
    pub think_time: Duration,
    /// Responses between pauses; 1 with a nonzero `think_time` is a
    /// uniform paced arrival, larger values are bursty arrivals.
    pub burst: usize,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            clients: 8,
            requests: 100,
            think_time: Duration::ZERO,
            burst: 1,
        }
    }
}

/// What one model sustained under a [`LoadSpec`].
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Model (manifest config) name.
    pub model: String,
    /// Workers per model the service ran with.
    pub workers: usize,
    /// Closed-loop clients that drove this model.
    pub clients: usize,
    /// Requests served.
    pub served: u64,
    /// Submit attempts rejected with [`ServeError::Busy`] (each was
    /// retried by the load generator).
    pub rejected: u64,
    /// Wall-clock time of the whole load run.
    pub wall: Duration,
    /// Sustained requests per second (served / wall).
    pub throughput: f64,
    /// Median request latency (submit to reply).
    pub p50: Duration,
    /// 95th-percentile latency.
    pub p95: Duration,
    /// 99th-percentile latency.
    pub p99: Duration,
    /// Batches executed.
    pub batches: u64,
    /// Mean live rows per batch.
    pub mean_occupancy: f64,
    /// Requests served by a worker that stole them from a sibling shard.
    pub stolen: u64,
}

impl LoadReport {
    /// One-line human-readable summary.
    pub fn print(&self) {
        println!(
            "{:<12} workers {:>2}, clients {:>2}: {:>8.0} req/s | p50 {:>9.2?} p95 {:>9.2?} \
             p99 {:>9.2?} | occupancy {:>5.1} | {} batches, {} rejected, {} stolen",
            self.model,
            self.workers,
            self.clients,
            self.throughput,
            self.p50,
            self.p95,
            self.p99,
            self.mean_occupancy,
            self.batches,
            self.rejected,
            self.stolen,
        );
    }

    /// JSON object for `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("served".to_string(), Json::Num(self.served as f64));
        m.insert("rejected".to_string(), Json::Num(self.rejected as f64));
        m.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        m.insert("throughput_rps".to_string(), Json::Num(self.throughput));
        m.insert("p50_us".to_string(), Json::Num(self.p50.as_secs_f64() * 1e6));
        m.insert("p95_us".to_string(), Json::Num(self.p95.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), Json::Num(self.p99.as_secs_f64() * 1e6));
        m.insert("batches".to_string(), Json::Num(self.batches as f64));
        m.insert(
            "mean_occupancy".to_string(),
            Json::Num(self.mean_occupancy),
        );
        m.insert("stolen".to_string(), Json::Num(self.stolen as f64));
        Json::Obj(m)
    }
}

/// Build a [`ModelSpec`] for `config` with a clash-free pattern at
/// roughly `density` (snapped to the admissible degree set), the shape
/// every serve surface (CLI, bench, example, tests) uses.
pub fn model_spec(
    artifacts_dir: impl AsRef<Path>,
    config: &str,
    density: f64,
    seed: u64,
) -> Result<ModelSpec> {
    let probe = Manifest::probe(artifacts_dir, config)?;
    let netc = NetConfig::new(probe.layers.clone());
    let dout = DoutConfig(
        (0..netc.n_junctions())
            .map(|i| netc.junction(i).dout_for_density(density))
            .collect(),
    );
    let mut rng = Rng::new(seed);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    Ok(ModelSpec::new(config, pattern))
}

/// Drive `spec` against every model in `models` concurrently and return
/// one report per model. Counters are read from the service's metrics,
/// so this expects a freshly started service (cumulative counters would
/// fold earlier traffic into the report).
pub fn run_load(
    svc: &InferenceService,
    models: &[String],
    spec: &LoadSpec,
    seed: u64,
) -> Result<Vec<LoadReport>> {
    anyhow::ensure!(spec.clients > 0 && spec.requests > 0, "empty load spec");
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (mi, model) in models.iter().enumerate() {
            let client = svc.client(model)?;
            for c in 0..spec.clients {
                let client = client.clone();
                handles.push(s.spawn(move || -> Result<()> {
                    let mut rng = Rng::new(seed ^ ((mi as u64) << 32) ^ c as u64);
                    let mut since_pause = 0usize;
                    for _ in 0..spec.requests {
                        let x: Vec<f32> =
                            (0..client.features()).map(|_| rng.normal()).collect();
                        loop {
                            match client.classify(x.clone()) {
                                Ok(p) => {
                                    anyhow::ensure!(
                                        p.class < client.classes(),
                                        "class {} out of range for {}",
                                        p.class,
                                        client.model()
                                    );
                                    break;
                                }
                                Err(ServeError::Busy) => std::thread::sleep(BUSY_BACKOFF),
                                Err(e) => anyhow::bail!("classify failed: {e}"),
                            }
                        }
                        since_pause += 1;
                        if !spec.think_time.is_zero() && since_pause >= spec.burst.max(1) {
                            std::thread::sleep(spec.think_time);
                            since_pause = 0;
                        }
                    }
                    Ok(())
                }));
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("load client panicked"))??;
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    let workers = svc.config().workers.max(1);
    models
        .iter()
        .map(|m| {
            let met = svc
                .metrics(m)
                .ok_or_else(|| anyhow::anyhow!("no metrics for '{m}'"))?;
            Ok(snapshot(m, workers, spec.clients, met, wall))
        })
        .collect()
}

fn snapshot(
    model: &str,
    workers: usize,
    clients: usize,
    met: &ModelMetrics,
    wall: Duration,
) -> LoadReport {
    let served = met.requests.load(Ordering::Relaxed);
    LoadReport {
        model: model.to_string(),
        workers,
        clients,
        served,
        rejected: met.rejected.load(Ordering::Relaxed),
        wall,
        throughput: served as f64 / wall.as_secs_f64().max(1e-9),
        p50: met.latency.quantile(0.50),
        p95: met.latency.quantile(0.95),
        p99: met.latency.quantile(0.99),
        batches: met.batches.load(Ordering::Relaxed),
        mean_occupancy: met.mean_occupancy(),
        stolen: met.stolen.load(Ordering::Relaxed),
    }
}

/// Start a fresh service for `models` with `workers` workers per model,
/// drive `load` against every model concurrently, shut down, and return
/// the per-model reports. The unit of comparison for the serve bench:
/// same load, varying worker count — and, with `quant` set, f32 vs
/// fixed-point execution of the same models under the same load
/// (`quant_exec` bench, `serve-bench --quant`).
pub fn bench_service(
    artifacts_dir: impl AsRef<Path>,
    models: &[String],
    workers: usize,
    queue_depth: usize,
    max_wait: Duration,
    load: &LoadSpec,
    seed: u64,
    quant: Option<crate::nn::fixed::QFormat>,
) -> Result<Vec<LoadReport>> {
    let dir = artifacts_dir.as_ref();
    let specs = models
        .iter()
        .map(|m| {
            model_spec(dir, m, 0.25, seed).map(|s| ModelSpec { quant, ..s })
        })
        .collect::<Result<Vec<_>>>()?;
    let svc = InferenceService::start(
        dir,
        specs,
        ServerConfig {
            max_wait,
            workers,
            queue_depth,
            tune_kernel_threads: true,
        },
    )?;
    let reports = run_load(&svc, models, load, seed ^ 0x5EED)?;
    svc.shutdown()?;
    Ok(reports)
}

/// Assemble the `BENCH_serve.json` document from `(workers, reports)`
/// scenarios; includes the sustained-throughput speedup of the largest
/// worker count over the single-worker baseline when both are present.
pub fn bench_json(scenarios: &[(usize, Vec<LoadReport>)]) -> Json {
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serve_load".to_string()));
    root.insert("recorded".to_string(), Json::Bool(true));
    root.insert(
        "kernel_threads_total".to_string(),
        Json::Num(parallel::machine_threads() as f64),
    );
    let mut arr = Vec::new();
    let mut base: Option<f64> = None;
    let mut best: Option<(usize, f64)> = None;
    for (workers, reports) in scenarios {
        let total: f64 = reports.iter().map(|r| r.throughput).sum();
        if *workers == 1 {
            base = Some(total);
        }
        let replace = match best {
            Some((w, _)) => *workers > w,
            None => true,
        };
        if replace {
            best = Some((*workers, total));
        }
        let mut obj = BTreeMap::new();
        obj.insert("workers".to_string(), Json::Num(*workers as f64));
        obj.insert("total_throughput_rps".to_string(), Json::Num(total));
        obj.insert(
            "models".to_string(),
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        );
        arr.push(Json::Obj(obj));
    }
    root.insert("scenarios".to_string(), Json::Arr(arr));
    // always emit the speedup keys — Null when the sweep had no
    // single-worker baseline or no multi-worker scenario — so a
    // key-wise merge over an older file can never leave stale values
    let (sw, sv) = match (base, best) {
        (Some(b), Some((w, t))) if w > 1 && b > 0.0 => {
            (Json::Num(w as f64), Json::Num(t / b))
        }
        _ => (Json::Null, Json::Null),
    };
    root.insert("speedup_workers".to_string(), sw);
    root.insert("speedup_vs_single_worker".to_string(), sv);
    Json::Obj(root)
}

/// Write a serve-bench document to `path`, merging over whatever the
/// file already holds so unrelated top-level sections survive — the
/// `serve_load` and `quant_exec` benches both record into
/// `BENCH_serve.json`, each owning different keys. When `doc` refreshes
/// the main scenario section (it carries a `recorded` flag), the
/// placeholder `note` is dropped. A missing file is written fresh; an
/// *unparsable* existing file is an error, never silently replaced —
/// losing the sibling bench's recorded section would be worse than
/// failing.
pub fn write_bench_json(path: impl AsRef<Path>, doc: Json) -> std::io::Result<()> {
    let path = path.as_ref();
    let merged = match std::fs::read_to_string(path) {
        Ok(text) => match (Json::parse(&text), doc) {
            (Ok(Json::Obj(mut base)), Json::Obj(new)) => {
                if new.contains_key("recorded") {
                    base.remove("note");
                }
                for (k, v) in new {
                    base.insert(k, v);
                }
                Json::Obj(base)
            }
            (Ok(_), _) | (Err(_), _) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "existing {} is not a JSON object — refusing to overwrite it \
                         (fix or delete the file, then rerun the bench)",
                        path.display()
                    ),
                ));
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => doc,
        Err(e) => return Err(e),
    };
    std::fs::write(path, format!("{merged}\n"))
}
