//! L3 coordinator: the training orchestrator and the inference service,
//! both running over the backend-agnostic `runtime::Engine` (the parallel
//! native backend by default, AOT PJRT artifacts behind the `pjrt`
//! feature; no Python on any path here).
//!
//! The paper's system contribution is the sparsity-aware accelerator, so
//! L3 is the surrounding machine: session/state management for training
//! (parameters, Adam state and masks live host-side between steps), and a
//! batched inference server whose dynamic batcher feeds the fixed-batch
//! compiled executable — the software analogue of feeding the junction
//! pipeline one input per junction cycle.

pub mod server;
pub mod trainer;

pub use server::{InferenceServer, ServerConfig, ServerStats};
pub use trainer::{TrainSession, TrainStepOut};
