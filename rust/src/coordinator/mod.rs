//! L3 coordinator: the training orchestrator and the inference service,
//! both running over the backend-agnostic [`crate::runtime::Engine`]
//! (the parallel native backend by default, AOT PJRT artifacts behind
//! the `pjrt` feature; no Python on any path here).
//!
//! The paper's system contribution is the sparsity-aware accelerator, so
//! L3 is the surrounding machine:
//!
//! - [`trainer`] — session/state management for training: parameters,
//!   Adam state and masks live host-side between fused train steps
//!   ([`TrainSession`]), plus the streaming pipelined session
//!   ([`PipelinedTrainSession`]) that runs the paper's Sec. III-A
//!   FF/BP/UP interleave on the native backend.
//! - [`server`] — the multi-worker, multi-model sharded inference
//!   service: per-worker engines, depth-balanced bounded request shards
//!   with work stealing, dynamic batching into the fixed-batch compiled
//!   executable (the software analogue of feeding the junction pipeline
//!   one input per junction cycle), and per-model [`ModelMetrics`].
//! - [`loadgen`] — the closed-loop load generator behind `pds serve`,
//!   `pds serve-bench` and the `serve_load` bench target, plus its
//!   socket mode (`run_socket_load`: real TCP connections with
//!   pipelined groups through [`crate::net::NetServer`], backing the
//!   `net_load` bench).

pub mod loadgen;
pub mod server;
pub mod trainer;

pub use server::{
    context_params, Client, InferenceServer, InferenceService, LatencyHistogram, ModelMetrics,
    ModelSpec, PendingPrediction, Prediction, ServeError, ServerConfig,
};
pub use trainer::{PipelinedTrainSession, TrainSession, TrainStepOut};
