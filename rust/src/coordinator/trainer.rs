//! Training sessions: the fused sequential path and the streaming
//! pipelined path, both config-validated against the runtime manifest.
//!
//! [`TrainSession`] drives the runtime's fused `train` program: host
//! state (weights, biases, Adam moments, masks, step counter) is
//! initialized in Rust, fed to the loaded train-step positionally per
//! the manifest, and replaced by the returned updated tensors — the
//! classic leader/state-manager loop, with the whole fwd/bwd/update
//! fused into a single backend execution (batch-parallel on the native
//! backend). It works on every backend, PJRT included.
//!
//! [`PipelinedTrainSession`] instead streams minibatches through the
//! paper's Sec. III-A junction pipeline
//! ([`crate::nn::pipeline::PipelinedTrainer`] via
//! [`Engine::train_pipelined`]): junction i runs FF on batch `t` while
//! junction i-1 runs BP/UP on batch `t-1`, with bounded, measured weight
//! staleness. Native backend only — a fused artifact cannot be split
//! into per-junction stages.

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::nn::pipeline::{PipelineConfig, PipelineMetrics, PipelinedTrainer};
use crate::runtime::{Engine, Program, Value};
use crate::sparsity::pattern::NetPattern;
use crate::util::rng::Rng;

/// Per-step outputs.
#[derive(Clone, Copy, Debug)]
pub struct TrainStepOut {
    /// Mean cross-entropy loss of the minibatch.
    pub loss: f32,
    /// Correct argmax predictions in the minibatch.
    pub correct: usize,
}

/// Training state bound to one artifact config.
pub struct TrainSession {
    /// Neuronal configuration `[N_0, ..., N_L]` of the config.
    pub layers: Vec<usize>,
    /// Batch size the artifact was compiled/synthesized for.
    pub batch: usize,
    train_prog: Program,
    forward_prog: Program,
    /// Interleaved per junction: w, b (then Adam m/v in the same layout).
    params: Vec<Value>,
    opt_m: Vec<Value>,
    opt_v: Vec<Value>,
    masks: Vec<Value>,
    t: f32,
    /// Learning rate fed to the train step each call.
    pub lr: f32,
    /// L2 penalty coefficient fed to the train step each call.
    pub l2: f32,
}

impl TrainSession {
    /// He-initialize parameters and bind masks from a pattern (pass an
    /// all-ones pattern mask for FC training).
    pub fn new(
        engine: &Engine,
        config: &str,
        pattern: &NetPattern,
        lr: f32,
        l2: f32,
        seed: u64,
    ) -> Result<Self> {
        let entry = engine
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("no config {config}"))?;
        let layers = entry.layers.clone();
        let batch = entry.batch;
        if pattern.junctions.len() != layers.len() - 1 {
            bail!("pattern has {} junctions, net has {}", pattern.junctions.len(), layers.len() - 1);
        }
        for (i, p) in pattern.junctions.iter().enumerate() {
            if p.shape.n_left != layers[i] || p.shape.n_right != layers[i + 1] {
                bail!("pattern junction {i} shape mismatch");
            }
        }
        let train_prog = engine.load(config, "train")?;
        let forward_prog = engine.load(config, "forward")?;

        let mut rng = Rng::new(seed);
        let mut params = Vec::new();
        let mut opt_m = Vec::new();
        let mut opt_v = Vec::new();
        let mut masks = Vec::new();
        for i in 1..layers.len() {
            let (nl, nr) = (layers[i - 1], layers[i]);
            let std = (2.0 / nl as f32).sqrt();
            let mask = pattern.junctions[i - 1].mask();
            // He init, pre-masked so excluded edges start (and stay) zero
            let w: Vec<f32> = (0..nr * nl)
                .zip(&mask)
                .map(|(_, &m)| rng.normal() * std * m)
                .collect();
            params.push(Value::F32(w, vec![nr, nl]));
            params.push(Value::F32(vec![0.1; nr], vec![nr]));
            opt_m.push(Value::F32(vec![0.0; nr * nl], vec![nr, nl]));
            opt_m.push(Value::F32(vec![0.0; nr], vec![nr]));
            opt_v.push(Value::F32(vec![0.0; nr * nl], vec![nr, nl]));
            opt_v.push(Value::F32(vec![0.0; nr], vec![nr]));
            masks.push(Value::F32(mask, vec![nr, nl]));
        }
        Ok(TrainSession {
            layers,
            batch,
            train_prog,
            forward_prog,
            params,
            opt_m,
            opt_v,
            masks,
            t: 1.0,
            lr,
            l2,
        })
    }

    /// Number of fused train steps executed so far.
    pub fn step_count(&self) -> usize {
        (self.t - 1.0) as usize
    }

    /// One fused train step on a full minibatch (`x: [batch, N_0]`,
    /// `y: [batch]`).
    pub fn step(&mut self, x: &[f32], y: &[i32]) -> Result<TrainStepOut> {
        let n0 = self.layers[0];
        if x.len() != self.batch * n0 || y.len() != self.batch {
            bail!("batch shape mismatch: artifact is compiled for batch {}", self.batch);
        }
        let mut inputs: Vec<Value> = Vec::with_capacity(self.train_prog.spec.inputs.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_m.iter().cloned());
        inputs.extend(self.opt_v.iter().cloned());
        inputs.extend(self.masks.iter().cloned());
        inputs.push(Value::F32(x.to_vec(), vec![self.batch, n0]));
        inputs.push(Value::I32(y.to_vec(), vec![self.batch]));
        inputs.push(Value::scalar_f32(self.t));
        inputs.push(Value::scalar_f32(self.lr));
        inputs.push(Value::scalar_f32(self.l2));

        let mut out = self.train_prog.run(&inputs)?;
        // outputs: 2L params, 2L m, 2L v, t, loss, correct
        let l2n = self.params.len();
        let correct = out.pop().unwrap().scalar()? as usize;
        let loss = out.pop().unwrap().scalar()?;
        let t = out.pop().unwrap().scalar()?;
        let mut it = out.into_iter();
        self.params = it.by_ref().take(l2n).collect();
        self.opt_m = it.by_ref().take(l2n).collect();
        self.opt_v = it.by_ref().take(l2n).collect();
        self.t = t;
        Ok(TrainStepOut { loss, correct })
    }

    /// Run one epoch over a dataset (drops the final partial batch, like
    /// the fixed-batch hardware pipeline would).
    pub fn epoch(&mut self, ds: &Dataset, rng: &mut Rng) -> Result<(f32, f64)> {
        let mut order: Vec<usize> = (0..ds.n).collect();
        rng.shuffle(&mut order);
        let mut loss_sum = 0f64;
        let mut correct = 0usize;
        let mut batches = 0usize;
        for chunk in order.chunks(self.batch) {
            if chunk.len() < self.batch {
                break;
            }
            let (x, y) = ds.gather(chunk);
            let out = self.step(&x, &y)?;
            loss_sum += out.loss as f64;
            correct += out.correct;
            batches += 1;
        }
        if batches == 0 {
            bail!("dataset smaller than one batch");
        }
        Ok((
            (loss_sum / batches as f64) as f32,
            correct as f64 / (batches * self.batch) as f64,
        ))
    }

    /// Logits for one batch through the forward artifact.
    pub fn logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let n0 = self.layers[0];
        let mut inputs: Vec<Value> = Vec::with_capacity(self.forward_prog.spec.inputs.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.masks.iter().cloned());
        inputs.push(Value::F32(x.to_vec(), vec![self.batch, n0]));
        let out = self.forward_prog.run(&inputs)?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Test accuracy over a dataset (full batches only).
    pub fn evaluate(&self, ds: &Dataset) -> Result<f64> {
        let classes = *self.layers.last().unwrap();
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut i = 0;
        while i + self.batch <= ds.n {
            let idx: Vec<usize> = (i..i + self.batch).collect();
            let (x, y) = ds.gather(&idx);
            let logits = self.logits(&x)?;
            for (bi, &label) in y.iter().enumerate() {
                let row = &logits[bi * classes..(bi + 1) * classes];
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                if best == label as usize {
                    correct += 1;
                }
            }
            seen += self.batch;
            i += self.batch;
        }
        if seen == 0 {
            bail!("dataset smaller than one batch");
        }
        Ok(correct as f64 / seen as f64)
    }

    /// Copy of a parameter tensor (junction i weight when `bias=false`).
    pub fn param(&self, junction: usize, bias: bool) -> &Value {
        &self.params[2 * junction + bias as usize]
    }

    /// Verify the pre-defined sparsity contract: every excluded weight is
    /// exactly zero in the current parameters.
    pub fn check_mask_invariant(&self) -> Result<()> {
        for (i, mask) in self.masks.iter().enumerate() {
            let w = self.params[2 * i].as_f32()?;
            let m = mask.as_f32()?;
            for (idx, (wv, mv)) in w.iter().zip(m).enumerate() {
                if *mv == 0.0 && *wv != 0.0 {
                    bail!("junction {i} weight {idx} excluded but nonzero ({wv})");
                }
            }
        }
        Ok(())
    }
}

/// Streaming pipelined training session bound to one artifact config:
/// the Sec. III-A FF/BP/UP interleave over real minibatches, with the
/// dataset/epoch glue of [`TrainSession`]. Built by
/// [`PipelinedTrainSession::new`] over [`Engine::train_pipelined`]
/// (native backend only).
pub struct PipelinedTrainSession {
    /// Neuronal configuration `[N_0, ..., N_L]` of the config.
    pub layers: Vec<usize>,
    /// Minibatch size each pipeline input carries.
    pub batch: usize,
    trainer: PipelinedTrainer,
}

impl PipelinedTrainSession {
    /// Validate `pattern` against `config`'s layers and build the
    /// pipelined engine. `cfg.batch = 0` adopts the config's batch size
    /// (the native pipeline is not shape-compiled, so any batch works).
    pub fn new(
        engine: &Engine,
        config: &str,
        pattern: &NetPattern,
        cfg: &PipelineConfig,
    ) -> Result<Self> {
        let entry = engine
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("no config {config}"))?;
        let layers = entry.layers.clone();
        let mut cfg = cfg.clone();
        if cfg.batch == 0 {
            cfg.batch = entry.batch;
        }
        let batch = cfg.batch;
        let trainer = engine.train_pipelined(config, pattern, &cfg)?;
        Ok(PipelinedTrainSession {
            layers,
            batch,
            trainer,
        })
    }

    /// One epoch over `ds` (shuffled with `rng`); returns (mean train
    /// loss, train accuracy).
    pub fn epoch(&mut self, ds: &Dataset, rng: &mut Rng) -> Result<(f32, f64)> {
        self.trainer.epoch(ds, rng)
    }

    /// Chunked test accuracy over a dataset.
    pub fn evaluate(&self, ds: &Dataset) -> f64 {
        self.trainer.evaluate(ds)
    }

    /// The underlying pipelined engine (staleness probes, banked z_net,
    /// schedule metrics).
    pub fn trainer(&self) -> &PipelinedTrainer {
        &self.trainer
    }

    /// Execution counters of the runs so far.
    pub fn metrics(&self) -> &PipelineMetrics {
        &self.trainer.metrics
    }
}
