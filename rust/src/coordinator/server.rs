//! Multi-worker, multi-model sharded inference service.
//!
//! The paper's hardware gets its throughput from running many junction
//! pipelines concurrently on a fixed clock (Sec. III); this module is the
//! software analogue of that scale-out. One [`InferenceService`] hosts any
//! number of *models* (manifest configs, each with its own pre-defined
//! sparse pattern and parameters), and each model is served by a pool of
//! worker threads:
//!
//! - **Per-worker engines.** Every worker owns its own
//!   [`crate::runtime::Engine`] and loaded `forward` executable. PJRT
//!   handles are thread-affine (the `xla` crate wraps raw pointers that
//!   must not cross threads), so per-worker engines are the *required*
//!   design, not an optimization. Construction stays cheap because the
//!   manifest is parsed once and shared ([`crate::runtime::Engine::for_worker`]).
//! - **Sharded queues + work stealing.** Each worker owns one bounded
//!   request shard. The router enqueues onto the shallowest shard
//!   (load balancing by queue depth) and a worker whose shard runs dry
//!   steals from the deepest sibling, so a hot shard never strands work
//!   behind an idle worker.
//! - **Backpressure, not unbounded growth.** Shards are bounded by
//!   [`ServerConfig::queue_depth`]; when every shard of a model is full,
//!   [`Client::classify`] fails fast with [`ServeError::Busy`] instead of
//!   queueing without limit. The caller decides whether to retry, shed,
//!   or slow down.
//! - **Dynamic batching.** A worker collects up to the config's compiled
//!   batch size or until [`ServerConfig::max_wait`] elapses, pads the
//!   tail with zero rows, executes once, and fans the argmax results
//!   back out — one fixed junction-cycle cost per flush, exactly like
//!   the hardware pipeline's rhythm.
//! - **Metrics.** Each model owns a lock-free [`ModelMetrics`] struct:
//!   request/reject/batch counters, a batch-occupancy histogram, and a
//!   log₂-bucketed latency histogram with p50/p95/p99 quantiles. The
//!   service exports every model through its
//!   [`crate::obs::registry::Registry`] (one collector per model,
//!   registered at startup holding a `Weak` core handle);
//!   [`InferenceService::registry`]`.snapshot()` is the one coherent
//!   view the CLI dump, the wire Metrics frame and the load generators
//!   all read.
//! - **Tracing.** A sampled request carries a boxed
//!   [`crate::obs::trace::ReqTrace`] through the shard queue
//!   ([`Client::submit_ctx_traced`]); the worker stamps the batch's
//!   execution window, closes the trace and attaches the
//!   [`TraceEcho`] to the [`Prediction`]. Unsampled requests carry
//!   `None` — no allocation, no timestamps beyond the ones serving
//!   already takes.
//! - **Quantized serving.** A model with [`ModelSpec::quant`] set is
//!   served in Qm.n fixed point ([`crate::nn::fixed`]): parameters are
//!   compacted and quantized once at startup, every worker runs the
//!   saturating integer kernels on raw words (argmax included — no
//!   dequantization on the reply path), and saturation events surface in
//!   [`ModelMetrics::quant_saturations`]. CLI: `serve --quant Qm.n`.
//! - **Multi-tenant contexts.** A model may host `C` tenant contexts
//!   over one shared pattern ([`ModelSpec::contexts`]): context 0
//!   serves the base parameters (the spec's, or the default He draw)
//!   and every further context an independently drawn per-tenant
//!   variant — all resident at once, the software analogue of the
//!   [`crate::hw::context`] bank RAM. Requests route by
//!   `(model, context)` ([`Client::classify_ctx`]); at each flush a
//!   worker groups the collected rows by owning context and executes
//!   each group against that context's *fetched* parameter bank, so
//!   tenants interleave through one worker pool with no model swapping
//!   (CLI: `serve --contexts C`).
//!
//! Implemented on std threads + channels (tokio is unavailable in the
//! offline build; the request path is compute-bound, not I/O-bound).

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::nn::actsparse::{ActMode, ActSpec, ActStats};
use crate::nn::fixed::{FixedSparseNet, QFormat};
use crate::nn::sparse::SparseNet;
use crate::obs::registry::{Registry, Sample};
use crate::obs::trace::{ReqTrace, TraceEcho};
use crate::runtime::{Engine, Manifest, Program, Value};
use crate::sparsity::pattern::NetPattern;
use crate::util::parallel;
use crate::util::rng::Rng;

// the histogram moved to the observability layer (obs::registry) so the
// net load generators and the registry share one bucketing; re-exported
// here because it grew up as part of this module's public API
pub use crate::obs::registry::LatencyHistogram;

/// How long an idle worker parks on its shard's condvar before re-polling
/// sibling shards (steals are not signalled on the thief's condvar).
const IDLE_POLL: Duration = Duration::from_millis(5);
/// Cap on the batch-fill wait, for the same reason.
const STEAL_POLL: Duration = Duration::from_millis(1);

/// Service tuning knobs (see the module docs for the architecture).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// How long a worker holds a partial batch open before flushing it.
    ///
    /// This is *the* latency/throughput trade-off of dynamic batching:
    /// the compiled executable always pays one full fixed-batch execution
    /// per flush, so a **larger** `max_wait` collects fuller batches —
    /// more requests amortize each execution (higher throughput, fewer
    /// padded rows) at the cost of up to `max_wait` of added queueing
    /// latency on every request. A **smaller** value flushes eagerly:
    /// lower p50 latency, but mostly-padded batches waste compute under
    /// light load. The default of 2 ms suits the built-in configs, whose
    /// batch execution takes a few hundred microseconds to a few
    /// milliseconds; exposed on the CLI as `--wait-ms`.
    pub max_wait: Duration,
    /// Worker threads per model. Each worker owns its own engine and one
    /// request shard (CLI: `--workers`).
    pub workers: usize,
    /// Bound of each shard's request queue. When every shard of a model
    /// is full, submission fails with [`ServeError::Busy`]
    /// (CLI: `--queue-depth`).
    pub queue_depth: usize,
    /// Divide the machine's kernel-thread budget evenly among the
    /// service's workers via [`parallel::worker_thread_budget`], so
    /// worker count × per-batch kernel threads does not oversubscribe
    /// the cores. The previous override is restored when the service
    /// drops (shutdown or any error path). Disable for tests that must
    /// not touch the global thread override (it is process-wide).
    pub tune_kernel_threads: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 1,
            queue_depth: 256,
            tune_kernel_threads: false,
        }
    }
}

/// Why a submission was not accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Every shard queue of the model is at capacity — explicit
    /// backpressure. Retry later, shed the request, or slow the caller.
    Busy,
    /// The service has shut down (or the model's workers died).
    Stopped,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Busy => write!(f, "service busy: all request shards full"),
            ServeError::Stopped => write!(f, "service stopped"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A classification response.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    /// Argmax class of the model's logits.
    pub class: usize,
    /// Time from submit to response (queueing + batch wait + execution).
    pub latency: Duration,
    /// How many live requests shared the batch that served this one.
    pub batch_occupancy: usize,
    /// Index of the worker (within the model's pool) that ran the batch.
    pub worker: usize,
    /// Tenant context whose parameter bank served this request.
    pub context: usize,
    /// Per-stage timing echo when the request was traced (sampled at the
    /// net front door or submitted via [`Client::submit_ctx_traced`]);
    /// `None` on the unsampled path.
    pub trace: Option<TraceEcho>,
}

struct Request {
    features: Vec<f32>,
    context: usize,
    submitted: Instant,
    reply: Sender<Prediction>,
    /// Sampled-tracing baton; `None` on the (overwhelmingly common)
    /// unsampled path, so the request stays allocation-free.
    trace: Option<Box<ReqTrace>>,
}

/// Per-model serving counters. All fields are lock-free atomics updated
/// by the router and the workers; read them at any time with
/// `Ordering::Relaxed`.
#[derive(Debug)]
pub struct ModelMetrics {
    /// Requests served (responses actually sent).
    pub requests: AtomicU64,
    /// Submit attempts rejected with [`ServeError::Busy`].
    pub rejected: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Zero rows padded into partial batches.
    pub padded_rows: AtomicU64,
    /// Requests a worker stole from a sibling shard.
    pub stolen: AtomicU64,
    /// Saturated fixed-point outputs across all quantized batches (zero
    /// on f32-served models). A persistently nonzero count means the
    /// model's Qm.n format lacks integer headroom for its inputs.
    pub quant_saturations: AtomicU64,
    /// Hidden-activation slots the activation mask kept live across all
    /// served batches (zero on models served without an [`ActSpec`]).
    pub act_active: AtomicU64,
    /// Hidden-activation slots considered by the activation mask.
    /// `act_active / act_total` is the achieved activation density.
    pub act_total: AtomicU64,
    /// Submit-to-reply latency histogram (see [`LatencyHistogram`]).
    pub latency: LatencyHistogram,
    occupancy: Vec<AtomicU64>,
}

impl ModelMetrics {
    fn new(batch: usize) -> Self {
        ModelMetrics {
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            quant_saturations: AtomicU64::new(0),
            act_active: AtomicU64::new(0),
            act_total: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
            occupancy: (0..batch).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Achieved activation density across everything this model served:
    /// live hidden-activation slots over slots considered. `1.0` when no
    /// activation mask ran (nothing was dropped).
    pub fn act_density(&self) -> f64 {
        ActStats {
            active: self.act_active.load(Ordering::Relaxed),
            total: self.act_total.load(Ordering::Relaxed),
        }
        .density()
    }

    fn record_act(&self, stats: ActStats) {
        if stats.total > 0 {
            self.act_active.fetch_add(stats.active, Ordering::Relaxed);
            self.act_total.fetch_add(stats.total, Ordering::Relaxed);
        }
    }

    /// Batch-occupancy histogram: entry `k` counts the batches that
    /// carried `k + 1` live requests, so `sum_k (k + 1) * hist[k]`
    /// equals [`ModelMetrics::requests`] and `sum_k hist[k]` equals
    /// [`ModelMetrics::batches`].
    pub fn occupancy_histogram(&self) -> Vec<u64> {
        self.occupancy.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Mean live rows per executed batch.
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Human-readable dump (what `pds serve` prints after a run).
    pub fn report(&self, model: &str) -> String {
        let batch = self.occupancy.len();
        let hist = self.occupancy_histogram();
        let nz: Vec<String> = hist
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(k, &c)| format!("{}:{c}", k + 1))
            .collect();
        let act = if self.act_total.load(Ordering::Relaxed) > 0 {
            format!(", act density {:.3}", self.act_density())
        } else {
            String::new()
        };
        format!(
            "model {model}: {} served, {} rejected, {} batches (mean occupancy {:.1}/{batch}, \
             {} stolen), {} padded rows, {} quant saturations{act}\n  latency p50 {:?} p95 {:?} \
             p99 {:?}; occupancy histogram {{{}}}",
            self.requests.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_occupancy(),
            self.stolen.load(Ordering::Relaxed),
            self.padded_rows.load(Ordering::Relaxed),
            self.quant_saturations.load(Ordering::Relaxed),
            self.latency.quantile(0.50),
            self.latency.quantile(0.95),
            self.latency.quantile(0.99),
            nz.join(" "),
        )
    }
}

struct ShardState {
    q: VecDeque<Request>,
    stopped: bool,
}

/// One bounded request queue, owned by one worker. `depth` mirrors the
/// queue length so the router and thieves can scan without locking.
struct Shard {
    state: Mutex<ShardState>,
    nonempty: Condvar,
    depth: AtomicUsize,
    capacity: usize,
}

impl Shard {
    fn new(capacity: usize) -> Shard {
        Shard {
            state: Mutex::new(ShardState {
                q: VecDeque::new(),
                stopped: false,
            }),
            nonempty: Condvar::new(),
            depth: AtomicUsize::new(0),
            capacity,
        }
    }

    fn try_push(&self, req: Request) -> Result<(), (ServeError, Request)> {
        let mut s = self.state.lock().unwrap();
        if s.stopped {
            return Err((ServeError::Stopped, req));
        }
        if s.q.len() >= self.capacity {
            return Err((ServeError::Busy, req));
        }
        s.q.push_back(req);
        self.depth.store(s.q.len(), Ordering::Relaxed);
        drop(s);
        self.nonempty.notify_one();
        Ok(())
    }

    fn try_pop(&self) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        let r = s.q.pop_front();
        self.depth.store(s.q.len(), Ordering::Relaxed);
        r
    }

    fn is_empty(&self) -> bool {
        self.state.lock().unwrap().q.is_empty()
    }

    /// Park until something is pushed, the shard stops, or `timeout`
    /// elapses (spurious wakeups are fine — callers re-poll).
    fn wait_nonempty(&self, timeout: Duration) {
        let s = self.state.lock().unwrap();
        if s.q.is_empty() && !s.stopped {
            let _ = self.nonempty.wait_timeout(s, timeout);
        }
    }

    fn stop(&self) {
        self.state.lock().unwrap().stopped = true;
        self.nonempty.notify_all();
    }
}

/// Everything [`InferenceService::start`] computes for one model before
/// any worker thread exists: the fallible work (validation, parameter
/// init, quantization + clip check) lives in the prepare pass, so a
/// failing model can never leak already-spawned sibling workers or a
/// pinned kernel-thread override.
struct PreparedModel {
    config: String,
    layers: Vec<usize>,
    batch: usize,
    masks: Arc<Vec<Value>>,
    /// Parameter bank: one entry per tenant context.
    params: Vec<Arc<Vec<Value>>>,
    /// Quantized-net bank (one per context) when serving Qm.n.
    qnets: Option<Vec<Arc<FixedSparseNet>>>,
    /// Compacted f32 net bank (one per context) when serving with an
    /// activation mask but no quantization — the sparse-sparse f32 path.
    snets: Option<Vec<Arc<SparseNet>>>,
    /// Activation-sparsity spec, if any (drives both act paths).
    act: Option<ActSpec>,
}

/// Shared state of one served model: its shards, shape info and metrics.
struct ModelCore {
    name: String,
    batch: usize,
    features: usize,
    classes: usize,
    contexts: usize,
    shards: Vec<Shard>,
    metrics: ModelMetrics,
    stop: AtomicBool,
}

impl ModelCore {
    /// Pop from the deepest sibling shard (depth is a racy hint; the
    /// victim's lock decides).
    fn steal(&self, not_from: usize) -> Option<Request> {
        let mut best = None;
        let mut best_depth = 0usize;
        for (i, sh) in self.shards.iter().enumerate() {
            if i == not_from {
                continue;
            }
            let d = sh.depth.load(Ordering::Relaxed);
            if d > best_depth {
                best_depth = d;
                best = Some(i);
            }
        }
        self.shards[best?].try_pop()
    }

    fn all_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }
}

/// Submission handle for one model; cloneable across client threads.
#[derive(Clone)]
pub struct Client {
    core: Arc<ModelCore>,
}

/// A request accepted into the service but not yet computed — the
/// non-blocking half of [`Client::submit`]. Call
/// [`PendingPrediction::wait`] to block for the reply. Dropping it
/// abandons the result (the worker's reply send fails harmlessly).
pub struct PendingPrediction {
    rx: mpsc::Receiver<Prediction>,
}

impl PendingPrediction {
    /// Block until the prediction is computed. Fails with
    /// [`ServeError::Stopped`] if the serving worker dropped the request
    /// during shutdown instead of executing it.
    pub fn wait(self) -> Result<Prediction, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Stopped)
    }
}

impl Client {
    /// Name of the model this client submits to.
    pub fn model(&self) -> &str {
        &self.core.name
    }

    /// Input feature dimension the model expects.
    pub fn features(&self) -> usize {
        self.core.features
    }

    /// Number of output classes.
    pub fn classes(&self) -> usize {
        self.core.classes
    }

    /// Compiled engine batch size — the most requests one worker flush
    /// can carry, and therefore the natural coalescing bound for
    /// upstream micro-batchers ([`crate::net::MicroBatcher`]).
    pub fn batch(&self) -> usize {
        self.core.batch
    }

    /// Tenant contexts this model hosts (`1` = single-tenant).
    pub fn contexts(&self) -> usize {
        self.core.contexts
    }

    /// Submit one feature vector without blocking for the result.
    ///
    /// Routing: the shallowest shard is tried first (load balances
    /// toward idle workers), then the remaining shards in index order
    /// on overflow. Fails fast with [`ServeError::Busy`]
    /// when every shard is at capacity (bounded-queue backpressure — the
    /// caller decides whether to retry or shed), and with
    /// [`ServeError::Stopped`] after shutdown. A burst of `submit`
    /// calls issued back-to-back lands in the worker queues together,
    /// so the dynamic batcher coalesces it into full engine batches —
    /// this is the primitive the network micro-batcher flushes through.
    ///
    /// # Panics
    /// If `features.len()` does not match the model's input dimension.
    pub fn submit(&self, features: Vec<f32>) -> Result<PendingPrediction, ServeError> {
        self.submit_ctx(features, 0)
    }

    /// Submit one feature vector for tenant context `context` without
    /// blocking for the result; see [`Client::submit`] for the routing
    /// and backpressure contract (contexts share the model's shards —
    /// the worker groups each flush by context at execution time).
    ///
    /// # Panics
    /// If `features.len()` does not match the model's input dimension,
    /// or `context >= self.contexts()`.
    pub fn submit_ctx(
        &self,
        features: Vec<f32>,
        context: usize,
    ) -> Result<PendingPrediction, ServeError> {
        self.submit_ctx_traced(features, context, None)
    }

    /// [`Client::submit_ctx`] carrying an open [`ReqTrace`] baton: the
    /// serving worker closes the trace when the batch executes and the
    /// echo surfaces on [`Prediction::trace`]. Pass `None` for the plain
    /// untraced submit (what [`Client::submit_ctx`] does).
    ///
    /// # Panics
    /// If `features.len()` does not match the model's input dimension,
    /// or `context >= self.contexts()`.
    pub fn submit_ctx_traced(
        &self,
        features: Vec<f32>,
        context: usize,
        trace: Option<Box<ReqTrace>>,
    ) -> Result<PendingPrediction, ServeError> {
        assert_eq!(features.len(), self.core.features, "feature dim mismatch");
        assert!(
            context < self.core.contexts,
            "context {context} out of range (model hosts {})",
            self.core.contexts
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut req = Request {
            features,
            context,
            submitted: Instant::now(),
            reply: reply_tx,
            trace,
        };
        let shards = &self.core.shards;
        let n = shards.len();
        // hot path: one O(n) scan for the shallowest shard, no
        // allocation; the remaining shards matter only on rejection
        let mut first = 0usize;
        let mut min_depth = usize::MAX;
        for (i, sh) in shards.iter().enumerate() {
            let d = sh.depth.load(Ordering::Relaxed);
            if d < min_depth {
                min_depth = d;
                first = i;
            }
        }
        let mut stopped = 0usize;
        for i in std::iter::once(first).chain((0..n).filter(|&i| i != first)) {
            match shards[i].try_push(req) {
                Ok(()) => return Ok(PendingPrediction { rx: reply_rx }),
                // a single stopped shard just means its worker died;
                // siblings may still serve — only all-stopped is fatal
                Err((ServeError::Stopped, r)) => {
                    stopped += 1;
                    req = r;
                }
                Err((_, r)) => req = r,
            }
        }
        if stopped == n {
            return Err(ServeError::Stopped);
        }
        self.core.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        Err(ServeError::Busy)
    }

    /// Submit one feature vector and block until its prediction returns
    /// ([`Client::submit`] + [`PendingPrediction::wait`]).
    ///
    /// # Panics
    /// If `features.len()` does not match the model's input dimension.
    pub fn classify(&self, features: Vec<f32>) -> Result<Prediction, ServeError> {
        self.submit(features)?.wait()
    }

    /// Submit for tenant context `context` and block for the prediction
    /// ([`Client::submit_ctx`] + [`PendingPrediction::wait`]).
    ///
    /// # Panics
    /// If `features.len()` does not match the model's input dimension,
    /// or `context >= self.contexts()`.
    pub fn classify_ctx(
        &self,
        features: Vec<f32>,
        context: usize,
    ) -> Result<Prediction, ServeError> {
        self.submit_ctx(features, context)?.wait()
    }
}

/// One model (manifest config + connection pattern + optional trained
/// parameters) for [`InferenceService::start`].
#[derive(Clone)]
pub struct ModelSpec {
    /// Manifest config name (`tiny`, `mnist_fc2`, `timit`, ...).
    pub config: String,
    /// Pre-defined sparse connection pattern; decides the masks and
    /// which weights are trainable.
    pub pattern: NetPattern,
    /// `w_i, b_i` interleaved per junction (the `forward` signature
    /// order). He-initialized from `pattern` when `None`.
    pub params: Option<Vec<Value>>,
    /// Serve this model in Qm.n fixed point (`nn::fixed`): the
    /// parameters are quantized once at startup and every worker runs
    /// the saturating integer kernels instead of a compiled f32
    /// `forward` program (CLI: `serve --quant Qm.n`). `None` serves f32.
    pub quant: Option<QFormat>,
    /// Tenant contexts this model hosts (clamped up to 1). Context 0
    /// serves [`ModelSpec::params`] (or the default He draw); contexts
    /// `1..C` serve independent per-tenant draws over the shared
    /// pattern — see [`context_params`] (CLI: `serve --contexts C`).
    pub contexts: usize,
    /// Run-time activation sparsity ([`crate::nn::actsparse`]): when
    /// set, every worker executes the sparse-sparse kernels — hidden
    /// activations are masked per batch row and the CSR loops skip
    /// inactive neurons — and the achieved density surfaces in
    /// [`ModelMetrics::act_density`]. Composes with [`ModelSpec::quant`]
    /// (selection then runs on raw Qm.n words). `None` serves
    /// weight-sparse-only (CLI: `serve --act-topk K`).
    pub act: Option<ActSpec>,
}

impl ModelSpec {
    /// Spec with He-initialized parameters, f32 serving, one context.
    pub fn new(config: impl Into<String>, pattern: NetPattern) -> ModelSpec {
        ModelSpec {
            config: config.into(),
            pattern,
            params: None,
            quant: None,
            contexts: 1,
            act: None,
        }
    }

    /// Serve this model quantized in `fmt` (see [`ModelSpec::quant`]).
    pub fn with_quant(mut self, fmt: QFormat) -> ModelSpec {
        self.quant = Some(fmt);
        self
    }

    /// Host `contexts` tenant contexts (see [`ModelSpec::contexts`]).
    pub fn with_contexts(mut self, contexts: usize) -> ModelSpec {
        self.contexts = contexts;
        self
    }

    /// Serve with run-time activation sparsity (see [`ModelSpec::act`]).
    pub fn with_act(mut self, spec: ActSpec) -> ModelSpec {
        self.act = Some(spec);
        self
    }
}

/// The multi-worker, multi-model inference service. See the module docs
/// for the architecture; [`InferenceServer`] is the single-model
/// convenience wrapper.
///
/// ```
/// use pds::coordinator::loadgen::model_spec;
/// use pds::coordinator::{InferenceService, ServerConfig};
///
/// // a ~25%-density clash-free model over the built-in `tiny` config
/// let spec = model_spec("/nonexistent/dir", "tiny", 0.25, 7).unwrap();
/// let svc = InferenceService::start("/nonexistent/dir", vec![spec], ServerConfig::default())
///     .unwrap();
/// let client = svc.client("tiny").unwrap();
/// let pred = client.classify(vec![0.0; client.features()]).unwrap();
/// assert!(pred.class < client.classes());
/// assert_eq!(svc.metrics("tiny").unwrap().batches.load(std::sync::atomic::Ordering::Relaxed), 1);
/// svc.shutdown().unwrap();
/// ```
pub struct InferenceService {
    models: BTreeMap<String, Arc<ModelCore>>,
    workers: Vec<JoinHandle<Result<()>>>,
    cfg: ServerConfig,
    /// Kernel-thread override in force before this service pinned it
    /// (`Some` only when `tune_kernel_threads` applied); restored on
    /// drop so even error paths hand the budget back.
    prev_threads: Option<usize>,
    /// The observability registry: one collector per model (registered
    /// at startup, holding `Weak` core handles), plus whatever the net
    /// layer registers on top. Shared so the net server can hang its
    /// own collectors off the same snapshot.
    registry: Arc<Registry>,
}

/// The samples one model contributes to a registry snapshot. All reads
/// are relaxed loads of the same atomics [`ModelMetrics`] exposes.
fn collect_model_samples(core: &ModelCore, out: &mut Vec<Sample>) {
    let m = &core.metrics;
    let l = || vec![("model", core.name.clone())];
    out.push(Sample::counter("serve.requests", l(), m.requests.load(Ordering::Relaxed)));
    out.push(Sample::counter("serve.rejected", l(), m.rejected.load(Ordering::Relaxed)));
    out.push(Sample::counter("serve.batches", l(), m.batches.load(Ordering::Relaxed)));
    out.push(Sample::counter("serve.padded_rows", l(), m.padded_rows.load(Ordering::Relaxed)));
    out.push(Sample::counter("serve.stolen", l(), m.stolen.load(Ordering::Relaxed)));
    out.push(Sample::counter(
        "serve.quant_saturations",
        l(),
        m.quant_saturations.load(Ordering::Relaxed),
    ));
    out.push(Sample::counter("serve.act_active", l(), m.act_active.load(Ordering::Relaxed)));
    out.push(Sample::counter("serve.act_total", l(), m.act_total.load(Ordering::Relaxed)));
    out.push(Sample::gauge("serve.contexts", l(), core.contexts as f64));
    out.push(Sample::gauge("serve.workers", l(), core.shards.len() as f64));
    out.push(Sample::gauge("serve.occupancy_mean", l(), m.mean_occupancy()));
    out.push(Sample::gauge("serve.act_density", l(), m.act_density()));
    out.push(Sample::histogram("serve.latency", l(), &m.latency));
}

impl InferenceService {
    /// Spawn `cfg.workers` workers for every model in `specs` and block
    /// until each has built its engine and loaded its `forward` program
    /// (startup failures surface here, not on first request).
    ///
    /// The manifest at `artifacts_dir` is parsed once; each worker gets
    /// a cheap engine over the shared parse
    /// ([`crate::runtime::Engine::for_worker`]).
    pub fn start(
        artifacts_dir: impl Into<PathBuf>,
        specs: Vec<ModelSpec>,
        cfg: ServerConfig,
    ) -> Result<InferenceService> {
        anyhow::ensure!(!specs.is_empty(), "no models to serve");
        let artifacts_dir = artifacts_dir.into();
        let workers_per_model = cfg.workers.max(1);
        let manifest = Arc::new(Manifest::load_or_builtin(&artifacts_dir)?);
        // validate AND fully prepare every model (masks, parameters, the
        // quantized net with its clip check) before spawning any worker
        // or pinning the process-wide kernel-thread budget: no failure
        // past this pass may leak running threads or a stale override
        let n_models = specs.len();
        let mut seen: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut prepared: Vec<PreparedModel> = Vec::with_capacity(n_models);
        for spec in specs {
            anyhow::ensure!(
                seen.insert(spec.config.clone()),
                "model '{}' listed twice",
                spec.config
            );
            let entry = manifest
                .configs
                .get(&spec.config)
                .ok_or_else(|| anyhow::anyhow!("config '{}' not in manifest", spec.config))?;
            let layers = entry.layers.clone();
            anyhow::ensure!(
                spec.pattern.junctions.len() == layers.len() - 1,
                "'{}': pattern has {} junctions, net has {}",
                spec.config,
                spec.pattern.junctions.len(),
                layers.len() - 1
            );
            for (i, p) in spec.pattern.junctions.iter().enumerate() {
                anyhow::ensure!(
                    p.shape.n_left == layers[i] && p.shape.n_right == layers[i + 1],
                    "'{}': pattern junction {i} shape mismatch",
                    spec.config
                );
            }
            let masks: Arc<Vec<Value>> = Arc::new(
                spec.pattern
                    .junctions
                    .iter()
                    .map(|p| Value::F32(p.mask(), vec![p.shape.n_right, p.shape.n_left]))
                    .collect(),
            );
            // per-context parameter bank: context 0 is the base, each
            // further context its own draw over the shared pattern
            let contexts = spec.contexts.max(1);
            let mut base = spec.params;
            let params: Vec<Arc<Vec<Value>>> = (0..contexts)
                .map(|ctx| Arc::new(context_params(&layers, &spec.pattern, base.take(), ctx)))
                .collect();
            // quantized serving: compact + quantize every context's
            // parameters ONCE here, so workers share immutable
            // fixed-point nets instead of re-quantizing per batch; the
            // clip and range gates apply per context
            let qnets: Option<Vec<Arc<FixedSparseNet>>> = match spec.quant {
                Some(fmt) => {
                    let mut nets = Vec::with_capacity(contexts);
                    for (ctx, p) in params.iter().enumerate() {
                        let net = quantized_net(&spec.pattern, p, fmt)?;
                        anyhow::ensure!(
                            net.clipped_params() == 0,
                            "'{}' context {ctx}: {} parameters clip at the {fmt} range — the \
                             format lacks integer headroom for this tenant's weights; pick a \
                             wider Qm.n",
                            spec.config,
                            net.clipped_params()
                        );
                        // static range certification on the exact net being
                        // served (cheap: a few interval propagations): the
                        // format must admit a nonempty saturation-free input
                        // range, or every request would clip
                        let (findings, _cert) =
                            crate::analysis::range::analyze_qnet(&spec.config, &net, None);
                        if let Some(f) = findings
                            .iter()
                            .find(|f| f.severity == crate::analysis::Severity::Error)
                        {
                            anyhow::bail!(
                                "'{}' context {ctx}: static range analysis rejects serving \
                                 at {fmt}: {f}",
                                spec.config
                            );
                        }
                        nets.push(Arc::new(net));
                    }
                    Some(nets)
                }
                None => None,
            };
            // activation sparsity: refuse degenerate specs at startup
            // (k = 0 would zero every hidden layer; a bad threshold is
            // unreachable via the manifest but reachable via the API),
            // then compact each context's parameters once for the f32
            // sparse-sparse path — the quantized path reuses `qnets`
            let act = spec.act.or(entry.act);
            if let Some(a) = &act {
                match a.mode {
                    ActMode::TopK(0) => anyhow::bail!(
                        "'{}': act_sparsity topk k=0 zeroes every hidden activation",
                        spec.config
                    ),
                    ActMode::Threshold(t) if !t.is_finite() || t < 0.0 => anyhow::bail!(
                        "'{}': act_sparsity threshold {t} must be finite and >= 0",
                        spec.config
                    ),
                    _ => {}
                }
            }
            let snets: Option<Vec<Arc<SparseNet>>> = match (&act, &qnets) {
                (Some(_), None) => Some(
                    params
                        .iter()
                        .map(|p| Ok(Arc::new(sparse_net(&spec.pattern, p)?)))
                        .collect::<Result<_>>()?,
                ),
                _ => None,
            };
            prepared.push(PreparedModel {
                config: spec.config,
                layers,
                batch: entry.batch,
                masks,
                params,
                qnets,
                snets,
                act,
            });
        }
        let mut prev_threads = None;
        if cfg.tune_kernel_threads {
            prev_threads = Some(parallel::thread_override());
            parallel::set_threads(parallel::worker_thread_budget(
                workers_per_model * n_models,
            ));
        }
        let registry = Arc::new(Registry::new());
        let mut models: BTreeMap<String, Arc<ModelCore>> = BTreeMap::new();
        let mut handles = Vec::new();
        let mut ready = Vec::new();
        for PreparedModel {
            config,
            layers,
            batch,
            masks,
            params,
            qnets,
            snets,
            act,
        } in prepared
        {
            let core = Arc::new(ModelCore {
                name: config.clone(),
                batch,
                features: layers[0],
                classes: *layers.last().unwrap(),
                contexts: params.len(),
                shards: (0..workers_per_model)
                    .map(|_| Shard::new(cfg.queue_depth.max(1)))
                    .collect(),
                metrics: ModelMetrics::new(batch),
                stop: AtomicBool::new(false),
            });
            for w in 0..workers_per_model {
                let (ready_tx, ready_rx) = mpsc::channel();
                ready.push((config.clone(), ready_rx));
                let core = Arc::clone(&core);
                let dir = artifacts_dir.clone();
                let manifest = Arc::clone(&manifest);
                let params = params.clone();
                let masks = Arc::clone(&masks);
                let qnets = qnets.clone();
                let snets = snets.clone();
                let max_wait = cfg.max_wait;
                handles.push(std::thread::spawn(move || {
                    worker_loop(
                        core, w, dir, manifest, params, masks, qnets, snets, act, max_wait,
                        ready_tx,
                    )
                }));
            }
            // Weak: the collector must never extend the core's lifetime
            // (callers tear the service down and assert nothing still
            // references it); after teardown it just contributes nothing
            let weak = Arc::downgrade(&core);
            registry.register(move |out| {
                if let Some(core) = weak.upgrade() {
                    collect_model_samples(&core, out);
                }
            });
            models.insert(core.name.clone(), core);
        }
        let svc = InferenceService {
            models,
            workers: handles,
            cfg,
            prev_threads,
            registry,
        };
        for (model, rx) in ready {
            let up = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker for '{model}' died during startup"));
            match up {
                Ok(Ok(())) => {}
                Ok(Err(e)) | Err(e) => {
                    let _ = svc.shutdown();
                    return Err(e.context(format!("starting worker for '{model}'")));
                }
            }
        }
        Ok(svc)
    }

    /// Submission handle for `model`.
    pub fn client(&self, model: &str) -> Result<Client> {
        let core = self
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' not served"))?;
        Ok(Client {
            core: Arc::clone(core),
        })
    }

    /// This model's raw metrics struct, if served. Prefer
    /// [`InferenceService::registry`] for a coherent cross-subsystem
    /// snapshot; this accessor remains for targeted counter asserts.
    pub fn metrics(&self, model: &str) -> Option<&ModelMetrics> {
        self.models.get(model).map(|c| &c.metrics)
    }

    /// The observability registry every model reports into. The net
    /// layer registers its own collectors here too, so one
    /// `registry().snapshot()` covers serve + batcher + net counters.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Names of the models being served.
    pub fn models(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// The configuration the service was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    fn signal_stop(&self) {
        for core in self.models.values() {
            // order matters: mark every shard closed to new submissions
            // *before* raising the stop flag, so a worker that observes
            // `stop` can conclude from empty queues that nothing is left
            for sh in &core.shards {
                sh.stop();
            }
            core.stop.store(true, Ordering::Release);
            for sh in &core.shards {
                sh.nonempty.notify_all();
            }
        }
    }

    /// Stop accepting requests, drain every queued request, and join the
    /// workers. The kernel-thread override this service pinned
    /// (`tune_kernel_threads`) is restored to its previous value when
    /// `self` drops at the end. Returns the first worker error, if any.
    pub fn shutdown(mut self) -> Result<()> {
        self.signal_stop();
        let mut first_err = None;
        for h in self.workers.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("serve worker panicked"));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for InferenceService {
    /// Dropping without [`InferenceService::shutdown`] still signals the
    /// workers to stop (they exit after draining, detached rather than
    /// joined), and restores the kernel-thread override this service
    /// pinned — so error paths that drop the service mid-run don't leak
    /// a divided thread budget into the rest of the process.
    fn drop(&mut self) {
        self.signal_stop();
        if let Some(prev) = self.prev_threads.take() {
            parallel::set_threads(prev);
        }
    }
}

/// Closes a worker's shard on every exit path — normal shutdown, a
/// `?` error from execution, or a panic: marks it stopped so new
/// submissions are rejected rather than queued forever, and drops any
/// already-queued requests so their clients observe
/// [`ServeError::Stopped`] instead of blocking on a reply that will
/// never come. Idempotent on the normal path (the shard is stopped and
/// drained by then).
struct ShardCloseGuard<'a> {
    shard: &'a Shard,
}

impl Drop for ShardCloseGuard<'_> {
    fn drop(&mut self) {
        self.shard.stop();
        while self.shard.try_pop().is_some() {}
    }
}

/// He-initialize `w_i, b_i` per junction with excluded edges pre-zeroed.
fn he_params(layers: &[usize], pattern: &NetPattern, seed: u64) -> Vec<Value> {
    let mut rng = Rng::new(seed);
    let mut p = Vec::new();
    for i in 1..layers.len() {
        let (nl, nr) = (layers[i - 1], layers[i]);
        let std = (2.0 / nl as f32).sqrt();
        let mask = pattern.junctions[i - 1].mask();
        let w: Vec<f32> = mask.iter().map(|&m| rng.normal() * std * m).collect();
        p.push(Value::F32(w, vec![nr, nl]));
        p.push(Value::F32(vec![0.1; nr], vec![nr]));
    }
    p
}

/// The parameters tenant context `ctx` of a model serves: context 0 is
/// the base (externally trained `base` parameters when supplied, else
/// the default He draw), and every further context an independent He
/// draw from a context-salted seed — a stand-in for per-tenant
/// fine-tuned variants over the shared pattern. Public so isolation
/// tests can start a single-tenant twin service from exactly the
/// parameters a multi-context service gives tenant `ctx` and assert
/// routing parity.
pub fn context_params(
    layers: &[usize],
    pattern: &NetPattern,
    base: Option<Vec<Value>>,
    ctx: usize,
) -> Vec<Value> {
    match (base, ctx) {
        (Some(p), 0) => p,
        (_, c) => he_params(
            layers,
            pattern,
            0xD15EA5E ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        ),
    }
}

/// Compact a model's dense parameters (w/b interleaved, the `forward`
/// signature order) into a CSR net — the startup step of f32
/// sparse-sparse serving: compact once, mask per flush.
fn sparse_net(pattern: &NetPattern, params: &[Value]) -> Result<SparseNet> {
    let mut pairs = Vec::with_capacity(pattern.junctions.len());
    for i in 0..pattern.junctions.len() {
        pairs.push((params[2 * i].as_f32()?, params[2 * i + 1].as_f32()?));
    }
    Ok(SparseNet::from_pattern_dense(pattern, &pairs))
}

/// Compact + quantize a model's dense parameters (w/b interleaved, the
/// `forward` signature order) into a fixed-point net — the startup step
/// of quantized serving: quantize once, serve many.
fn quantized_net(
    pattern: &NetPattern,
    params: &[Value],
    fmt: QFormat,
) -> Result<FixedSparseNet> {
    let mut pairs = Vec::with_capacity(pattern.junctions.len());
    for i in 0..pattern.junctions.len() {
        pairs.push((params[2 * i].as_f32()?, params[2 * i + 1].as_f32()?));
    }
    Ok(FixedSparseNet::from_f32(
        &SparseNet::from_pattern_dense(pattern, &pairs),
        fmt,
    ))
}

/// How one worker executes a flushed batch: through a compiled backend
/// `forward` program (f32), or through the model's shared quantized
/// nets (Qm.n fixed point — no engine, no compiled program). Both paths
/// hold a *bank* of per-context state, indexed by the context that owns
/// the rows being executed — fetched per flush group, never swapped.
enum ExecPath {
    /// Compiled f32 path: one compiled program shared by all contexts,
    /// one positional input list per context (holding that tenant's
    /// parameters); only the fetched context's trailing x tensor is
    /// rewritten per flush.
    Prog {
        prog: Program,
        inputs: Vec<Vec<Value>>,
        x_idx: usize,
    },
    /// Fixed-point path: per-context quantized nets and one reusable
    /// quantized input buffer. With an [`ActSpec`] the workers run the
    /// quantized sparse-sparse kernels (selection on raw Qm.n words).
    Quant {
        nets: Vec<Arc<FixedSparseNet>>,
        xq: Vec<i32>,
        act: Option<ActSpec>,
    },
    /// f32 sparse-sparse path: per-context compacted CSR nets executed
    /// with a fresh per-flush activation mask ([`SparseNet::logits_act`]),
    /// bypassing the compiled program entirely.
    Act {
        nets: Vec<Arc<SparseNet>>,
        spec: ActSpec,
        x: Vec<f32>,
    },
}

/// Argmax per occupied row (works on f32 logits and raw fixed-point
/// words alike — dequantization is order-preserving, so the quantized
/// path never needs it).
fn argmax_rows<T: Copy + PartialOrd>(logits: &[T], rows: usize, classes: usize) -> Vec<usize> {
    (0..rows)
        .map(|i| {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            best
        })
        .collect()
}

/// One worker: builds its backend on this thread (PJRT executables wrap
/// thread-affine raw handles; quantized models skip the backend and use
/// the shared fixed-point net), then loops collecting dynamic batches
/// from its own shard — stealing from the deepest sibling when dry —
/// executing, and fanning results back out.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    core: Arc<ModelCore>,
    w: usize,
    artifacts_dir: PathBuf,
    manifest: Arc<Manifest>,
    params: Vec<Arc<Vec<Value>>>,
    masks: Arc<Vec<Value>>,
    qnets: Option<Vec<Arc<FixedSparseNet>>>,
    snets: Option<Vec<Arc<SparseNet>>>,
    act: Option<ActSpec>,
    max_wait: Duration,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let (batch, features, classes) = (core.batch, core.features, core.classes);
    let mut exec = match (qnets, snets) {
        (Some(nets), _) => ExecPath::Quant {
            nets,
            xq: vec![0i32; batch * features],
            act,
        },
        (None, Some(nets)) => ExecPath::Act {
            nets,
            spec: act.expect("snets are only prepared alongside an ActSpec"),
            x: vec![0f32; batch * features],
        },
        (None, None) => {
            let engine = match Engine::for_worker(&artifacts_dir, &manifest) {
                Ok(e) => e,
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready.send(Err(e));
                    anyhow::bail!("{msg}");
                }
            };
            let prog = match engine.load(&core.name, "forward") {
                Ok(p) => p,
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready.send(Err(e));
                    anyhow::bail!("{msg}");
                }
            };
            // weights and masks are immutable and `Program::run` only
            // borrows them, so build one positional input list per
            // context once and rewrite only the fetched context's
            // trailing x tensor per flush — no per-batch parameter
            // clones, no bank swapping
            let x_idx = params[0].len() + masks.len();
            let inputs: Vec<Vec<Value>> = params
                .iter()
                .map(|p| {
                    let mut v: Vec<Value> = Vec::with_capacity(p.len() + masks.len() + 1);
                    v.extend(p.iter().cloned());
                    v.extend(masks.iter().cloned());
                    v.push(Value::F32(vec![0f32; batch * features], vec![batch, features]));
                    v
                })
                .collect();
            ExecPath::Prog {
                prog,
                inputs,
                x_idx,
            }
        }
    };
    let _ = ready.send(Ok(()));
    let my = &core.shards[w];
    let _close = ShardCloseGuard { shard: my };
    let mut pending: Vec<Request> = Vec::with_capacity(batch);
    loop {
        // block for the first request of a batch (or drain + exit)
        let first = loop {
            if let Some(r) = my.try_pop() {
                break r;
            }
            if let Some(r) = core.steal(w) {
                core.metrics.stolen.fetch_add(1, Ordering::Relaxed);
                break r;
            }
            if core.stop.load(Ordering::Acquire) {
                // shards stopped before the flag was raised, so empty
                // queues now mean empty forever
                if core.all_empty() {
                    return Ok(());
                }
                continue;
            }
            my.wait_nonempty(IDLE_POLL);
        };
        pending.push(first);
        let deadline = Instant::now() + max_wait;
        while pending.len() < batch {
            if let Some(r) = my.try_pop() {
                pending.push(r);
                continue;
            }
            if let Some(r) = core.steal(w) {
                core.metrics.stolen.fetch_add(1, Ordering::Relaxed);
                pending.push(r);
                continue;
            }
            let now = Instant::now();
            if now >= deadline || core.stop.load(Ordering::Acquire) {
                break;
            }
            // cap the wait so sibling shards are re-polled for stealing
            // even while this worker's own shard stays quiet
            my.wait_nonempty((deadline - now).min(STEAL_POLL));
        }
        // fan the flush out per tenant context: rows are grouped by the
        // context that owns them and each group executes as one padded
        // batch against that context's fetched state bank — requests
        // never cross banks, and the groups run back to back with no
        // idle time between tenants
        let m = &core.metrics;
        let mut groups: BTreeMap<usize, Vec<Request>> = BTreeMap::new();
        for req in pending.drain(..) {
            groups.entry(req.context).or_default().push(req);
        }
        for (ctx, group) in groups {
            let occupancy = group.len();
            // stamp the execute window only when some request in this
            // group is traced — the untraced path takes zero timestamps
            let traced = group.iter().any(|r| r.trace.is_some());
            let exec_start = traced.then(Instant::now);
            let best_classes: Vec<usize> = match &mut exec {
                ExecPath::Prog {
                    prog,
                    inputs,
                    x_idx,
                } => {
                    let ctx_inputs = &mut inputs[ctx];
                    if let Value::F32(x, _) = &mut ctx_inputs[*x_idx] {
                        for (i, req) in group.iter().enumerate() {
                            x[i * features..(i + 1) * features].copy_from_slice(&req.features);
                        }
                        // zero the tail so rows left over from a fuller flush
                        // never leak into this batch's padding
                        x[occupancy * features..].fill(0.0);
                    }
                    let out = prog.run(ctx_inputs)?;
                    argmax_rows(out[0].as_f32()?, occupancy, classes)
                }
                ExecPath::Quant { nets, xq, act } => {
                    let net = &nets[ctx];
                    let fmt = net.fmt;
                    // input clips count as saturations: a clipped feature
                    // violates the error bound the same way a saturated
                    // MAC does
                    let mut clipped = 0usize;
                    for (i, req) in group.iter().enumerate() {
                        for (d, &v) in xq[i * features..(i + 1) * features]
                            .iter_mut()
                            .zip(&req.features)
                        {
                            *d = fmt.quantize_counted(v, &mut clipped);
                        }
                    }
                    xq[occupancy * features..].fill(0);
                    let (logits, sats) = match act {
                        Some(aspec) => {
                            let (logits, sats, stats) = net.logits_q_act(xq, batch, aspec);
                            m.record_act(stats);
                            (logits, sats)
                        }
                        None => net.logits_q(xq, batch),
                    };
                    if sats + clipped > 0 {
                        m.quant_saturations
                            .fetch_add((sats + clipped) as u64, Ordering::Relaxed);
                    }
                    argmax_rows(&logits, occupancy, classes)
                }
                ExecPath::Act { nets, spec, x } => {
                    for (i, req) in group.iter().enumerate() {
                        x[i * features..(i + 1) * features].copy_from_slice(&req.features);
                    }
                    x[occupancy * features..].fill(0.0);
                    let (logits, stats) = nets[ctx].logits_act(x, batch, spec);
                    m.record_act(stats);
                    argmax_rows(&logits, occupancy, classes)
                }
            };
            let exec_end = traced.then(Instant::now);
            m.requests.fetch_add(occupancy as u64, Ordering::Relaxed);
            m.batches.fetch_add(1, Ordering::Relaxed);
            m.padded_rows.fetch_add((batch - occupancy) as u64, Ordering::Relaxed);
            m.occupancy[occupancy - 1].fetch_add(1, Ordering::Relaxed);
            for (req, best) in group.into_iter().zip(best_classes) {
                let latency = req.submitted.elapsed();
                m.latency.record(latency);
                let trace = req.trace.map(|tr| {
                    tr.finish(
                        exec_start.expect("exec window stamped when any request is traced"),
                        exec_end.expect("exec window stamped when any request is traced"),
                        w,
                    )
                });
                let _ = req.reply.send(Prediction {
                    class: best,
                    latency,
                    batch_occupancy: occupancy,
                    worker: w,
                    context: ctx,
                    trace,
                });
            }
        }
    }
}

/// Single-model convenience wrapper over [`InferenceService`] (the shape
/// most tests and simple callers want).
pub struct InferenceServer {
    svc: InferenceService,
    model: String,
}

impl InferenceServer {
    /// One model, `cfg.workers` workers. See [`InferenceService::start`].
    pub fn start(
        artifacts_dir: impl Into<PathBuf>,
        config: &str,
        pattern: &NetPattern,
        params: Option<Vec<Value>>,
        cfg: ServerConfig,
    ) -> Result<Self> {
        let svc = InferenceService::start(
            artifacts_dir,
            vec![ModelSpec {
                config: config.to_string(),
                pattern: pattern.clone(),
                params,
                quant: None,
                contexts: 1,
                act: None,
            }],
            cfg,
        )?;
        Ok(InferenceServer {
            svc,
            model: config.to_string(),
        })
    }

    /// Submission handle; cloneable across client threads.
    pub fn client(&self) -> Client {
        self.svc.client(&self.model).expect("own model is served")
    }

    /// The model's metrics registry.
    pub fn metrics(&self) -> &ModelMetrics {
        self.svc.metrics(&self.model).expect("own model is served")
    }

    /// Stop, drain, and join the workers.
    pub fn shutdown(self) -> Result<()> {
        self.svc.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_request() -> (Request, mpsc::Receiver<Prediction>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                features: vec![0.0; 4],
                context: 0,
                submitted: Instant::now(),
                reply: tx,
                trace: None,
            },
            rx,
        )
    }

    #[test]
    fn shard_rejects_when_full_and_recovers() {
        let sh = Shard::new(2);
        let (r1, _k1) = dummy_request();
        let (r2, _k2) = dummy_request();
        let (r3, _k3) = dummy_request();
        assert!(sh.try_push(r1).is_ok());
        assert!(sh.try_push(r2).is_ok());
        let err = sh.try_push(r3).err().map(|(e, _)| e);
        assert_eq!(err, Some(ServeError::Busy));
        // popping one frees capacity again: bounded, never blocking
        assert!(sh.try_pop().is_some());
        let (r4, _k4) = dummy_request();
        assert!(sh.try_push(r4).is_ok());
        assert_eq!(sh.depth.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stopped_shard_rejects_with_stopped() {
        let sh = Shard::new(4);
        sh.stop();
        let (r, _k) = dummy_request();
        match sh.try_push(r) {
            Err((ServeError::Stopped, _)) => {}
            _ => panic!("expected Stopped"),
        }
    }
}
