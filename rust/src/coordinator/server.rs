//! Batched inference service: request router + dynamic batcher over the
//! fixed-batch `forward` program of the runtime backend.
//!
//! A worker thread owns the loaded executable and the (sparse) model
//! parameters. Clients submit single feature vectors; the batcher
//! collects up to the config's compiled batch size or until
//! `max_wait` elapses, pads the tail with zero rows, executes once, and
//! fans the argmax results back out. This mirrors the hardware pipeline's
//! rhythm: a full junction cycle is paid per batch regardless of
//! occupancy, so latency = queueing + one fixed execution.
//!
//! On the default native backend the batched execution itself is
//! parallel: the forward kernels chunk the batch dimension across the
//! `util::parallel` thread pool, so one flush saturates multiple cores.
//!
//! Implemented on std threads + channels (tokio is unavailable in the
//! offline build; the request path is compute-bound, not I/O-bound).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::{Engine, Manifest, Value};
use crate::sparsity::pattern::NetPattern;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Flush a partial batch after this long (the latency/throughput knob).
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A classification response.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub class: usize,
    /// Time from submit to response.
    pub latency: Duration,
    /// How full the batch that served this request was.
    pub batch_occupancy: usize,
}

struct Request {
    features: Vec<f32>,
    submitted: Instant,
    reply: Sender<Prediction>,
}

/// Shared counters.
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
}

/// Handle for submitting requests; cloneable across client threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    features: usize,
}

impl Client {
    /// Submit one feature vector; blocks until the prediction returns.
    pub fn classify(&self, features: Vec<f32>) -> Result<Prediction> {
        assert_eq!(features.len(), self.features, "feature dim mismatch");
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            features,
            submitted: Instant::now(),
            reply: reply_tx,
        };
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(reply_rx.recv()?)
    }
}

pub struct InferenceServer {
    client_tx: Sender<Request>,
    worker: Option<JoinHandle<Result<()>>>,
    pub stats: Arc<ServerStats>,
    features: usize,
}

impl InferenceServer {
    /// Spawn the worker: it builds its own engine (PJRT executables are
    /// not `Send` — the xla crate wraps thread-affine raw handles — so the
    /// backend lives entirely on the worker thread), loads the `forward`
    /// program of `config`, and serves with He-initialized (or externally
    /// trained) parameters for `pattern`.
    pub fn start(
        artifacts_dir: impl Into<PathBuf>,
        config: &str,
        pattern: &NetPattern,
        params: Option<Vec<Value>>,
        cfg: ServerConfig,
    ) -> Result<Self> {
        let artifacts_dir = artifacts_dir.into();
        let config = config.to_string();
        // read the manifest up front (host-side, cheap) for shape info
        let probe = Manifest::probe(&artifacts_dir, &config)?;
        let layers = probe.layers;
        let batch = probe.batch;
        let classes = *layers.last().unwrap();
        let features = layers[0];

        let params = match params {
            Some(p) => p,
            None => {
                let mut rng = Rng::new(0xD15EA5E);
                let mut p = Vec::new();
                for i in 1..layers.len() {
                    let (nl, nr) = (layers[i - 1], layers[i]);
                    let std = (2.0 / nl as f32).sqrt();
                    let mask = pattern.junctions[i - 1].mask();
                    let w: Vec<f32> = mask.iter().map(|&m| rng.normal() * std * m).collect();
                    p.push(Value::F32(w, vec![nr, nl]));
                    p.push(Value::F32(vec![0.1; nr], vec![nr]));
                }
                p
            }
        };
        let masks: Vec<Value> = pattern
            .junctions
            .iter()
            .map(|p| Value::F32(p.mask(), vec![p.shape.n_right, p.shape.n_left]))
            .collect();

        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServerStats::default());
        let worker_stats = Arc::clone(&stats);
        let worker = std::thread::spawn(move || -> Result<()> {
            // backend objects live and die on this thread
            let engine = match Engine::new(&artifacts_dir) {
                Ok(e) => e,
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready_tx.send(Err(e));
                    anyhow::bail!("{msg}");
                }
            };
            let prog = match engine.load(&config, "forward") {
                Ok(p) => p,
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready_tx.send(Err(e));
                    anyhow::bail!("{msg}");
                }
            };
            let _ = ready_tx.send(Ok(()));
            let mut pending: Vec<Request> = Vec::with_capacity(batch);
            loop {
                // block for the first request of a batch
                match rx.recv() {
                    Err(_) => return Ok(()), // all clients dropped
                    Ok(req) => pending.push(req),
                }
                let deadline = Instant::now() + cfg.max_wait;
                while pending.len() < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(req) => pending.push(req),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // assemble the padded batch
                let occupancy = pending.len();
                let mut x = vec![0f32; batch * features];
                for (i, req) in pending.iter().enumerate() {
                    x[i * features..(i + 1) * features].copy_from_slice(&req.features);
                }
                let mut inputs: Vec<Value> = Vec::new();
                inputs.extend(params.iter().cloned());
                inputs.extend(masks.iter().cloned());
                inputs.push(Value::F32(x, vec![batch, features]));
                let out = prog.run(&inputs)?;
                let logits = out[0].as_f32()?;
                worker_stats.requests.fetch_add(occupancy as u64, Ordering::Relaxed);
                worker_stats.batches.fetch_add(1, Ordering::Relaxed);
                worker_stats
                    .padded_rows
                    .fetch_add((batch - occupancy) as u64, Ordering::Relaxed);
                for (i, req) in pending.drain(..).enumerate() {
                    let row = &logits[i * classes..(i + 1) * classes];
                    let mut best = 0usize;
                    for (c, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = c;
                        }
                    }
                    let _ = req.reply.send(Prediction {
                        class: best,
                        latency: req.submitted.elapsed(),
                        batch_occupancy: occupancy,
                    });
                }
            }
        });
        // propagate load/compile failures synchronously
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(InferenceServer {
            client_tx: tx,
            worker: Some(worker),
            stats,
            features,
        })
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.client_tx.clone(),
            features: self.features,
        }
    }

    /// Stop the worker (drops the submit channel, then joins).
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.client_tx);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}
