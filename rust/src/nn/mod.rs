//! Native Rust trainer for pre-defined sparse MLPs — the software-
//! simulation path (the paper's Sec. IV experiments ran as software sims;
//! DESIGN.md §Substitutions). Implements exactly the masked fwd/bwd/Adam
//! math of the AOT JAX artifacts (cross-checked in rust/tests/), so the
//! wide experiment sweeps and the PJRT path are interchangeable.
//!
//! - [`matrix`]: dense row-major matmul kernels,
//! - [`dense`]: masked-dense MLP (FC baselines, LSS training §V-B),
//! - [`sparse`]: CSR compacted-edge MLP — compute and storage proportional
//!   to |W_i|, the software twin of the hardware's edge processing,
//! - [`adam`]: the Adam optimizer [46] with the paper's decay schedule,
//! - [`trainer`]: sequential epoch loop, minibatching, metrics, LSS
//!   pruning, pipeline-staleness emulation (Sec. III-D),
//! - [`pipeline`]: the pipelined training engine — minibatches stream
//!   through the Sec. III-A FF/BP/UP interleave with `hw`'s timetable
//!   and clash-free banked weight views as the executable source of
//!   truth (sequential-equivalent at depth 1),
//! - [`fixed`]: the Qm.n fixed-point execution path (saturating
//!   arithmetic, LUT sigmoid, quantized twins of the [`sparse`] kernels)
//!   — the arithmetic the paper's FPGA companion (arXiv:1806.01087)
//!   actually computes in, differentially tested against f32,
//! - [`actsparse`]: run-time activation sparsity (top-k / thresholded
//!   masks with a z-banked packed index layout) composing with the
//!   pre-defined weight sparsity — sparse-sparse execution.

pub mod actsparse;
pub mod adam;
pub mod dense;
pub mod fixed;
pub mod matrix;
pub mod pipeline;
pub mod sparse;
pub mod trainer;

/// Softmax cross-entropy over logits [batch, classes]: returns (mean loss,
/// #correct, dlogits = (softmax - onehot)/batch).
pub fn softmax_ce(logits: &[f32], y: &[i32], classes: usize) -> (f32, usize, Vec<f32>) {
    let batch = y.len();
    assert_eq!(logits.len(), batch * classes);
    let mut dlogits = vec![0f32; logits.len()];
    let mut loss = 0f64;
    let mut correct = 0usize;
    for i in 0..batch {
        let row = &logits[i * classes..(i + 1) * classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        for &v in row {
            denom += (v - mx).exp();
        }
        let target = y[i] as usize;
        let logp_t = row[target] - mx - denom.ln();
        loss -= logp_t as f64;
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = c;
            }
            let p = (v - mx).exp() / denom;
            dlogits[i * classes + c] = (p - if c == target { 1.0 } else { 0.0 }) / batch as f32;
        }
        if best == target {
            correct += 1;
        }
    }
    ((loss / batch as f64) as f32, correct, dlogits)
}

/// ReLU applied in place; returns nothing (derivative is recomputed from
/// the pre-activation sign where needed).
pub fn relu(xs: &mut [f32]) {
    for v in xs {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_ce_uniform_logits() {
        // all-zero logits: loss = ln(C), grads = (1/C - onehot)/B
        let logits = vec![0f32; 2 * 4];
        let (loss, _correct, d) = softmax_ce(&logits, &[1, 3], 4);
        assert!((loss - (4f32).ln()).abs() < 1e-6);
        assert!((d[0] - 0.25 / 2.0).abs() < 1e-6);
        assert!((d[1] - (0.25 - 1.0) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_ce_counts_correct() {
        let logits = vec![5.0, 0.0, 0.0, 0.0, 0.0, 5.0];
        let (_, correct, _) = softmax_ce(&logits, &[0, 2], 3);
        assert_eq!(correct, 2);
        let (_, correct2, _) = softmax_ce(&logits, &[1, 2], 3);
        assert_eq!(correct2, 1);
    }

    #[test]
    fn grads_sum_to_zero_per_row() {
        let logits = vec![0.3, -1.0, 2.0, 0.1, 0.0, 0.7];
        let (_, _, d) = softmax_ce(&logits, &[2, 0], 3);
        for i in 0..2 {
            let s: f32 = d[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }
}
