//! CSR compacted-edge MLP: the software twin of the hardware's edge-based
//! processing (Fig. 4 layout). Storage and MACs are proportional to
//! `|W_i|` = sum of in-degrees — this is where pre-defined sparsity's
//! training-complexity reduction is actually realized in software
//! (Sec. II-B: complexity directly proportional to the number of edges).
//!
//! The kernels here are batch-parallel over [`crate::util::parallel`]:
//! FF and BP chunk independent batch rows across threads, UP reduces the
//! batch with per-thread partial accumulators. They back the reference
//! trainers and the native runtime backend's `gather_forward` program
//! (the inference service's compacted path).

use crate::nn::actsparse::{ActError, ActSpec, ActStats, ActivationMask};
use crate::sparsity::pattern::{NetPattern, Pattern};
use crate::util::parallel;
use crate::util::rng::Rng;

/// One junction in compacted form: `idx/wc` rows follow the paper's edge
/// numbering (row j = right neuron j's in-edges).
#[derive(Clone, Debug)]
pub struct SparseLayer {
    /// Left (input) layer width `N_{i-1}`.
    pub n_left: usize,
    /// Right (output) layer width `N_i`.
    pub n_right: usize,
    /// CSR row offsets, len n_right + 1 (uniform d_in => `offsets[j] = j*d_in`).
    pub offsets: Vec<u32>,
    /// Left-neuron index per edge.
    pub idx: Vec<u32>,
    /// Weight per edge (the Fig. 4 weight memory).
    pub wc: Vec<f32>,
    /// Bias per right neuron.
    pub bias: Vec<f32>,
}

impl SparseLayer {
    /// Build from a connection pattern with He init over the *connected*
    /// fan-in (mean in-degree), constant bias.
    pub fn init_he(p: &Pattern, bias_init: f32, rng: &mut Rng) -> Self {
        let mut offsets = Vec::with_capacity(p.shape.n_right + 1);
        let mut idx = Vec::with_capacity(p.n_edges());
        offsets.push(0u32);
        for edges in &p.in_edges {
            idx.extend_from_slice(edges);
            offsets.push(idx.len() as u32);
        }
        let mean_din = (p.n_edges() as f32 / p.shape.n_right as f32).max(1.0);
        let std = (2.0 / mean_din).sqrt();
        let wc = (0..idx.len()).map(|_| rng.normal() * std).collect();
        SparseLayer {
            n_left: p.shape.n_left,
            n_right: p.shape.n_right,
            offsets,
            idx,
            wc,
            bias: vec![bias_init; p.shape.n_right],
        }
    }

    /// Build from a connection pattern plus dense row-major
    /// `[n_right, n_left]` weights and a bias vector — the compaction
    /// step every dense-parameter surface (runtime values, trained
    /// sessions) uses to enter the CSR kernels. Off-pattern entries of
    /// `dense` are ignored.
    pub fn from_pattern_dense(p: &Pattern, dense: &[f32], bias: &[f32]) -> Self {
        assert_eq!(bias.len(), p.shape.n_right);
        let mut offsets = Vec::with_capacity(p.shape.n_right + 1);
        let mut idx = Vec::with_capacity(p.n_edges());
        offsets.push(0u32);
        for edges in &p.in_edges {
            idx.extend_from_slice(edges);
            offsets.push(idx.len() as u32);
        }
        SparseLayer {
            n_left: p.shape.n_left,
            n_right: p.shape.n_right,
            offsets,
            idx,
            wc: p.compact_weights(dense),
            bias: bias.to_vec(),
        }
    }

    /// Stored edge count `|W_i|`.
    pub fn n_edges(&self) -> usize {
        self.idx.len()
    }

    /// FF (eq. 2a): `h[b, j] = sum_f wc[j, f] * a[b, idx[j, f]] + bias[j]`.
    /// Batch rows are independent, so they are chunked across the
    /// [`parallel`] thread pool.
    pub fn forward(&self, a: &[f32], batch: usize, out: &mut [f32]) {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_right);
        let work = self.n_edges().max(1);
        parallel::par_rows(out, self.n_right, work, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(self.n_right).enumerate() {
                let bi = row0 + li;
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                for j in 0..self.n_right {
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    let mut acc = self.bias[j];
                    for e in lo..hi {
                        acc += self.wc[e] * ar[self.idx[e] as usize];
                    }
                    or[j] = acc;
                }
            }
        });
    }

    /// FF (eq. 2a) with a run-time activation mask: edges whose left
    /// neuron is inactive are *skipped* in place, inside the same CSR
    /// edge order as [`SparseLayer::forward`] — an all-ones mask
    /// therefore reproduces the unmasked kernel bit for bit (f32
    /// summation order is preserved), and a sparse mask does
    /// `density * |W_i|` MACs instead of `|W_i|`. `active` is row-major
    /// `[batch * n_left]`.
    pub fn forward_masked(&self, a: &[f32], batch: usize, active: &[bool], out: &mut [f32]) {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(active.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_right);
        let work = self.n_edges().max(1);
        parallel::par_rows(out, self.n_right, work, |row0, chunk| {
            for (li, or) in chunk.chunks_mut(self.n_right).enumerate() {
                let bi = row0 + li;
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
                for j in 0..self.n_right {
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    let mut acc = self.bias[j];
                    for e in lo..hi {
                        let k = self.idx[e] as usize;
                        if !mr[k] {
                            continue;
                        }
                        acc += self.wc[e] * ar[k];
                    }
                    or[j] = acc;
                }
            }
        });
    }

    /// BP (eq. 3b inner sum): `da[b, k] = sum_j wc[j,.] delta[b, j]`
    /// scattered over idx. Caller applies the activation-derivative
    /// product. The scatter stays within one batch row, so rows
    /// parallelize cleanly.
    pub fn backprop(&self, delta: &[f32], batch: usize, out: &mut [f32]) {
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(out.len(), batch * self.n_left);
        let work = self.n_edges().max(1);
        parallel::par_rows(out, self.n_left, work, |row0, chunk| {
            chunk.fill(0.0);
            for (li, or) in chunk.chunks_mut(self.n_left).enumerate() {
                let bi = row0 + li;
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                for j in 0..self.n_right {
                    let dv = dr[j];
                    if dv == 0.0 {
                        continue;
                    }
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        or[self.idx[e] as usize] += self.wc[e] * dv;
                    }
                }
            }
        });
    }

    /// BP (eq. 3b inner sum) with a run-time activation mask: the
    /// scatter skips inactive left neurons — their (zeroed) activations
    /// contributed nothing forward, so no gradient flows back through
    /// them. Same edge order as [`SparseLayer::backprop`]; an all-ones
    /// mask is bit-for-bit identical.
    pub fn backprop_masked(&self, delta: &[f32], batch: usize, active: &[bool], out: &mut [f32]) {
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(active.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_left);
        let work = self.n_edges().max(1);
        parallel::par_rows(out, self.n_left, work, |row0, chunk| {
            chunk.fill(0.0);
            for (li, or) in chunk.chunks_mut(self.n_left).enumerate() {
                let bi = row0 + li;
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
                for j in 0..self.n_right {
                    let dv = dr[j];
                    if dv == 0.0 {
                        continue;
                    }
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        let k = self.idx[e] as usize;
                        if !mr[k] {
                            continue;
                        }
                        or[k] += self.wc[e] * dv;
                    }
                }
            }
        });
    }

    /// UP gradients (eq. 4b): `gwc[e] = sum_b delta[b, j(e)] * a[b, idx[e]]`,
    /// `gb[j] = sum_b delta[b, j]`. Adds the L2 term `2*l2*wc`. The batch
    /// reduction runs on per-thread partial buffers merged at the end.
    pub fn grads(
        &self,
        a: &[f32],
        delta: &[f32],
        batch: usize,
        l2: f32,
        gwc: &mut [f32],
        gb: &mut [f32],
    ) {
        assert_eq!(gwc.len(), self.wc.len());
        assert_eq!(gb.len(), self.n_right);
        let nw = gwc.len();
        let work = self.n_edges().max(1);
        let body = |range: std::ops::Range<usize>, gw: &mut [f32], gbp: &mut [f32]| {
            for bi in range {
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                for j in 0..self.n_right {
                    let dv = dr[j];
                    if dv == 0.0 {
                        continue;
                    }
                    gbp[j] += dv;
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        gw[e] += dv * ar[self.idx[e] as usize];
                    }
                }
            }
        };
        if parallel::threads_for(batch, work) <= 1 {
            // serial fast path: accumulate straight into the caller's
            // buffers, no scratch allocation
            gwc.fill(0.0);
            gb.fill(0.0);
            body(0..batch, gwc, gb);
        } else {
            // one contiguous accumulator [gwc | gb] so a single reduction
            // covers both gradient tensors
            let mut both = vec![0f32; nw + self.n_right];
            parallel::par_batch_reduce(batch, work, &mut both, |range, acc| {
                let (gw, gbp) = acc.split_at_mut(nw);
                body(range, gw, gbp);
            });
            gwc.copy_from_slice(&both[..nw]);
            gb.copy_from_slice(&both[nw..]);
        }
        for (g, &w) in gwc.iter_mut().zip(&self.wc) {
            *g += 2.0 * l2 * w;
        }
    }

    /// UP gradients (eq. 4b) with a run-time activation mask: the
    /// per-edge accumulation skips edges whose left activation the mask
    /// dropped (their `a` term is zero by construction). Bias gradients
    /// and the L2 term are unaffected — the bias input is the constant
    /// 1 and weight decay applies to every stored edge. Same reduction
    /// structure as [`SparseLayer::grads`]; an all-ones mask is
    /// bit-for-bit identical.
    pub fn grads_masked(
        &self,
        a: &[f32],
        delta: &[f32],
        batch: usize,
        active: &[bool],
        l2: f32,
        gwc: &mut [f32],
        gb: &mut [f32],
    ) {
        assert_eq!(gwc.len(), self.wc.len());
        assert_eq!(gb.len(), self.n_right);
        assert_eq!(active.len(), batch * self.n_left);
        let nw = gwc.len();
        let work = self.n_edges().max(1);
        let body = |range: std::ops::Range<usize>, gw: &mut [f32], gbp: &mut [f32]| {
            for bi in range {
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                for j in 0..self.n_right {
                    let dv = dr[j];
                    if dv == 0.0 {
                        continue;
                    }
                    gbp[j] += dv;
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        let k = self.idx[e] as usize;
                        if !mr[k] {
                            continue;
                        }
                        gw[e] += dv * ar[k];
                    }
                }
            }
        };
        if parallel::threads_for(batch, work) <= 1 {
            gwc.fill(0.0);
            gb.fill(0.0);
            body(0..batch, gwc, gb);
        } else {
            let mut both = vec![0f32; nw + self.n_right];
            parallel::par_batch_reduce(batch, work, &mut both, |range, acc| {
                let (gw, gbp) = acc.split_at_mut(nw);
                body(range, gw, gbp);
            });
            gwc.copy_from_slice(&both[..nw]);
            gb.copy_from_slice(&both[nw..]);
        }
        for (g, &w) in gwc.iter_mut().zip(&self.wc) {
            *g += 2.0 * l2 * w;
        }
    }

    /// Densify to row-major `[n_right, n_left]` (for cross-checks and
    /// for loading into the AOT masked-dense artifacts).
    pub fn to_dense(&self) -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0f32; self.n_right * self.n_left];
        let mut m = vec![0f32; self.n_right * self.n_left];
        for j in 0..self.n_right {
            for e in self.offsets[j] as usize..self.offsets[j + 1] as usize {
                let k = self.idx[e] as usize;
                w[j * self.n_left + k] = self.wc[e];
                m[j * self.n_left + k] = 1.0;
            }
        }
        (w, m)
    }
}

/// Whole-network compacted MLP.
#[derive(Clone, Debug)]
pub struct SparseNet {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub layers: Vec<usize>,
    /// One compacted layer per junction.
    pub junctions: Vec<SparseLayer>,
}

/// Gradients in the compacted layout.
pub struct SparseGrads {
    /// Per-edge weight gradients, per junction.
    pub gwc: Vec<Vec<f32>>,
    /// Bias gradients per junction.
    pub gb: Vec<Vec<f32>>,
}

/// Result of one forward+backward pass over the compacted net.
pub struct SparseStepOut {
    /// Mean softmax cross-entropy of the minibatch.
    pub loss: f32,
    /// Correct argmax predictions in the minibatch.
    pub correct: usize,
    /// Loss gradients in the compacted layout (L2 term included).
    pub grads: SparseGrads,
}

impl SparseNet {
    /// He-initialize every junction from `pattern` (constant bias).
    pub fn init_he(pattern: &NetPattern, bias_init: f32, rng: &mut Rng) -> Self {
        let mut layers = vec![pattern.junctions[0].shape.n_left];
        layers.extend(pattern.junctions.iter().map(|p| p.shape.n_right));
        SparseNet {
            layers,
            junctions: pattern
                .junctions
                .iter()
                .map(|p| SparseLayer::init_he(p, bias_init, rng))
                .collect(),
        }
    }

    /// Build a compacted net from a connection pattern plus one
    /// `(dense_weights, bias)` pair per junction (dense row-major
    /// `[n_right, n_left]`) — the single home for the dense-parameter →
    /// CSR compaction used by quantized serving and `train --quant-eval`.
    pub fn from_pattern_dense(pattern: &NetPattern, params: &[(&[f32], &[f32])]) -> Self {
        assert_eq!(params.len(), pattern.junctions.len());
        let mut layers = vec![pattern.junctions[0].shape.n_left];
        layers.extend(pattern.junctions.iter().map(|p| p.shape.n_right));
        SparseNet {
            layers,
            junctions: pattern
                .junctions
                .iter()
                .zip(params)
                .map(|(p, &(w, b))| SparseLayer::from_pattern_dense(p, w, b))
                .collect(),
        }
    }

    /// Total stored edges across every junction.
    pub fn n_edges(&self) -> usize {
        self.junctions.iter().map(|j| j.n_edges()).sum()
    }

    /// Inference pass: logits `[batch, N_L]`.
    pub fn logits(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let mut a = x.to_vec();
        let l = self.junctions.len();
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0f32; batch * junction.n_right];
            junction.forward(&a, batch, &mut h);
            if i != l - 1 {
                super::relu(&mut h);
            }
            a = h;
        }
        a
    }

    /// Forward + backward over a minibatch.
    pub fn step(&self, x: &[f32], y: &[i32], batch: usize, l2: f32) -> SparseStepOut {
        let l = self.junctions.len();
        let classes = *self.layers.last().unwrap();
        // forward, keeping activations and pre-activations
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(l);
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0f32; batch * junction.n_right];
            junction.forward(&acts[i], batch, &mut h);
            pre.push(h.clone());
            if i != l - 1 {
                super::relu(&mut h);
            }
            acts.push(h);
        }
        let (loss, correct, dlogits) = super::softmax_ce(acts.last().unwrap(), y, classes);

        let mut gwc = Vec::with_capacity(l);
        let mut gb = Vec::with_capacity(l);
        for junction in &self.junctions {
            gwc.push(vec![0f32; junction.wc.len()]);
            gb.push(vec![0f32; junction.n_right]);
        }
        let mut dh = dlogits;
        for i in (0..l).rev() {
            let junction = &self.junctions[i];
            junction.grads(&acts[i], &dh, batch, l2, &mut gwc[i], &mut gb[i]);
            if i > 0 {
                let mut da = vec![0f32; batch * junction.n_left];
                junction.backprop(&dh, batch, &mut da);
                for (dv, &hv) in da.iter_mut().zip(&pre[i - 1]) {
                    if hv <= 0.0 {
                        *dv = 0.0;
                    }
                }
                dh = da;
            }
        }
        SparseStepOut {
            loss,
            correct,
            grads: SparseGrads { gwc, gb },
        }
    }

    /// Sparse-sparse inference: every hidden layer's activations go
    /// through `spec`'s top-k / threshold selection and the masked CSR
    /// kernels skip the dropped neurons entirely. The input layer is
    /// never masked (it is data, not an activation the net produced).
    /// Returns the logits plus the achieved activation-density tally —
    /// the gauge the serving metrics surface. A spec that keeps
    /// everything (`topk(k >= width)`, `threshold(0)`) reproduces
    /// [`SparseNet::logits`] bit for bit.
    pub fn logits_act(&self, x: &[f32], batch: usize, spec: &ActSpec) -> (Vec<f32>, ActStats) {
        let l = self.junctions.len();
        let mut stats = ActStats::default();
        let mut a = x.to_vec();
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0f32; batch * junction.n_right];
            if i == 0 {
                junction.forward(&a, batch, &mut h);
            } else {
                let m = spec.mask(&a, junction.n_left, batch, 0);
                stats.merge(m.stats());
                junction.forward_masked(&a, batch, &m.active, &mut h);
            }
            if i != l - 1 {
                super::relu(&mut h);
            }
            a = h;
        }
        (a, stats)
    }

    /// Sparse-sparse inference with *caller-supplied* masks (one per
    /// hidden layer), each checked before use: shape, freshness against
    /// `stamp`, and coverage of every right neuron the pattern
    /// requires. A stale or corrupted mask comes back as a typed
    /// [`ActError`] naming the layer instead of silently wrong logits —
    /// the surface the analyzer's mutation harness drives.
    pub fn logits_masked(
        &self,
        x: &[f32],
        batch: usize,
        masks: &[ActivationMask],
        stamp: u64,
    ) -> Result<Vec<f32>, ActError> {
        let l = self.junctions.len();
        assert_eq!(masks.len(), l.saturating_sub(1), "one mask per hidden layer");
        let mut a = x.to_vec();
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0f32; batch * junction.n_right];
            if i == 0 {
                junction.forward(&a, batch, &mut h);
            } else {
                let m = &masks[i - 1];
                m.verify_shape(i, junction.n_left, batch)?;
                m.verify_fresh(i, stamp)?;
                m.verify_coverage(i, &junction.offsets, &junction.idx, junction.n_right)?;
                junction.forward_masked(&a, batch, &m.active, &mut h);
            }
            if i != l - 1 {
                super::relu(&mut h);
            }
            a = h;
        }
        Ok(a)
    }

    /// Forward + backward with run-time activation sparsity: the masks
    /// built on the forward pass gate the same layers' BP scatter and
    /// UP accumulation, so all three loops do `density * |W_i|` work.
    /// An all-keeping spec reproduces [`SparseNet::step`] bit for bit.
    pub fn step_act(
        &self,
        x: &[f32],
        y: &[i32],
        batch: usize,
        l2: f32,
        spec: &ActSpec,
    ) -> (SparseStepOut, ActStats) {
        let l = self.junctions.len();
        let classes = *self.layers.last().unwrap();
        let mut stats = ActStats::default();
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(l);
        let mut masks: Vec<ActivationMask> = Vec::with_capacity(l.saturating_sub(1));
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0f32; batch * junction.n_right];
            if i == 0 {
                junction.forward(&acts[i], batch, &mut h);
            } else {
                let m = spec.mask(&acts[i], junction.n_left, batch, 0);
                stats.merge(m.stats());
                junction.forward_masked(&acts[i], batch, &m.active, &mut h);
                masks.push(m);
            }
            pre.push(h.clone());
            if i != l - 1 {
                super::relu(&mut h);
            }
            acts.push(h);
        }
        let (loss, correct, dlogits) = super::softmax_ce(acts.last().unwrap(), y, classes);

        let mut gwc = Vec::with_capacity(l);
        let mut gb = Vec::with_capacity(l);
        for junction in &self.junctions {
            gwc.push(vec![0f32; junction.wc.len()]);
            gb.push(vec![0f32; junction.n_right]);
        }
        let mut dh = dlogits;
        for i in (0..l).rev() {
            let junction = &self.junctions[i];
            if i == 0 {
                junction.grads(&acts[i], &dh, batch, l2, &mut gwc[i], &mut gb[i]);
            } else {
                junction.grads_masked(
                    &acts[i],
                    &dh,
                    batch,
                    &masks[i - 1].active,
                    l2,
                    &mut gwc[i],
                    &mut gb[i],
                );
                let mut da = vec![0f32; batch * junction.n_left];
                junction.backprop_masked(&dh, batch, &masks[i - 1].active, &mut da);
                for (dv, &hv) in da.iter_mut().zip(&pre[i - 1]) {
                    if hv <= 0.0 {
                        *dv = 0.0;
                    }
                }
                dh = da;
            }
        }
        (
            SparseStepOut {
                loss,
                correct,
                grads: SparseGrads { gwc, gb },
            },
            stats,
        )
    }

    /// Classification accuracy under an activation-sparsity spec (the
    /// equal-accuracy axis of the sparse-sparse benches).
    pub fn accuracy_act(&self, x: &[f32], y: &[i32], spec: &ActSpec) -> f64 {
        let batch = y.len();
        let classes = *self.layers.last().unwrap();
        let (logits, _) = self.logits_act(x, batch, spec);
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / batch as f64
    }

    /// Classification accuracy over one batch.
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let batch = y.len();
        let classes = *self.layers.last().unwrap();
        let logits = self.logits(x, batch);
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dense::DenseNet;
    use crate::sparsity::config::{DoutConfig, NetConfig};
    use crate::sparsity::{generate, Method};

    fn setup(seed: u64) -> (SparseNet, DenseNet, Vec<f32>, Vec<i32>) {
        let net = NetConfig::new(vec![20, 12, 6]);
        let dout = DoutConfig(vec![6, 3]);
        let mut rng = Rng::new(seed);
        let pattern = generate(Method::Structured, &net, &dout, None, &mut rng);
        let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
        // mirror into a dense net with identical weights + masks
        let mut dnet = DenseNet::init_he(&[20, 12, 6], 0.1, &mut rng);
        let mut masks = Vec::new();
        for (i, j) in snet.junctions.iter().enumerate() {
            let (w, m) = j.to_dense();
            dnet.w[i] = w;
            dnet.b[i] = j.bias.clone();
            masks.push(m);
        }
        dnet.set_masks(masks);
        let x: Vec<f32> = (0..8 * 20).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..8).map(|_| rng.below(6) as i32).collect();
        (snet, dnet, x, y)
    }

    #[test]
    fn sparse_forward_matches_masked_dense() {
        let (snet, dnet, x, _) = setup(0);
        let ls = snet.logits(&x, 8);
        let ld = dnet.logits(&x, 8);
        for (a, b) in ls.iter().zip(&ld) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_grads_match_masked_dense() {
        let (snet, dnet, x, y) = setup(1);
        let so = snet.step(&x, &y, 8, 0.01);
        let dor = dnet.step(&x, &y, 8, 0.01, None);
        assert!((so.loss - dor.loss).abs() < 1e-5);
        assert_eq!(so.correct, dor.correct);
        for (i, j) in snet.junctions.iter().enumerate() {
            // compacted grads scattered to dense must equal the dense grads
            let nl = j.n_left;
            for jr in 0..j.n_right {
                for e in j.offsets[jr] as usize..j.offsets[jr + 1] as usize {
                    let k = j.idx[e] as usize;
                    let dg = dor.grads.gw[i][jr * nl + k];
                    assert!(
                        (so.grads.gwc[i][e] - dg).abs() < 1e-4,
                        "junction {i} edge {e}: {} vs {dg}",
                        so.grads.gwc[i][e]
                    );
                }
            }
            for (a, b) in so.grads.gb[i].iter().zip(&dor.grads.gb[i]) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn all_ones_mask_is_bit_for_bit() {
        use crate::nn::actsparse::ActSpec;
        let (snet, _, x, y) = setup(4);
        let keep_all = ActSpec::top_k(usize::MAX);
        let (la, stats) = snet.logits_act(&x, 8, &keep_all);
        let ld = snet.logits(&x, 8);
        assert_eq!(la, ld, "all-keeping spec must be bit-identical");
        assert!((stats.density() - 1.0).abs() < 1e-12);
        let (sa, _) = snet.step_act(&x, &y, 8, 0.01, &keep_all);
        let sd = snet.step(&x, &y, 8, 0.01);
        assert_eq!(sa.loss.to_bits(), sd.loss.to_bits());
        assert_eq!(sa.correct, sd.correct);
        for (ga, gd) in sa.grads.gwc.iter().zip(&sd.grads.gwc) {
            assert_eq!(ga, gd);
        }
    }

    #[test]
    fn masked_forward_equals_zeroed_activations() {
        // the masked kernel must compute exactly the CSR sum with the
        // inactive terms absent, in the original edge order
        let (snet, _, x, _) = setup(5);
        let j = &snet.junctions[0];
        let mut active = vec![true; 8 * j.n_left];
        for (i, a) in active.iter_mut().enumerate() {
            if i % 3 == 0 {
                *a = false;
            }
        }
        let mut out = vec![0f32; 8 * j.n_right];
        j.forward_masked(&x, 8, &active, &mut out);
        // reference: same CSR order, inactive contributions skipped
        for bi in 0..8 {
            let ar = &x[bi * j.n_left..(bi + 1) * j.n_left];
            let mr = &active[bi * j.n_left..(bi + 1) * j.n_left];
            for jr in 0..j.n_right {
                let mut acc = j.bias[jr];
                for e in j.offsets[jr] as usize..j.offsets[jr + 1] as usize {
                    let k = j.idx[e] as usize;
                    if mr[k] {
                        acc += j.wc[e] * ar[k];
                    }
                }
                assert_eq!(acc.to_bits(), out[bi * j.n_right + jr].to_bits());
            }
        }
    }

    #[test]
    fn edge_count_matches_pattern() {
        let (snet, _, _, _) = setup(2);
        assert_eq!(snet.n_edges(), 20 * 6 + 12 * 3);
    }

    #[test]
    fn variable_degree_csr_roundtrip() {
        // non-uniform in-degree (random pattern) works through CSR
        let net = NetConfig::new(vec![30, 10, 5]);
        let mut rng = Rng::new(3);
        let pattern = generate(
            Method::Random,
            &net,
            &DoutConfig(vec![3, 2]),
            None,
            &mut rng,
        );
        let snet = SparseNet::init_he(&pattern, 0.0, &mut rng);
        assert_eq!(snet.n_edges(), 30 * 3 + 10 * 2);
        let x: Vec<f32> = (0..4 * 30).map(|_| rng.normal()).collect();
        let logits = snet.logits(&x, 4);
        assert_eq!(logits.len(), 4 * 5);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
