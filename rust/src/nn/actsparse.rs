//! Activation sparsity for the pre-defined sparse execution stack.
//!
//! The source paper prunes *weights* ahead of time; "Two Sparsities Are
//! Better Than One" (arXiv 2112.13896) shows the gains multiply when a
//! run-time *activation* mask composes with the pre-defined pattern, and
//! arXiv 1806.01087 shows the hardware payoff of skipping inactive
//! operands in exactly the FF/BP/UP loops this crate models. This module
//! provides the mask itself:
//!
//! - [`ActMode`] / [`ActSpec`]: top-k or thresholded selection, applied
//!   per minibatch row to a layer's left activations;
//! - [`ActivationMask`]: the row-major boolean mask plus a batch stamp
//!   (so reuse across batches is a typed error, not silent wrongness);
//! - [`PackedRow`]: a packed, complementary-sparsity-style index layout
//!   whose wave-level non-overlap is *guaranteed* by the z-regular
//!   banking of [`crate::hw::zconfig`] (Appendix B: `z | N_left`, bank
//!   of neuron `n` is `n mod z`), verified by [`PackedRow::verify`].
//!
//! The masked FF/BP/UP kernels themselves live next to their dense-
//! activation twins in [`crate::nn::sparse`] and [`crate::nn::fixed`];
//! they *skip* inactive left neurons in place inside the existing CSR
//! edge order, so an all-ones mask reproduces the unmasked kernels
//! bit for bit (f32 summation order is preserved, and the Qm.n i64
//! accumulation is exact either way).

use std::fmt;

use crate::hw::zconfig;

/// Selection rule for an activation mask.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActMode {
    /// Keep the `k` largest-magnitude activations per row. Ties break
    /// toward the lower neuron index, so selection is deterministic.
    TopK(usize),
    /// Keep every activation with magnitude at least `t`.
    Threshold(f32),
}

/// An activation-sparsity request: one selection rule applied to every
/// hidden layer of a net. This is the type the manifest's
/// `"act_sparsity"` key parses into and the serving stack plumbs
/// through [`crate::coordinator::ModelSpec`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActSpec {
    /// Selection rule applied to each hidden layer's activations.
    pub mode: ActMode,
}

impl ActSpec {
    /// Top-k selection: keep the `k` largest-magnitude activations.
    pub fn top_k(k: usize) -> Self {
        ActSpec { mode: ActMode::TopK(k) }
    }

    /// Threshold selection: keep magnitudes at least `t`.
    pub fn threshold(t: f32) -> Self {
        ActSpec { mode: ActMode::Threshold(t) }
    }

    /// Build the mask for one layer's activations under this spec.
    pub fn mask(&self, acts: &[f32], n: usize, batch: usize, stamp: u64) -> ActivationMask {
        match self.mode {
            ActMode::TopK(k) => ActivationMask::top_k(acts, n, batch, k, stamp),
            ActMode::Threshold(t) => ActivationMask::threshold(acts, n, batch, t, stamp),
        }
    }
}

impl fmt::Display for ActSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.mode {
            ActMode::TopK(k) => write!(f, "topk({k})"),
            ActMode::Threshold(t) => write!(f, "threshold({t})"),
        }
    }
}

/// Typed activation-sparsity failures. Every variant names the layer it
/// was detected on — the analyzer's mutation harness pins that a
/// corrupted mask is *caught*, not silently multiplied through.
#[derive(Debug, Clone, PartialEq)]
pub enum ActError {
    /// The bank count does not divide the layer width, so the z-regular
    /// packing argument (Appendix B) does not apply.
    NotDividing {
        /// Layer the packing was requested for.
        layer: usize,
        /// Requested bank count.
        z: usize,
        /// Layer width it fails to divide.
        n: usize,
    },
    /// Two packed indices in one wave map to the same bank.
    Overlap {
        /// Layer the packed row belongs to.
        layer: usize,
        /// Wave containing the collision.
        wave: usize,
        /// Bank claimed twice.
        bank: usize,
    },
    /// A packed index is outside the layer.
    OutOfRange {
        /// Layer the packed row belongs to.
        layer: usize,
        /// The offending index.
        index: u32,
        /// Layer width.
        n: usize,
    },
    /// An index appears in more than one wave of the same row.
    Duplicate {
        /// Layer the packed row belongs to.
        layer: usize,
        /// The repeated index.
        index: u32,
    },
    /// The mask was built for a different batch than it is being used
    /// on (reuse across batches silently freezes the selection).
    Stale {
        /// Layer the mask is applied to.
        layer: usize,
        /// Stamp the mask carries.
        have: u64,
        /// Stamp of the batch being executed.
        want: u64,
    },
    /// The mask drops *every* in-edge of a right neuron the pattern
    /// requires, so that neuron would silently compute bias-only.
    Uncovered {
        /// Layer whose junction loses the neuron.
        layer: usize,
        /// The right neuron with no surviving in-edges.
        neuron: usize,
    },
    /// The mask's shape does not match the layer it is applied to.
    BadShape {
        /// Layer the mask is applied to.
        layer: usize,
        /// Slots the layer expects (`n * batch`).
        want: usize,
        /// Slots the mask carries.
        have: usize,
    },
}

impl fmt::Display for ActError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ActError::NotDividing { layer, z, n } => {
                write!(f, "layer {layer}: z = {z} does not divide layer width {n}")
            }
            ActError::Overlap { layer, wave, bank } => write!(
                f,
                "layer {layer}: packed wave {wave} claims bank {bank} twice"
            ),
            ActError::OutOfRange { layer, index, n } => {
                write!(f, "layer {layer}: packed index {index} outside width {n}")
            }
            ActError::Duplicate { layer, index } => {
                write!(f, "layer {layer}: packed index {index} appears in two waves")
            }
            ActError::Stale { layer, have, want } => write!(
                f,
                "layer {layer}: stale activation mask (built for batch {have}, executing batch {want})"
            ),
            ActError::Uncovered { layer, neuron } => write!(
                f,
                "layer {layer}: mask drops every in-edge of right neuron {neuron}"
            ),
            ActError::BadShape { layer, want, have } => write!(
                f,
                "layer {layer}: mask has {have} slots, layer expects {want}"
            ),
        }
    }
}

impl std::error::Error for ActError {}

/// Achieved activation-density tally across masked layers — the number
/// the serving metrics surface as a gauge.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ActStats {
    /// Left-neuron slots the mask kept active.
    pub active: u64,
    /// Left-neuron slots considered.
    pub total: u64,
}

impl ActStats {
    /// Fraction of slots kept (1.0 when nothing was masked).
    pub fn density(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.active as f64 / self.total as f64
        }
    }

    /// Fold another tally into this one.
    pub fn merge(&mut self, other: ActStats) {
        self.active += other.active;
        self.total += other.total;
    }
}

/// A per-row boolean activation mask over one layer's left neurons,
/// stamped with the batch it was built for.
#[derive(Clone, Debug, PartialEq)]
pub struct ActivationMask {
    /// Neurons per row.
    pub n: usize,
    /// Rows (minibatch size).
    pub batch: usize,
    /// Row-major `[batch * n]` activity flags.
    pub active: Vec<bool>,
    /// Batch stamp the mask was built for (staleness detection).
    pub stamp: u64,
}

impl ActivationMask {
    /// The identity mask: every neuron active. Masked kernels fed this
    /// reproduce their dense-activation twins bit for bit.
    pub fn all_ones(n: usize, batch: usize, stamp: u64) -> Self {
        ActivationMask {
            n,
            batch,
            active: vec![true; n * batch],
            stamp,
        }
    }

    /// Keep the `k` largest-magnitude activations of each row. Ties
    /// break toward the lower index (deterministic; NaN magnitudes sort
    /// via `total_cmp`, i.e. after every finite magnitude).
    pub fn top_k(acts: &[f32], n: usize, batch: usize, k: usize, stamp: u64) -> Self {
        assert_eq!(acts.len(), n * batch, "activation buffer shape");
        let mut active = vec![false; n * batch];
        if k >= n {
            active.fill(true);
            return ActivationMask { n, batch, active, stamp };
        }
        let mut order: Vec<u32> = Vec::with_capacity(n);
        for r in 0..batch {
            let row = &acts[r * n..(r + 1) * n];
            order.clear();
            order.extend(0..n as u32);
            order.sort_unstable_by(|&a, &b| {
                let (ma, mb) = (row[a as usize].abs(), row[b as usize].abs());
                mb.total_cmp(&ma).then(a.cmp(&b))
            });
            for &i in &order[..k] {
                active[r * n + i as usize] = true;
            }
        }
        ActivationMask { n, batch, active, stamp }
    }

    /// Keep every activation with magnitude at least `t`. Monotone: a
    /// larger threshold never activates a neuron a smaller one dropped.
    pub fn threshold(acts: &[f32], n: usize, batch: usize, t: f32, stamp: u64) -> Self {
        assert_eq!(acts.len(), n * batch, "activation buffer shape");
        let active = acts.iter().map(|a| a.abs() >= t).collect();
        ActivationMask { n, batch, active, stamp }
    }

    /// One row's flags.
    pub fn row(&self, r: usize) -> &[bool] {
        &self.active[r * self.n..(r + 1) * self.n]
    }

    /// Number of active slots across all rows.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Achieved density of this mask.
    pub fn stats(&self) -> ActStats {
        ActStats {
            active: self.active_count() as u64,
            total: self.active.len() as u64,
        }
    }

    /// Refuse a mask built for a different batch stamp.
    pub fn verify_fresh(&self, layer: usize, stamp: u64) -> Result<(), ActError> {
        if self.stamp != stamp {
            return Err(ActError::Stale {
                layer,
                have: self.stamp,
                want: stamp,
            });
        }
        Ok(())
    }

    /// Refuse a mask whose shape does not match the layer.
    pub fn verify_shape(&self, layer: usize, n: usize, batch: usize) -> Result<(), ActError> {
        if self.n != n || self.batch != batch || self.active.len() != n * batch {
            return Err(ActError::BadShape {
                layer,
                want: n * batch,
                have: self.active.len(),
            });
        }
        Ok(())
    }

    /// Refuse a mask that drops *every* in-edge of some right neuron of
    /// the junction's CSR pattern (`offsets`/`idx` as stored by the
    /// compacted layers): the pattern requires the neuron, the mask
    /// would silently reduce it to its bias.
    pub fn verify_coverage(
        &self,
        layer: usize,
        offsets: &[u32],
        idx: &[u32],
        n_right: usize,
    ) -> Result<(), ActError> {
        for r in 0..self.batch {
            let row = self.row(r);
            for j in 0..n_right {
                let (lo, hi) = (offsets[j] as usize, offsets[j + 1] as usize);
                if lo != hi && !idx[lo..hi].iter().any(|&k| row[k as usize]) {
                    return Err(ActError::Uncovered { layer, neuron: j });
                }
            }
        }
        Ok(())
    }

    /// Pack each row into the z-banked wave layout. Requires the
    /// Appendix-B regularity `z | n`; the result is non-overlapping by
    /// construction (see [`PackedRow`]).
    pub fn pack(&self, layer: usize, z: usize) -> Result<Vec<PackedRow>, ActError> {
        if z == 0 || self.n % z != 0 {
            return Err(ActError::NotDividing { layer, z, n: self.n });
        }
        let waves_per_row = self.n / z;
        let mut rows = Vec::with_capacity(self.batch);
        for r in 0..self.batch {
            let row = self.row(r);
            let mut waves = vec![Vec::new(); waves_per_row];
            for (i, &a) in row.iter().enumerate() {
                if a {
                    waves[i / z].push(i as u32);
                }
            }
            rows.push(PackedRow { z, waves });
        }
        Ok(rows)
    }
}

/// One row's packed, complementary-sparsity-style index layout: the
/// active indices grouped into *waves*, where wave `w` holds the active
/// subset of neurons `w*z .. (w+1)*z`. Within that range each neuron
/// maps to a distinct bank (`bank(n) = n mod z`, and `z | n_left` per
/// Appendix B), so a wave can issue one fetch per bank with **no
/// overlap by construction** — the complementary-sparsity trick riding
/// on the z-regular structure instead of a learned permutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedRow {
    /// Bank count (the junction's z; divides the layer width).
    pub z: usize,
    /// Waves of active indices, ascending within each wave.
    pub waves: Vec<Vec<u32>>,
}

impl PackedRow {
    /// Number of packed (active) indices.
    pub fn active_count(&self) -> usize {
        self.waves.iter().map(Vec::len).sum()
    }

    /// Cycles a banked fetch of this row needs: one per non-empty wave.
    pub fn fetch_waves(&self) -> usize {
        self.waves.iter().filter(|w| !w.is_empty()).count()
    }

    /// Check the layout invariants the z-regular construction
    /// guarantees: every index in range, no bank claimed twice within a
    /// wave, no index in two waves. A violation is exactly the
    /// "overlapping packed index" corruption the mutation harness
    /// injects, and comes back as a typed [`ActError`] naming the
    /// layer, wave and bank.
    pub fn verify(&self, layer: usize, n: usize) -> Result<(), ActError> {
        let mut seen = vec![false; n];
        let mut banks = vec![usize::MAX; self.z];
        for (w, wave) in self.waves.iter().enumerate() {
            for &i in wave {
                if i as usize >= n {
                    return Err(ActError::OutOfRange { layer, index: i, n });
                }
                if seen[i as usize] {
                    return Err(ActError::Duplicate { layer, index: i });
                }
                seen[i as usize] = true;
                let bank = zconfig::bank_of(i as usize, self.z);
                if banks[bank] == w {
                    return Err(ActError::Overlap { layer, wave: w, bank });
                }
                banks[bank] = w;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_keeps_exactly_k_with_deterministic_ties() {
        let acts = [0.5, -0.5, 0.25, 0.0];
        let m = ActivationMask::top_k(&acts, 4, 1, 2, 0);
        // |0.5| ties with |-0.5|: both beat 0.25, lower indices win
        assert_eq!(m.active, vec![true, true, false, false]);
        assert_eq!(m.active_count(), 2);
        // k >= n keeps everything
        let m = ActivationMask::top_k(&acts, 4, 1, 9, 0);
        assert_eq!(m.active_count(), 4);
    }

    #[test]
    fn threshold_is_monotone() {
        let acts = [0.1, -0.4, 0.9, 0.0];
        let lo = ActivationMask::threshold(&acts, 4, 1, 0.2, 0);
        let hi = ActivationMask::threshold(&acts, 4, 1, 0.5, 0);
        for (a, b) in hi.active.iter().zip(&lo.active) {
            assert!(!a | b, "raising the threshold must not activate");
        }
        assert_eq!(lo.active, vec![false, true, true, false]);
        assert_eq!(hi.active, vec![false, false, true, false]);
    }

    #[test]
    fn packing_respects_the_z_banks_and_verifies() {
        let acts = [1.0, 0.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0];
        let m = ActivationMask::threshold(&acts, 8, 1, 0.5, 0);
        let rows = m.pack(0, 4).expect("4 divides 8");
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.waves, vec![vec![0, 2], vec![4, 5]]);
        assert_eq!(row.active_count(), 4);
        assert_eq!(row.fetch_waves(), 2);
        row.verify(0, 8).expect("constructed layout is clash-free");
        // z must divide n
        assert_eq!(
            m.pack(3, 3),
            Err(ActError::NotDividing { layer: 3, z: 3, n: 8 })
        );
    }

    #[test]
    fn injected_overlap_is_caught_with_wave_and_bank() {
        let mut row = PackedRow {
            z: 4,
            waves: vec![vec![0, 2], vec![4, 5]],
        };
        row.waves[0][1] = 4; // banks 0 and 0 in wave 0
        assert_eq!(
            row.verify(1, 8),
            Err(ActError::Overlap { layer: 1, wave: 0, bank: 0 })
        );
    }

    #[test]
    fn duplicate_and_out_of_range_indices_are_caught() {
        let dup = PackedRow {
            z: 4,
            waves: vec![vec![1], vec![1]],
        };
        assert_eq!(dup.verify(0, 8), Err(ActError::Duplicate { layer: 0, index: 1 }));
        let oob = PackedRow {
            z: 4,
            waves: vec![vec![9]],
        };
        assert_eq!(
            oob.verify(2, 8),
            Err(ActError::OutOfRange { layer: 2, index: 9, n: 8 })
        );
    }

    #[test]
    fn stale_masks_and_bad_shapes_are_refused() {
        let m = ActivationMask::all_ones(4, 2, 7);
        m.verify_fresh(0, 7).expect("same stamp is fresh");
        assert_eq!(
            m.verify_fresh(2, 8),
            Err(ActError::Stale { layer: 2, have: 7, want: 8 })
        );
        m.verify_shape(0, 4, 2).expect("shape matches");
        assert_eq!(
            m.verify_shape(1, 4, 3),
            Err(ActError::BadShape { layer: 1, want: 12, have: 8 })
        );
    }

    #[test]
    fn dropped_required_neuron_is_caught_by_coverage() {
        // CSR: right neuron 0 reads {0, 1}, right neuron 1 reads {2, 3}
        let offsets = [0u32, 2, 4];
        let idx = [0u32, 1, 2, 3];
        let mut m = ActivationMask::all_ones(4, 1, 0);
        m.verify_coverage(0, &offsets, &idx, 2).expect("all-ones covers");
        m.active[2] = false;
        m.verify_coverage(0, &offsets, &idx, 2).expect("one in-edge left");
        m.active[3] = false;
        assert_eq!(
            m.verify_coverage(5, &offsets, &idx, 2),
            Err(ActError::Uncovered { layer: 5, neuron: 1 })
        );
    }

    #[test]
    fn spec_dispatch_and_stats() {
        let acts = [0.9, 0.1, -0.8, 0.2];
        let spec = ActSpec::top_k(1);
        let m = spec.mask(&acts, 4, 1, 3);
        assert_eq!(m.active, vec![true, false, false, false]);
        assert_eq!(m.stamp, 3);
        let spec = ActSpec::threshold(0.5);
        let m = spec.mask(&acts, 4, 1, 3);
        assert_eq!(m.active, vec![true, false, true, false]);
        let s = m.stats();
        assert_eq!(s, ActStats { active: 2, total: 4 });
        assert!((s.density() - 0.5).abs() < 1e-12);
        assert_eq!(format!("{}", ActSpec::top_k(8)), "topk(8)");
        assert_eq!(format!("{}", ActSpec::threshold(0.25)), "threshold(0.25)");
    }
}
