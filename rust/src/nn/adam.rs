//! Adam [46] with the paper's configuration (Sec. IV-A): defaults
//! beta1=0.9, beta2=0.999, eps=1e-8, lr decay 1e-5. The math matches
//! python/compile/model.py::adam_step exactly (cross-checked in the
//! runtime integration tests).

/// Optimizer hyperparameters (defaults = the paper's configuration).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    /// Base learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator stabilizer.
    pub eps: f32,
    /// Learning-rate decay: effective lr at step t is `lr / (1 + decay*(t-1))`.
    pub decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            decay: 1e-5,
        }
    }
}

/// First/second-moment state for one parameter tensor.
#[derive(Clone, Debug)]
pub struct AdamState {
    /// First-moment (mean) accumulator per parameter.
    pub m: Vec<f32>,
    /// Second-moment (uncentered variance) accumulator per parameter.
    pub v: Vec<f32>,
}

impl AdamState {
    /// Fresh zeroed state for an `n`-element parameter tensor.
    pub fn zeros(n: usize) -> Self {
        AdamState {
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// In-place Adam update of `p` with gradient `g` at step `t` (1-based).
    pub fn step(&mut self, p: &mut [f32], g: &[f32], t: f32, cfg: &AdamConfig) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.m.len());
        let lr_t = cfg.lr / (1.0 + cfg.decay * (t - 1.0));
        let bc1 = 1.0 - cfg.beta1.powf(t);
        let bc2 = 1.0 - cfg.beta2.powf(t);
        for i in 0..p.len() {
            let m = cfg.beta1 * self.m[i] + (1.0 - cfg.beta1) * g[i];
            let v = cfg.beta2 * self.v[i] + (1.0 - cfg.beta2) * g[i] * g[i];
            self.m[i] = m;
            self.v[i] = v;
            p[i] -= lr_t * (m / bc1) / ((v / bc2).sqrt() + cfg.eps);
        }
    }
}

/// Per-junction optimizer over (weight, bias) tensor pairs.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Hyperparameters shared by every tensor.
    pub cfg: AdamConfig,
    /// Step counter (1-based after the first [`Adam::step`]).
    pub t: f32,
    /// Per-junction (weight, bias) moment states.
    pub states: Vec<(AdamState, AdamState)>,
}

impl Adam {
    /// Zeroed optimizer for junctions with `(weight_len, bias_len)` shapes.
    pub fn new(cfg: AdamConfig, shapes: &[(usize, usize)]) -> Self {
        Adam {
            cfg,
            t: 0.0,
            states: shapes
                .iter()
                .map(|&(nw, nb)| (AdamState::zeros(nw), AdamState::zeros(nb)))
                .collect(),
        }
    }

    /// One optimization step over all junctions.
    pub fn step(
        &mut self,
        w: &mut [Vec<f32>],
        b: &mut [Vec<f32>],
        gw: &[Vec<f32>],
        gb: &[Vec<f32>],
    ) {
        self.t += 1.0;
        for i in 0..w.len() {
            let (sw, sb) = &mut self.states[i];
            sw.step(&mut w[i], &gw[i], self.t, &self.cfg);
            sb.step(&mut b[i], &gb[i], self.t, &self.cfg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_formula() {
        // mirrors python/tests/test_model.py::test_adam_step_matches_reference_formula
        let mut st = AdamState {
            m: vec![0.01, 0.0, 0.02],
            v: vec![0.001, 0.0, 0.002],
        };
        let mut p = vec![1.0, -2.0, 0.5];
        let g = vec![0.1, 0.2, -0.3];
        let cfg = AdamConfig {
            lr: 1e-2,
            decay: 0.0,
            ..Default::default()
        };
        st.step(&mut p, &g, 3.0, &cfg);
        let m_ref: Vec<f32> = vec![0.9 * 0.01 + 0.1 * 0.1, 0.02, 0.9 * 0.02 - 0.1 * 0.3];
        for i in 0..3 {
            let v_ref = 0.999 * [0.001, 0.0, 0.002][i] + 0.001 * g[i] * g[i];
            let mhat = m_ref[i] / (1.0 - 0.9f32.powi(3));
            let vhat = v_ref / (1.0 - 0.999f32.powi(3));
            let p_ref = [1.0, -2.0, 0.5][i] - 1e-2 * mhat / (vhat.sqrt() + 1e-8);
            assert!((p[i] - p_ref).abs() < 1e-6, "i={i}: {} vs {p_ref}", p[i]);
            assert!((st.m[i] - m_ref[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn lr_decay_schedule() {
        // effective lr at step t is lr / (1 + decay*(t-1)): the same state
        // and gradient at t=11 with decay=0.1 moves exactly half as far as
        // with decay=0.
        let take_step = |decay: f32| {
            let cfg = AdamConfig {
                lr: 1.0,
                decay,
                ..Default::default()
            };
            let mut st = AdamState::zeros(1);
            let mut p = vec![0.0f32];
            st.step(&mut p, &[1.0], 11.0, &cfg);
            -p[0]
        };
        let no_decay = take_step(0.0);
        let with_decay = take_step(0.1);
        assert!((with_decay - no_decay / 2.0).abs() < 1e-6, "{with_decay} vs {no_decay}");
        // t=1 with bias correction and constant grad: step magnitude = lr
        let cfg = AdamConfig { lr: 1.0, decay: 0.0, ..Default::default() };
        let mut st = AdamState::zeros(1);
        let mut p = vec![0.0f32];
        st.step(&mut p, &[1.0], 1.0, &cfg);
        assert!((-p[0] - 1.0).abs() < 1e-3, "{}", -p[0]);
    }

    #[test]
    fn zero_grad_zero_update() {
        let cfg = AdamConfig::default();
        let mut st = AdamState::zeros(4);
        let mut p = vec![1.0, 2.0, 3.0, 4.0];
        let orig = p.clone();
        for t in 1..5 {
            st.step(&mut p, &[0.0; 4], t as f32, &cfg);
        }
        assert_eq!(p, orig, "excluded edges with zero grads must not move");
    }

    #[test]
    fn multi_tensor_wrapper() {
        let mut opt = Adam::new(AdamConfig::default(), &[(4, 2), (3, 1)]);
        let mut w = vec![vec![1.0; 4], vec![1.0; 3]];
        let mut b = vec![vec![0.0; 2], vec![0.0; 1]];
        let gw = vec![vec![1.0; 4], vec![-1.0; 3]];
        let gb = vec![vec![0.5; 2], vec![0.0; 1]];
        opt.step(&mut w, &mut b, &gw, &gb);
        assert!(w[0][0] < 1.0);
        assert!(w[1][0] > 1.0);
        assert!(b[0][0] < 0.0);
        assert_eq!(b[1][0], 0.0);
        assert_eq!(opt.t, 1.0);
    }
}
