//! Software pipelined training engine: the Sec. III-A junction pipeline +
//! FF/BP/UP operational parallelism executed on real minibatches, with
//! `hw` as the executable source of truth for the schedule.
//!
//! Minibatches stream through the network the way inputs stream through
//! the paper's Fig. 2c timeline: junction `i` runs FF on batch `t` while
//! junction `i-1` is still running BP/UP on batch `t-1`. The timetable is
//! [`crate::hw::pipeline::Pipeline`] itself — `FF_i(n)` at junction cycle
//! `tau = n·k + i`, `BP_i(n)`/`UP_i(n)` at `tau = n·k + 2L - i + 1` —
//! generalized by an admission stride `k`: at `k = 1` every junction
//! cycle admits a new minibatch (the full hardware schedule, up to `2L`
//! batches in flight and the paper's Sec. III-D weight staleness of
//! `2(L-i)+1` updates at junction i); at `k = 2L` a batch finishes
//! completely before the next is admitted, which makes the run
//! *bit-for-bit identical* to the sequential [`crate::nn::trainer`] loop
//! (staleness 0). [`PipelineConfig::depth`] picks the point on that line.
//!
//! All operations scheduled in one junction cycle are mutually
//! independent (they touch different in-flight batches, and weight
//! updates are deferred to the end of the cycle exactly like the
//! hardware's end-of-cycle write-back), so each cycle fans its
//! operations out over scoped stage threads; the per-op kernels are the
//! same batch-parallel [`crate::nn::sparse`] kernels the sequential
//! trainer uses, with the kernel-thread budget divided by
//! [`crate::util::parallel::worker_thread_budget`] so stage count ×
//! kernel threads stays within the machine budget.
//!
//! [`MultiPipelinedTrainer`] adds the *context* dimension on top: `C`
//! independent tenants (models, or user sessions carrying per-user
//! fine-tuned weights) share one junction schedule under round-robin
//! admission, their per-tenant state held in a
//! [`crate::hw::context::ContextBank`] and fetched per cycle rather
//! than swapped. Tenant `c`'s cycles are exactly a solo run at stride
//! `C·k` shifted by its admission slot, so interleaved training is
//! bit-identical per context to `C` independent single-tenant runs —
//! the isolation property `tests/prop_context.rs` pins, with fault
//! hooks proving the audits would catch any aliasing or starvation.
//!
//! The hardware model does not just *inspire* this engine — it checks it:
//! construction audits the timetable with
//! [`crate::hw::pipeline::Pipeline::audit`], every junction's weight
//! buffer is replayed through the clash-free banked view
//! ([`crate::hw::banked::BankedWeights`], geometry from
//! [`crate::hw::zconfig::balanced_for_edges`]), and the run *measures*
//! its own weight staleness, which tests compare against the closed form
//! `Pipeline::staleness` / `Pipeline::measured_staleness`.

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::hw::banked::BankedWeights;
use crate::hw::context::{ContextBank, ContextError, ContextFault, ContextId};
use crate::hw::pipeline::{Op, Pipeline};
use crate::hw::zconfig::{self, ZConfig};
use crate::nn::adam::{AdamConfig, AdamState};
use crate::nn::sparse::SparseNet;
use crate::nn::trainer::{EpochStat, History};
use crate::nn::{relu, softmax_ce};
use crate::obs::prof::{Stage, StageProf};
use crate::sparsity::pattern::NetPattern;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Knobs of the pipelined trainer.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Epochs for [`PipelinedTrainer::train`].
    pub epochs: usize,
    /// Minibatch size (every batch is one pipeline input).
    pub batch: usize,
    /// Maximum minibatches in flight: `1` is sequential-equivalent
    /// (bit-for-bit the [`crate::nn::trainer`] loop), `2L` (or `0` =
    /// auto) is the full Fig. 2c schedule with the paper's Sec. III-D
    /// staleness.
    pub depth: usize,
    /// Optimizer configuration (per-junction Adam states, stepped once
    /// per batch per junction exactly like the sequential trainer).
    pub adam: AdamConfig,
    /// L2 penalty coefficient.
    pub l2: f32,
    /// Seed for parameter init and the epoch shuffles.
    pub seed: u64,
    /// Parallelism of the largest junction's banked weight view
    /// (`0` = auto); shapes the audited [`ZConfig`], not the arithmetic.
    pub z0: usize,
    /// Divide the machine's kernel-thread budget by the steady-state
    /// stage count for the duration of each run (restored afterwards,
    /// even on panic). Off by default so tests don't touch the global
    /// override.
    pub tune_kernel_threads: bool,
    /// Record per-junction FF/BP/UP wall time and modelled clocks into
    /// [`PipelinedTrainer::prof`] (CLI: `train --profile`). Off by
    /// default: the disabled path takes zero timestamps.
    pub profile: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            epochs: 12,
            batch: 64,
            depth: 0,
            adam: AdamConfig::default(),
            l2: 1e-4,
            seed: 0,
            z0: 0,
            tune_kernel_threads: false,
            profile: false,
        }
    }
}

/// Execution counters of the pipelined runs so far.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    /// Junction cycles executed.
    pub taus: u64,
    /// FF/BP/UP operations executed.
    pub ops: u64,
    /// Most operations co-scheduled in one junction cycle (steady state
    /// reaches `3L - 1` at full depth).
    pub max_ops_in_tau: usize,
    /// Minibatches retired.
    pub flights: u64,
}

/// One in-flight minibatch and its queued per-layer state (the software
/// analogue of the Table-I activation / a-dot / delta bank queues).
struct Flight {
    x: Vec<f32>,
    y: Vec<i32>,
    batch: usize,
    /// `acts[j]` = activations out of junction j+1 (logits for the last).
    acts: Vec<Option<Vec<f32>>>,
    /// `pre[j]` = pre-activations of junction j+1.
    pre: Vec<Option<Vec<f32>>>,
    /// `delta[j]` = loss gradient at layer j+1.
    delta: Vec<Option<Vec<f32>>>,
    /// Weight version each junction's FF read (staleness probe).
    ff_version: Vec<u64>,
    loss: f32,
    correct: usize,
}

impl Flight {
    fn new(x: Vec<f32>, y: Vec<i32>, l: usize) -> Flight {
        let batch = y.len();
        Flight {
            x,
            y,
            batch,
            acts: vec![None; l],
            pre: vec![None; l],
            delta: vec![None; l],
            ff_version: vec![0; l],
            loss: 0.0,
            correct: 0,
        }
    }

    /// UP_1 was the last operation of this input: drop the queued state.
    fn retire(&mut self) {
        self.x = Vec::new();
        self.y = Vec::new();
        for slot in self.acts.iter_mut().chain(&mut self.pre).chain(&mut self.delta) {
            *slot = None;
        }
    }
}

/// What one operation produced; installed after the junction-cycle
/// barrier (the hardware's end-of-cycle write-back).
enum OpOut {
    Ff {
        pre: Vec<f32>,
        act: Vec<f32>,
        /// Loss head, only from the last junction: (mean loss, #correct,
        /// dlogits).
        head: Option<(f32, usize, Vec<f32>)>,
    },
    Bp {
        dprev: Vec<f32>,
    },
    Up {
        gwc: Vec<f32>,
        gb: Vec<f32>,
    },
}

/// Steady-state staleness observations for one junction.
#[derive(Clone, Copy, Debug, Default)]
struct StalenessProbe {
    value: Option<usize>,
    consistent: bool,
}

/// Restores the kernel-thread override when a pipelined run ends (even
/// by panic), mirroring the inference service's budget handling.
struct ThreadBudgetGuard {
    prev: Option<usize>,
}

impl ThreadBudgetGuard {
    fn pin(concurrent_ops: usize, enable: bool) -> ThreadBudgetGuard {
        if !enable {
            return ThreadBudgetGuard { prev: None };
        }
        let prev = parallel::thread_override();
        parallel::set_threads(parallel::worker_thread_budget(concurrent_ops.max(1)));
        ThreadBudgetGuard { prev: Some(prev) }
    }
}

impl Drop for ThreadBudgetGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            parallel::set_threads(prev);
        }
    }
}

/// The pipelined training engine (see the module docs for the schedule).
pub struct PipelinedTrainer {
    net: SparseNet,
    cfg: PipelineConfig,
    pipe: Pipeline,
    /// Junction cycles between admitted minibatches (1 = full schedule,
    /// 2L = sequential-equivalent).
    stride: usize,
    /// First input index whose staleness is clamp-free (pipeline full).
    warmup: usize,
    opt: Vec<(AdamState, AdamState)>,
    /// UP count per junction (the weight version counters of Sec. III-D).
    versions: Vec<u64>,
    zcfg: ZConfig,
    banked: Vec<BankedWeights>,
    probes: Vec<StalenessProbe>,
    /// Execution counters, cumulative over this trainer's runs.
    pub metrics: PipelineMetrics,
    /// Per-junction FF/BP/UP stage profile, cumulative over this
    /// trainer's runs; recording only when [`PipelineConfig::profile`]
    /// was set. The modelled clock cost per op at junction `j` is the
    /// paper's `ceil(E_j / z_j)` over the audited banked geometry.
    pub prof: StageProf,
}

impl PipelinedTrainer {
    /// He-initialize a compacted net for `pattern` (seeded from
    /// `cfg.seed`, the same init the sequential trainer would perform)
    /// and build the engine. `layers` is the expected neuronal
    /// configuration; mismatched patterns are rejected.
    pub fn from_pattern(
        layers: &[usize],
        pattern: &NetPattern,
        cfg: &PipelineConfig,
    ) -> Result<PipelinedTrainer> {
        let net = init_for_pattern(layers, pattern, cfg)?;
        PipelinedTrainer::new(net, cfg.clone())
    }

    /// [`PipelinedTrainer::from_pattern`] with an explicit admission
    /// stride instead of the depth→stride mapping — the constructor the
    /// multi-tenant interleave uses (each of `C` tenants runs at stride
    /// `C·k`, which `depth` cannot always express) and that parity tests
    /// use to build the solo twin of one tenant.
    pub fn from_pattern_with_stride(
        layers: &[usize],
        pattern: &NetPattern,
        cfg: &PipelineConfig,
        stride: usize,
    ) -> Result<PipelinedTrainer> {
        let net = init_for_pattern(layers, pattern, cfg)?;
        PipelinedTrainer::new_with_stride(net, cfg.clone(), stride)
    }

    /// Build the engine around an existing compacted net (weights are
    /// taken as-is; useful for resuming or for parity tests that
    /// construct the sequential twin from the same init).
    pub fn new(net: SparseNet, cfg: PipelineConfig) -> Result<PipelinedTrainer> {
        let l = net.junctions.len();
        ensure!(l >= 1, "net has no junctions");
        let depth = if cfg.depth == 0 { 2 * l } else { cfg.depth.min(2 * l) };
        let stride = (2 * l).div_ceil(depth);
        PipelinedTrainer::new_with_stride(net, cfg, stride)
    }

    /// [`PipelinedTrainer::new`] with an explicit admission stride:
    /// minibatch `n` is admitted at junction cycle `n·stride + 1`. Any
    /// `stride >= 2L` is sequential-equivalent (a batch retires before
    /// the next is admitted), so staleness is 0 there; `stride = 1` is
    /// the full Fig. 2c schedule.
    pub fn new_with_stride(
        net: SparseNet,
        cfg: PipelineConfig,
        stride: usize,
    ) -> Result<PipelinedTrainer> {
        let l = net.junctions.len();
        ensure!(l >= 1, "net has no junctions");
        ensure!(stride >= 1, "stride must be positive");
        ensure!(cfg.batch > 0, "batch must be positive");
        let edges: Vec<usize> = net.junctions.iter().map(|j| j.n_edges()).collect();
        ensure!(
            edges.iter().all(|&e| e > 0),
            "every junction needs at least one edge"
        );
        let pipe = Pipeline::new(l);
        // the timetable itself must satisfy the paper's structural claims
        pipe.audit((4 * l + 8) as i64)
            .map_err(|e| anyhow::anyhow!("pipeline schedule audit failed: {e}"))?;
        let warmup = (2 * l).div_ceil(stride);
        // banked weight views: balanced z_net over the actual edge counts
        let max_e = *edges.iter().max().unwrap();
        let z0 = if cfg.z0 == 0 { 32 } else { cfg.z0 };
        let c_target = max_e.div_ceil(z0.clamp(1, max_e));
        let zcfg = zconfig::balanced_for_edges(&edges, c_target);
        let banked: Vec<BankedWeights> = edges
            .iter()
            .zip(&zcfg.z)
            .map(|(&e, &z)| BankedWeights::new(e, z))
            .collect();
        for (view, junction) in banked.iter().zip(&net.junctions) {
            view.audit(&junction.wc)
                .map_err(|e| anyhow::anyhow!("banked weight audit failed: {e}"))?;
        }
        let opt = net
            .junctions
            .iter()
            .map(|j| (AdamState::zeros(j.wc.len()), AdamState::zeros(j.bias.len())))
            .collect();
        // modelled clock cost per op: ceil(E_j / z_j) over the audited
        // banked geometry — the same quantity the hw simulator charges
        let cycles_per_op: Vec<u64> = edges
            .iter()
            .zip(&zcfg.z)
            .map(|(&e, &z)| e.div_ceil(z.max(1)) as u64)
            .collect();
        let prof = StageProf::new(cycles_per_op, cfg.profile);
        Ok(PipelinedTrainer {
            probes: vec![StalenessProbe::default(); l],
            versions: vec![0; l],
            opt,
            banked,
            zcfg,
            stride,
            warmup,
            pipe,
            net,
            cfg,
            metrics: PipelineMetrics::default(),
            prof,
        })
    }

    /// The trained network (weights update in place as batches retire).
    pub fn net(&self) -> &SparseNet {
        &self.net
    }

    /// Junction cycles between admitted minibatches (1 = full Fig. 2c
    /// schedule, 2L = sequential-equivalent).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Effective minibatches in flight (`ceil(2L / stride)`).
    pub fn depth(&self) -> usize {
        (2 * self.pipe.l).div_ceil(self.stride)
    }

    /// The balanced banked z_net the weight views were derived from.
    pub fn z_net(&self) -> &ZConfig {
        &self.zcfg
    }

    /// Weight staleness the schedule implies at junction `i` (1-based):
    /// the paper's `2(L-i)+1` divided by the admission stride (0 when
    /// sequential-equivalent).
    pub fn expected_staleness(&self, i: usize) -> usize {
        (2 * (self.pipe.l - i) + 1) / self.stride
    }

    /// Steady-state weight staleness *measured* during the runs so far at
    /// junction `i` (1-based): `None` until the pipeline has filled, or
    /// if the observations were not constant (which would falsify the
    /// schedule model).
    pub fn measured_staleness(&self, i: usize) -> Option<usize> {
        let p = &self.probes[i - 1];
        if p.consistent {
            p.value
        } else {
            None
        }
    }

    /// Re-replay every junction's current weight buffer through its
    /// clash-free banked view (see [`BankedWeights::audit`]).
    pub fn audit_banked(&self) -> Result<()> {
        for (view, junction) in self.banked.iter().zip(&self.net.junctions) {
            view.audit(&junction.wc)
                .map_err(|e| anyhow::anyhow!("banked weight audit failed: {e}"))?;
        }
        Ok(())
    }

    /// Quantized twin of [`PipelinedTrainer::audit_banked`]: quantize
    /// every junction's current weights into `fmt` and replay the raw
    /// Qm.n words through the same banked views
    /// ([`BankedWeights::audit_fixed`]) — the check `train --quant-eval`
    /// runs before reporting quantized accuracy, proving the integer
    /// weight memories obey the identical Fig. 4 layout and port
    /// discipline.
    pub fn audit_banked_quantized(&self, fmt: crate::nn::fixed::QFormat) -> Result<()> {
        for (view, junction) in self.banked.iter().zip(&self.net.junctions) {
            view.audit_fixed(&fmt.quantize_slice(&junction.wc))
                .map_err(|e| anyhow::anyhow!("banked quantized weight audit failed: {e}"))?;
        }
        Ok(())
    }

    /// One epoch over `ds`: shuffle with `rng`, chunk into `cfg.batch`
    /// minibatches (the final partial batch included, like the sequential
    /// trainer), stream them through the pipeline. Returns (mean train
    /// loss, train accuracy).
    pub fn epoch(&mut self, ds: &Dataset, rng: &mut Rng) -> Result<(f32, f64)> {
        let mut order: Vec<usize> = (0..ds.n).collect();
        rng.shuffle(&mut order);
        self.epoch_in_order(ds, &order)
    }

    fn epoch_in_order(&mut self, ds: &Dataset, order: &[usize]) -> Result<(f32, f64)> {
        let l = self.net.junctions.len();
        // eager gather holds one extra copy of the epoch's inputs; only
        // ~depth flights ever carry live activations (retired flights
        // free their buffers), so switch to gathering at FF_1 admission
        // if datasets outgrow the in-repo synthetic scale
        let flights: Vec<Flight> = order
            .chunks(self.cfg.batch)
            .map(|chunk| {
                let (x, y) = ds.gather(chunk);
                Flight::new(x, y, l)
            })
            .collect();
        ensure!(!flights.is_empty(), "dataset is empty");
        let (loss_sum, correct, seen) = self.run_flights(flights);
        Ok((
            (loss_sum / seen as f64) as f32,
            correct as f64 / seen as f64,
        ))
    }

    /// Train for `cfg.epochs`, mirroring [`crate::nn::trainer::train`]'s
    /// shuffle discipline (same seed mix, cumulative order permutation)
    /// so a depth-1 run reproduces the sequential trainer bit for bit.
    pub fn train(&mut self, train_ds: &Dataset, test_ds: &Dataset) -> Result<History> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7261696e);
        let mut order: Vec<usize> = (0..train_ds.n).collect();
        let mut history = History { epochs: Vec::new() };
        for epoch in 0..self.cfg.epochs {
            rng.shuffle(&mut order);
            let (train_loss, train_acc) = self.epoch_in_order(train_ds, &order)?;
            let test_acc = self.evaluate(test_ds);
            history.epochs.push(EpochStat {
                epoch,
                train_loss,
                train_acc,
                test_acc,
            });
        }
        Ok(history)
    }

    /// Chunked test accuracy — the same evaluation loop as the
    /// sequential trainer ([`crate::nn::trainer::evaluate_with`]), so
    /// histories are comparable number for number.
    pub fn evaluate(&self, ds: &Dataset) -> f64 {
        crate::nn::trainer::evaluate_with(ds, |x, y| self.net.accuracy(x, y))
    }

    /// The tau loop: run every junction cycle of the timetable, fanning
    /// the cycle's operations out over stage threads and applying weight
    /// updates at the cycle barrier. Returns (loss sum, correct, seen).
    fn run_flights(&mut self, mut flights: Vec<Flight>) -> (f64, usize, usize) {
        let l = self.net.junctions.len();
        let k = self.stride;
        let nb = flights.len();
        let mut totals = (0f64, 0usize, 0usize);
        if nb == 0 {
            return totals;
        }
        let concurrent = self.pipe.steady_state_ops().div_ceil(k);
        let _budget = ThreadBudgetGuard::pin(concurrent, self.cfg.tune_kernel_threads);
        let last_tau = (nb - 1) * k + 2 * l;
        for tau in 1..=last_tau {
            self.step_tau(tau, &mut flights, &mut totals);
        }
        totals
    }

    /// Assemble junction cycle `tau` from the hw timetable for a run of
    /// `nb` admitted minibatches: FF_i(n) at `tau = n·k + i`,
    /// BP_i/UP_i(n) at `tau = n·k + 2L - i + 1` (k = admission stride).
    fn ops_at(&self, tau: usize, nb: usize) -> Vec<(usize, Op, usize)> {
        let l = self.net.junctions.len();
        let k = self.stride;
        let mut ops: Vec<(usize, Op, usize)> = Vec::with_capacity(3 * l);
        for i in 1..=l {
            if tau >= i && (tau - i) % k == 0 {
                let n = (tau - i) / k;
                if n < nb {
                    ops.push((i, Op::Ff, n));
                }
            }
            let off = 2 * l - i + 1;
            if tau >= off && (tau - off) % k == 0 {
                let n = (tau - off) / k;
                if n < nb {
                    if i >= 2 {
                        ops.push((i, Op::Bp, n));
                    }
                    ops.push((i, Op::Up, n));
                }
            }
        }
        ops
    }

    /// Execute one junction cycle against `flights`: probe the weight
    /// versions FF reads, fan the cycle's operations out over stage
    /// threads, then install results and the deferred UP write-backs at
    /// the cycle barrier (the hardware's end-of-cycle write-back).
    /// Retired-flight totals accumulate into `(loss sum, correct, seen)`.
    ///
    /// This is the unit the multi-tenant interleave replays per tenant:
    /// a solo run is exactly `step_tau(1..=last_tau)` in order, so any
    /// schedule that preserves a tenant's cycle order reproduces its
    /// solo run bit for bit.
    fn step_tau(
        &mut self,
        tau: usize,
        flights: &mut [Flight],
        totals: &mut (f64, usize, usize),
    ) {
        let l = self.net.junctions.len();
        let ops = self.ops_at(tau, flights.len());
        if ops.is_empty() {
            return;
        }
        // staleness probe: note the weight version each FF reads
        for &(i, op, n) in &ops {
            if op == Op::Ff {
                flights[n].ff_version[i - 1] = self.versions[i - 1];
            }
        }
        // all ops in one junction cycle are mutually independent:
        // execute concurrently, reading the cycle-start weights
        let net = &self.net;
        let fl: &[Flight] = flights;
        let l2 = self.cfg.l2;
        // profiling stamps wall time around each op inside its stage
        // thread; disabled, no timestamp is ever taken
        let profiling = self.prof.enabled();
        let timed = move |op: (usize, Op, usize)| {
            let t0 = profiling.then(Instant::now);
            let out = exec_op(net, fl, l2, l, op);
            (out, t0.map(|t| t.elapsed()))
        };
        let results: Vec<(OpOut, Option<Duration>)> = if ops.len() == 1 {
            vec![timed(ops[0])]
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = ops[1..]
                    .iter()
                    .map(|&op| s.spawn(move || timed(op)))
                    .collect();
                let mut out = Vec::with_capacity(ops.len());
                out.push(timed(ops[0]));
                for h in handles {
                    out.push(h.join().expect("pipeline stage panicked"));
                }
                out
            })
        };
        // cycle barrier: install results, then the deferred UP
        // write-backs (so FF/BP of this cycle saw pre-update weights,
        // exactly like the hardware's dual-ported write-back)
        for ((res, wall), &(i, op, n)) in results.into_iter().zip(&ops) {
            if let Some(d) = wall {
                self.prof.record(i, stage_of(op), d);
            }
            let j = i - 1;
            match res {
                OpOut::Ff { pre, act, head } => {
                    let f = &mut flights[n];
                    f.pre[j] = Some(pre);
                    f.acts[j] = Some(act);
                    if let Some((loss, corr, dlogits)) = head {
                        f.loss = loss;
                        f.correct = corr;
                        f.delta[l - 1] = Some(dlogits);
                    }
                }
                OpOut::Bp { dprev } => {
                    flights[n].delta[i - 2] = Some(dprev);
                }
                OpOut::Up { gwc, gb } => {
                    if n >= self.warmup {
                        // the version BP_i(n)/UP_i(n) read this cycle
                        // minus the version FF_i(n) read = staleness
                        let s = (self.versions[j] - flights[n].ff_version[j]) as usize;
                        let probe = &mut self.probes[j];
                        match probe.value {
                            None => {
                                probe.value = Some(s);
                                probe.consistent = true;
                            }
                            Some(prev) if prev != s => probe.consistent = false,
                            Some(_) => {}
                        }
                    }
                    let t = (self.versions[j] + 1) as f32;
                    let junction = &mut self.net.junctions[j];
                    let (sw, sb) = &mut self.opt[j];
                    sw.step(&mut junction.wc, &gwc, t, &self.cfg.adam);
                    sb.step(&mut junction.bias, &gb, t, &self.cfg.adam);
                    self.versions[j] += 1;
                    if i == 1 {
                        // UP_1 is the last op of input n: retire it
                        let f = &mut flights[n];
                        totals.0 += f.loss as f64 * f.batch as f64;
                        totals.1 += f.correct;
                        totals.2 += f.batch;
                        f.retire();
                        self.metrics.flights += 1;
                    }
                }
            }
        }
        self.metrics.taus += 1;
        self.metrics.ops += ops.len() as u64;
        self.metrics.max_ops_in_tau = self.metrics.max_ops_in_tau.max(ops.len());
    }
}

/// Validate `pattern` against the expected neuronal configuration and
/// He-initialize a compacted net from `cfg.seed` (the same init the
/// sequential trainer would perform).
fn init_for_pattern(
    layers: &[usize],
    pattern: &NetPattern,
    cfg: &PipelineConfig,
) -> Result<SparseNet> {
    ensure!(layers.len() >= 2, "need at least input + output layer");
    ensure!(
        pattern.junctions.len() == layers.len() - 1,
        "pattern has {} junctions, net has {}",
        pattern.junctions.len(),
        layers.len() - 1
    );
    for (i, p) in pattern.junctions.iter().enumerate() {
        ensure!(
            p.shape.n_left == layers[i] && p.shape.n_right == layers[i + 1],
            "pattern junction {i} shape mismatch"
        );
    }
    let mut rng = Rng::new(cfg.seed);
    Ok(SparseNet::init_he(pattern, 0.1, &mut rng))
}

/// Per-context parameter seed: context 0 keeps `seed` unchanged (a
/// single-context run is bit-for-bit the single-tenant run), every
/// further context mixes in a golden-ratio stride so tenants start from
/// independent initializations (the "per-user delta" of the serving
/// story).
pub fn context_seed(seed: u64, context: usize) -> u64 {
    seed ^ (context as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The multi-tenant pipelined trainer: `C` independent tenant contexts
/// interleaved through one junction schedule (see the module docs).
///
/// Admission is round-robin over the contexts: global minibatch `g`
/// belongs to the context in admission slot `g mod C`, so each tenant's
/// own batches are `C·k` junction cycles apart (`k` = the global
/// admission stride from [`PipelineConfig::depth`]) and the per-context
/// staleness law is `floor((2(L-i)+1) / (C·k))` — measured and exposed
/// via [`MultiPipelinedTrainer::measured_staleness`]. Per-tenant state
/// (weights, Adam accumulators, version counters) lives in a
/// [`ContextBank`] fetched once per tenant per junction cycle;
/// [`MultiPipelinedTrainer::audit_contexts`] proves every fetch hit its
/// own tenant's bank.
pub struct MultiPipelinedTrainer {
    tenants: ContextBank<PipelinedTrainer>,
    /// Junction cycles between *global* (tenant-interleaved) admissions.
    k: usize,
    /// Admission order: round-robin slot `s` admits `admission[s]`.
    admission: Vec<ContextId>,
}

impl MultiPipelinedTrainer {
    /// Build `contexts` tenants over one shared `pattern` (one parsed
    /// manifest entry serves every tenant): tenant `c` He-initializes
    /// from [`context_seed`]`(cfg.seed, c)` and runs at stride
    /// `contexts · k`. A single context reproduces
    /// [`PipelinedTrainer::from_pattern`] exactly.
    pub fn from_pattern(
        layers: &[usize],
        pattern: &NetPattern,
        cfg: &PipelineConfig,
        contexts: usize,
    ) -> Result<MultiPipelinedTrainer> {
        ensure!(contexts >= 1, "need at least one context");
        ensure!(layers.len() >= 2, "need at least input + output layer");
        let l = layers.len() - 1;
        let depth = if cfg.depth == 0 { 2 * l } else { cfg.depth.min(2 * l) };
        let k = (2 * l).div_ceil(depth);
        let mut tenants = Vec::with_capacity(contexts);
        for c in 0..contexts {
            let mut tcfg = cfg.clone();
            tcfg.seed = context_seed(cfg.seed, c);
            tenants.push(PipelinedTrainer::from_pattern_with_stride(
                layers,
                pattern,
                &tcfg,
                contexts * k,
            )?);
        }
        Ok(MultiPipelinedTrainer {
            tenants: ContextBank::new(tenants),
            k,
            admission: (0..contexts).collect(),
        })
    }

    /// Override the round-robin admission order (must be a permutation
    /// of the contexts). Isolation is order-independent — the property
    /// tests randomize this to prove it.
    pub fn with_admission(mut self, order: Vec<ContextId>) -> Result<MultiPipelinedTrainer> {
        let contexts = self.tenants.contexts();
        ensure!(
            order.len() == contexts,
            "admission order must name every context once"
        );
        let mut seen = vec![false; contexts];
        for &c in &order {
            ensure!(
                c < contexts && !seen[c],
                "admission order must be a permutation of 0..{contexts}"
            );
            seen[c] = true;
        }
        self.admission = order;
        Ok(self)
    }

    /// Number of tenant contexts sharing the schedule.
    pub fn contexts(&self) -> usize {
        self.tenants.contexts()
    }

    /// Junction cycles between each tenant's own admissions (`C·k`).
    pub fn stride(&self) -> usize {
        self.tenants.contexts() * self.k
    }

    /// Read access to tenant `c`'s underlying trainer (metrics, nets,
    /// staleness probes).
    ///
    /// # Panics
    /// If `c` is out of range.
    pub fn tenant(&self, c: ContextId) -> &PipelinedTrainer {
        self.tenants.peek(c).expect("context out of range")
    }

    /// Tenant `c`'s trained network.
    pub fn net(&self, c: ContextId) -> &SparseNet {
        self.tenant(c).net()
    }

    /// Per-context staleness the schedule implies at junction `i`
    /// (1-based) for tenant `c`: `floor((2(L-i)+1) / (C·k))`.
    pub fn expected_staleness(&self, c: ContextId, i: usize) -> usize {
        self.tenant(c).expected_staleness(i)
    }

    /// Steady-state staleness *measured* for tenant `c` at junction `i`
    /// during the runs so far (see
    /// [`PipelinedTrainer::measured_staleness`]).
    pub fn measured_staleness(&self, c: ContextId, i: usize) -> Option<usize> {
        self.tenant(c).measured_staleness(i)
    }

    /// Replay the context-fetch log: every per-cycle state fetch must
    /// have hit its own tenant's bank (no aliasing, no starved tenant).
    /// The error names the offending context.
    pub fn audit_contexts(&self) -> Result<(), ContextError> {
        self.tenants.audit()
    }

    /// Merged FF/BP/UP stage profile over every tenant (stage-wise
    /// sums; per-tenant profiles stay readable via
    /// [`MultiPipelinedTrainer::tenant`]`(c).prof`).
    pub fn profile_merged(&self) -> StageProf {
        let mut total = StageProf::disabled();
        for t in self.tenants.iter() {
            total.merge(&t.prof);
        }
        total
    }

    /// Replay every tenant's weight buffers through their clash-free
    /// banked views (see [`PipelinedTrainer::audit_banked`]).
    pub fn audit_banked(&self) -> Result<()> {
        for t in self.tenants.iter() {
            t.audit_banked()?;
        }
        Ok(())
    }

    /// Install a context-fetch defect on the tenant state bank
    /// (test-only hook for the non-vacuity battery).
    #[doc(hidden)]
    pub fn inject_fault(&mut self, fault: ContextFault) {
        self.tenants.inject_fault(fault);
    }

    /// Train every tenant for `cfg.epochs` over the shared datasets,
    /// interleaved through one schedule. Each tenant shuffles with its
    /// own seeded rng and accumulates its own history — bit-for-bit
    /// what `C` solo [`PipelinedTrainer::train`] runs at stride `C·k`
    /// would produce (the isolation property).
    pub fn train(&mut self, train_ds: &Dataset, test_ds: &Dataset) -> Result<Vec<History>> {
        let contexts = self.tenants.contexts();
        let epochs = self.tenant(0).cfg.epochs;
        let mut rngs: Vec<Rng> = (0..contexts)
            .map(|c| Rng::new(self.tenant(c).cfg.seed ^ 0x7261696e))
            .collect();
        let mut orders: Vec<Vec<usize>> = vec![(0..train_ds.n).collect(); contexts];
        let mut histories: Vec<History> = (0..contexts)
            .map(|_| History { epochs: Vec::new() })
            .collect();
        for epoch in 0..epochs {
            for (rng, order) in rngs.iter_mut().zip(&mut orders) {
                rng.shuffle(order);
            }
            let stats = self.epoch_in_orders(train_ds, &orders)?;
            for (c, history) in histories.iter_mut().enumerate() {
                let test_acc = self.tenant(c).evaluate(test_ds);
                history.epochs.push(EpochStat {
                    epoch,
                    train_loss: stats[c].0,
                    train_acc: stats[c].1,
                    test_acc,
                });
            }
        }
        Ok(histories)
    }

    /// One interleaved epoch with explicit per-tenant sample orders.
    /// Returns per-tenant (mean train loss, train accuracy).
    fn epoch_in_orders(
        &mut self,
        ds: &Dataset,
        orders: &[Vec<usize>],
    ) -> Result<Vec<(f32, f64)>> {
        let contexts = self.tenants.contexts();
        let mut flights: Vec<Vec<Flight>> = Vec::with_capacity(contexts);
        for (c, order) in orders.iter().enumerate() {
            let t = self.tenant(c);
            let l = t.net.junctions.len();
            let fl: Vec<Flight> = order
                .chunks(t.cfg.batch)
                .map(|chunk| {
                    let (x, y) = ds.gather(chunk);
                    Flight::new(x, y, l)
                })
                .collect();
            ensure!(!fl.is_empty(), "dataset is empty");
            flights.push(fl);
        }
        let totals = self.run_interleaved(flights);
        Ok(totals
            .iter()
            .map(|&(loss, corr, seen)| {
                ((loss / seen as f64) as f32, corr as f64 / seen as f64)
            })
            .collect())
    }

    /// The global tau loop: at global junction cycle `T`, the tenant in
    /// admission slot `s` executes its local cycle `T - s·k` — every
    /// tenant advances through exactly the cycle sequence of its solo
    /// run, fetched from the context bank per cycle, with zero idle
    /// cycles between tenants once the interleave is full.
    fn run_interleaved(&mut self, mut flights: Vec<Vec<Flight>>) -> Vec<(f64, usize, usize)> {
        let contexts = self.tenants.contexts();
        let k = self.k;
        let kk = contexts * k;
        let admission = self.admission.clone();
        let first = self.tenant(0);
        let l = first.net.junctions.len();
        // the interleave carries the aggregate op load of a stride-k
        // single-tenant run, so pin the same kernel-thread budget
        let concurrent = first.pipe.steady_state_ops().div_ceil(k);
        let tune = first.cfg.tune_kernel_threads;
        let _budget = ThreadBudgetGuard::pin(concurrent, tune);
        let mut totals = vec![(0f64, 0usize, 0usize); contexts];
        // tenant c's last local cycle; slot s shifts it by s·k globally
        let last_local: Vec<usize> = flights
            .iter()
            .map(|fl| if fl.is_empty() { 0 } else { (fl.len() - 1) * kk + 2 * l })
            .collect();
        let global_last = admission
            .iter()
            .enumerate()
            .map(|(s, &c)| last_local[c] + s * k)
            .max()
            .unwrap_or(0);
        for tau_g in 1..=global_last {
            for (s, &c) in admission.iter().enumerate() {
                let offset = s * k;
                if tau_g <= offset {
                    continue;
                }
                let lt = tau_g - offset;
                if lt > last_local[c] {
                    continue;
                }
                if let Some(tenant) = self.tenants.fetch_mut(c) {
                    tenant.step_tau(lt, &mut flights[c], &mut totals[c]);
                }
            }
        }
        totals
    }
}

/// Map a scheduled hw op onto its profiling stage.
fn stage_of(op: Op) -> Stage {
    match op {
        Op::Ff => Stage::Ff,
        Op::Bp => Stage::Bp,
        Op::Up => Stage::Up,
    }
}

/// Execute one scheduled operation against the cycle-start state. Reads
/// only; every write (activations, deltas, weight updates) is installed
/// at the cycle barrier by the caller.
fn exec_op(
    net: &SparseNet,
    flights: &[Flight],
    l2: f32,
    l: usize,
    (i, op, n): (usize, Op, usize),
) -> OpOut {
    let junction = &net.junctions[i - 1];
    let f = &flights[n];
    let batch = f.batch;
    match op {
        Op::Ff => {
            let a_in: &[f32] = if i == 1 {
                &f.x
            } else {
                f.acts[i - 2].as_deref().expect("FF input not ready")
            };
            let mut h = vec![0f32; batch * junction.n_right];
            junction.forward(a_in, batch, &mut h);
            let pre = h.clone();
            let head = if i == l {
                // the loss head rides on the last junction's FF slot
                let (loss, corr, dlogits) = softmax_ce(&h, &f.y, junction.n_right);
                Some((loss, corr, dlogits))
            } else {
                relu(&mut h);
                None
            };
            OpOut::Ff { pre, act: h, head }
        }
        Op::Bp => {
            let d = f.delta[i - 1].as_deref().expect("BP delta not ready");
            let mut da = vec![0f32; batch * junction.n_left];
            junction.backprop(d, batch, &mut da);
            // fold the ReLU derivative of layer i-1 into the handoff
            let pre_prev = f.pre[i - 2].as_deref().expect("BP pre-activations not ready");
            for (dv, &hv) in da.iter_mut().zip(pre_prev) {
                if hv <= 0.0 {
                    *dv = 0.0;
                }
            }
            OpOut::Bp { dprev: da }
        }
        Op::Up => {
            // eq. (4b) over the *queued* left activations of input n
            let a_in: &[f32] = if i == 1 {
                &f.x
            } else {
                f.acts[i - 2].as_deref().expect("UP activations not queued")
            };
            let d = f.delta[i - 1].as_deref().expect("UP delta not ready");
            let mut gwc = vec![0f32; junction.wc.len()];
            let mut gb = vec![0f32; junction.n_right];
            junction.grads(a_in, d, batch, l2, &mut gwc, &mut gb);
            OpOut::Up { gwc, gb }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Spec;
    use crate::sparsity::config::{DoutConfig, NetConfig};
    use crate::sparsity::{generate, Method};

    fn toy_pattern(layers: &[usize], dout: &[usize], seed: u64) -> NetPattern {
        let netc = NetConfig::new(layers.to_vec());
        let mut rng = Rng::new(seed);
        generate(
            Method::Structured,
            &netc,
            &DoutConfig(dout.to_vec()),
            None,
            &mut rng,
        )
    }

    #[test]
    fn depth_maps_to_stride() {
        let pattern = toy_pattern(&[12, 10, 6], &[5, 3], 0);
        let mk = |depth| {
            PipelinedTrainer::from_pattern(
                &[12, 10, 6],
                &pattern,
                &PipelineConfig {
                    depth,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        // L = 2: full schedule = 4 in flight
        assert_eq!(mk(0).stride(), 1);
        assert_eq!(mk(0).depth(), 4);
        assert_eq!(mk(1).stride(), 4);
        assert_eq!(mk(1).depth(), 1);
        assert_eq!(mk(2).stride(), 2);
        assert_eq!(mk(99).stride(), 1);
        // expected staleness: full schedule = paper closed form, depth 1 = 0
        let full = mk(0);
        assert_eq!(full.expected_staleness(1), 3);
        assert_eq!(full.expected_staleness(2), 1);
        let seq = mk(1);
        assert_eq!(seq.expected_staleness(1), 0);
        assert_eq!(seq.expected_staleness(2), 0);
    }

    #[test]
    fn single_batch_matches_reference_step_loss() {
        // one minibatch through the pipeline = one fused reference step
        let layers = [12usize, 10, 6];
        let pattern = toy_pattern(&layers, &[5, 3], 1);
        let mut rng = Rng::new(2);
        let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
        let mut xr = Rng::new(3);
        let x: Vec<f32> = (0..8 * 12).map(|_| xr.normal()).collect();
        let y: Vec<i32> = (0..8).map(|_| xr.below(6) as i32).collect();
        let reference = snet.step(&x, &y, 8, 1e-4);

        let mut trainer = PipelinedTrainer::new(
            snet.clone(),
            PipelineConfig {
                batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let flights = vec![Flight::new(x, y, 2)];
        let (loss_sum, correct, seen) = trainer.run_flights(flights);
        assert_eq!(seen, 8);
        assert_eq!(correct, reference.correct);
        assert!((loss_sum / 8.0 - reference.loss as f64).abs() < 1e-6);
        // one update per junction happened
        assert_eq!(trainer.versions, vec![1, 1]);
        trainer.audit_banked().unwrap();
    }

    #[test]
    fn interleaved_contexts_match_solo_runs_bit_for_bit() {
        use crate::hw::context::{ContextError, ContextFault};
        let layers = [12usize, 10, 6];
        let pattern = toy_pattern(&layers, &[5, 3], 7);
        let spec = Spec {
            name: "ctx-toy",
            features: 12,
            classes: 6,
            latent_dim: 5,
            shaping: crate::data::Shaping::Continuous,
            separation: 2.0,
            noise: 0.5,
        };
        let mut drng = Rng::new(11);
        let train_ds = spec.generate(40, &mut drng);
        let test_ds = spec.generate(16, &mut drng);
        let cfg = PipelineConfig {
            epochs: 2,
            batch: 8,
            depth: 0,
            seed: 9,
            ..Default::default()
        };
        let contexts = 3;
        let mut multi =
            MultiPipelinedTrainer::from_pattern(&layers, &pattern, &cfg, contexts).unwrap();
        let histories = multi.train(&train_ds, &test_ds).unwrap();
        multi.audit_contexts().unwrap();
        multi.audit_banked().unwrap();
        for c in 0..contexts {
            let mut tcfg = cfg.clone();
            tcfg.seed = context_seed(cfg.seed, c);
            let mut solo = PipelinedTrainer::from_pattern_with_stride(
                &layers,
                &pattern,
                &tcfg,
                multi.stride(),
            )
            .unwrap();
            let solo_history = solo.train(&train_ds, &test_ds).unwrap();
            for (a, b) in histories[c].epochs.iter().zip(&solo_history.epochs) {
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "ctx {c}");
                assert_eq!(a.train_acc, b.train_acc, "ctx {c}");
            }
            for (ja, jb) in multi.net(c).junctions.iter().zip(&solo.net().junctions) {
                for (wa, wb) in ja.wc.iter().zip(&jb.wc) {
                    assert_eq!(wa.to_bits(), wb.to_bits(), "ctx {c} weight bleed");
                }
                for (ba, bb) in ja.bias.iter().zip(&jb.bias) {
                    assert_eq!(ba.to_bits(), bb.to_bits(), "ctx {c} bias bleed");
                }
            }
        }
        // non-vacuity: aliasing two contexts onto one bank is caught,
        // naming the aliased context
        let mut bad =
            MultiPipelinedTrainer::from_pattern(&layers, &pattern, &cfg, contexts).unwrap();
        bad.inject_fault(ContextFault::Alias { from: 1, to: 0 });
        bad.train(&train_ds, &test_ds).unwrap();
        let err = bad.audit_contexts().unwrap_err();
        assert_eq!(
            err,
            ContextError::Aliased {
                requested: 1,
                effective: 0
            }
        );
        assert_eq!(err.context(), Some(1));
    }

    #[test]
    fn single_context_interleave_is_the_single_tenant_trainer() {
        let layers = [12usize, 10, 6];
        let pattern = toy_pattern(&layers, &[5, 3], 3);
        let spec = Spec {
            name: "ctx-one",
            features: 12,
            classes: 6,
            latent_dim: 5,
            shaping: crate::data::Shaping::Continuous,
            separation: 2.0,
            noise: 0.5,
        };
        let mut drng = Rng::new(13);
        let train_ds = spec.generate(32, &mut drng);
        let test_ds = spec.generate(16, &mut drng);
        let cfg = PipelineConfig {
            epochs: 2,
            batch: 8,
            seed: 4,
            ..Default::default()
        };
        let mut multi =
            MultiPipelinedTrainer::from_pattern(&layers, &pattern, &cfg, 1).unwrap();
        let mut solo = PipelinedTrainer::from_pattern(&layers, &pattern, &cfg).unwrap();
        assert_eq!(multi.stride(), solo.stride());
        let mh = multi.train(&train_ds, &test_ds).unwrap();
        let sh = solo.train(&train_ds, &test_ds).unwrap();
        multi.audit_contexts().unwrap();
        for (a, b) in mh[0].epochs.iter().zip(&sh.epochs) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_acc, b.test_acc);
        }
        for (ja, jb) in multi.net(0).junctions.iter().zip(&solo.net().junctions) {
            for (wa, wb) in ja.wc.iter().zip(&jb.wc) {
                assert_eq!(wa.to_bits(), wb.to_bits());
            }
        }
    }

    #[test]
    fn steady_state_reaches_full_operational_parallelism() {
        let layers = [12usize, 10, 8, 6];
        let pattern = toy_pattern(&layers, &[5, 4, 3], 4);
        let mut trainer = PipelinedTrainer::from_pattern(
            &layers,
            &pattern,
            &PipelineConfig {
                batch: 4,
                depth: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let spec = Spec {
            name: "toy",
            features: 12,
            classes: 6,
            latent_dim: 5,
            shaping: crate::data::Shaping::Continuous,
            separation: 2.0,
            noise: 0.5,
        };
        let mut rng = Rng::new(5);
        let ds = spec.generate(48, &mut rng); // 12 batches >> 2L = 6
        let mut erng = Rng::new(6);
        trainer.epoch(&ds, &mut erng).unwrap();
        // L = 3: steady state co-schedules 3L - 1 = 8 ops per cycle
        assert_eq!(trainer.metrics.max_ops_in_tau, 8);
        assert_eq!(trainer.metrics.flights, 12);
        // every junction saw one update per batch
        assert_eq!(trainer.versions, vec![12, 12, 12]);
        // profiling was off: zero junction geometry is still reported,
        // but nothing was recorded and no timestamps were taken
        assert!(!trainer.prof.enabled());
        assert_eq!(trainer.prof.total_cycles(), 0);
    }

    #[test]
    fn profile_accounts_for_every_scheduled_op() {
        let layers = [12usize, 10, 6];
        let pattern = toy_pattern(&layers, &[5, 3], 8);
        let mut trainer = PipelinedTrainer::from_pattern(
            &layers,
            &pattern,
            &PipelineConfig {
                batch: 8,
                depth: 0,
                profile: true,
                ..Default::default()
            },
        )
        .unwrap();
        let spec = Spec {
            name: "prof-toy",
            features: 12,
            classes: 6,
            latent_dim: 5,
            shaping: crate::data::Shaping::Continuous,
            separation: 2.0,
            noise: 0.5,
        };
        let mut rng = Rng::new(17);
        let ds = spec.generate(32, &mut rng);
        let mut erng = Rng::new(18);
        trainer.epoch(&ds, &mut erng).unwrap();
        // every op the scheduler executed is in the profile, per stage
        let profiled_ops: u64 = (1..=trainer.prof.junctions())
            .flat_map(|j| Stage::ALL.iter().map(move |&s| (j, s)))
            .map(|(j, s)| trainer.prof.stage(j, s).ops)
            .sum();
        assert_eq!(profiled_ops, trainer.metrics.ops);
        // the modelled clock charge matches ceil(E/z) per junction
        for (j, (junction, &z)) in trainer
            .net
            .junctions
            .iter()
            .zip(&trainer.zcfg.z)
            .enumerate()
        {
            assert_eq!(
                trainer.prof.cycles_per_op(j + 1),
                junction.n_edges().div_ceil(z.max(1)) as u64
            );
        }
        assert!(trainer.prof.total_cycles() > 0);
        assert!(trainer.prof.total_wall() > Duration::ZERO);
    }
}
