//! Masked-dense MLP (eqs. 2-4 with a fixed 0/1 mask per junction).
//!
//! Used for FC baselines and for the §V-B LSS comparison (which must start
//! fully connected and prune during training). The invariant maintained
//! throughout: `w[i]` is always element-wise masked, so excluded edges are
//! exactly zero at every step — the pre-defined sparsity contract.

use super::matrix;
use crate::util::rng::Rng;

/// Masked-dense MLP state.
#[derive(Clone, Debug)]
pub struct DenseNet {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub layers: Vec<usize>,
    /// Weights per junction, row-major [n_right, n_left].
    pub w: Vec<Vec<f32>>,
    /// Biases per junction.
    pub b: Vec<Vec<f32>>,
    /// 0/1 masks per junction (all-ones = FC).
    pub masks: Vec<Vec<f32>>,
}

/// Gradients in the same layout as (w, b).
#[derive(Clone, Debug)]
pub struct Grads {
    /// Weight gradients per junction (masked).
    pub gw: Vec<Vec<f32>>,
    /// Bias gradients per junction.
    pub gb: Vec<Vec<f32>>,
}

/// Result of one forward+backward pass.
pub struct StepOut {
    /// Mean softmax cross-entropy of the minibatch.
    pub loss: f32,
    /// Correct argmax predictions in the minibatch.
    pub correct: usize,
    /// Loss gradients (regularizers included, masks applied).
    pub grads: Grads,
}

impl DenseNet {
    /// He-initialized [45] network with constant bias (Sec. IV-A), all-ones
    /// masks (FC).
    pub fn init_he(layers: &[usize], bias_init: f32, rng: &mut Rng) -> Self {
        let mut w = Vec::new();
        let mut b = Vec::new();
        let mut masks = Vec::new();
        for i in 1..layers.len() {
            let (nl, nr) = (layers[i - 1], layers[i]);
            let std = (2.0 / nl as f32).sqrt();
            w.push((0..nr * nl).map(|_| rng.normal() * std).collect());
            b.push(vec![bias_init; nr]);
            masks.push(vec![1.0; nr * nl]);
        }
        DenseNet {
            layers: layers.to_vec(),
            w,
            b,
            masks,
        }
    }

    /// Number of junctions L.
    pub fn n_junctions(&self) -> usize {
        self.layers.len() - 1
    }

    /// Install masks (and zero the excluded weights).
    pub fn set_masks(&mut self, masks: Vec<Vec<f32>>) {
        assert_eq!(masks.len(), self.n_junctions());
        self.masks = masks;
        self.apply_masks();
    }

    /// Re-zero every excluded weight (the pre-defined sparsity contract).
    pub fn apply_masks(&mut self) {
        for (w, m) in self.w.iter_mut().zip(&self.masks) {
            for (wv, &mv) in w.iter_mut().zip(m) {
                *wv *= mv;
            }
        }
    }

    /// Forward pass; returns activations per layer (a[0] = input) and
    /// pre-activations per junction.
    pub fn forward(&self, x: &[f32], batch: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let l = self.n_junctions();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l + 1);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(l);
        acts.push(x.to_vec());
        for i in 0..l {
            let (nl, nr) = (self.layers[i], self.layers[i + 1]);
            let mut h = vec![0f32; batch * nr];
            matrix::matmul_nt(&acts[i], &self.w[i], batch, nl, nr, &mut h);
            matrix::add_bias(&mut h, &self.b[i], batch, nr);
            pre.push(h.clone());
            if i != l - 1 {
                super::relu(&mut h);
            }
            acts.push(h);
        }
        (acts, pre)
    }

    /// Logits only (inference).
    pub fn logits(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let (acts, _) = self.forward(x, batch);
        acts.last().unwrap().clone()
    }

    /// Full forward + backward: softmax-CE loss with L2 penalty `l2` and
    /// optional per-junction L1 penalty `l1` (the §V-B LSS term). Gradients
    /// are masked, so Adam state of excluded edges stays zero.
    pub fn step(&self, x: &[f32], y: &[i32], batch: usize, l2: f32, l1: Option<&[f32]>) -> StepOut {
        let l = self.n_junctions();
        let classes = *self.layers.last().unwrap();
        let (acts, pre) = self.forward(x, batch);
        let (loss, correct, dlogits) = super::softmax_ce(acts.last().unwrap(), y, classes);

        let mut gw: Vec<Vec<f32>> = self.w.iter().map(|w| vec![0f32; w.len()]).collect();
        let mut gb: Vec<Vec<f32>> = self.b.iter().map(|b| vec![0f32; b.len()]).collect();
        let mut dh = dlogits;
        for i in (0..l).rev() {
            let (nl, nr) = (self.layers[i], self.layers[i + 1]);
            // eq. (4b): dW = dh^T @ a_{i-1} (+ regularizers), masked
            matrix::matmul_tn_acc(&dh, &acts[i], batch, nr, nl, 1.0, &mut gw[i]);
            for j in 0..nr {
                let mut acc = 0f32;
                for bi in 0..batch {
                    acc += dh[bi * nr + j];
                }
                gb[i][j] = acc;
            }
            for (idx, g) in gw[i].iter_mut().enumerate() {
                let wv = self.w[i][idx];
                *g += 2.0 * l2 * wv;
                if let Some(gammas) = l1 {
                    *g += gammas[i] * wv.signum() * if wv == 0.0 { 0.0 } else { 1.0 };
                }
                *g *= self.masks[i][idx];
            }
            if i > 0 {
                // eq. (3b): da = dh @ W, then multiply by relu'(h_{i-1})
                let mut da = vec![0f32; batch * nl];
                matrix::matmul_nn(&dh, &self.w[i], batch, nr, nl, &mut da);
                for (dv, &hv) in da.iter_mut().zip(&pre[i - 1]) {
                    if hv <= 0.0 {
                        *dv = 0.0;
                    }
                }
                dh = da;
            }
        }
        StepOut {
            loss,
            correct,
            grads: Grads { gw, gb },
        }
    }

    /// Classification accuracy over a dataset slice.
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let batch = y.len();
        let classes = *self.layers.last().unwrap();
        let logits = self.logits(x, batch);
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / batch as f64
    }

    /// §V-B LSS finalization: keep the per-junction top-|W_i|*rho weights
    /// by magnitude, zero the rest, install the induced mask.
    pub fn prune_to_density(&mut self, rho: &[f64]) {
        assert_eq!(rho.len(), self.n_junctions());
        for i in 0..self.n_junctions() {
            let w = &mut self.w[i];
            let keep = ((w.len() as f64) * rho[i]).round() as usize;
            let mut mags: Vec<(f32, usize)> =
                w.iter().enumerate().map(|(idx, v)| (v.abs(), idx)).collect();
            mags.sort_by(|a, b| b.0.total_cmp(&a.0));
            let mut mask = vec![0f32; w.len()];
            for &(_, idx) in mags.iter().take(keep) {
                mask[idx] = 1.0;
            }
            for (wv, &mv) in w.iter_mut().zip(&mask) {
                *wv *= mv;
            }
            self.masks[i] = mask;
        }
    }

    /// Density of each junction as induced by the installed masks.
    pub fn mask_densities(&self) -> Vec<f64> {
        self.masks
            .iter()
            .map(|m| m.iter().filter(|&&v| v == 1.0).count() as f64 / m.len() as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(seed: u64) -> (DenseNet, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let net = DenseNet::init_he(&[6, 5, 4], 0.1, &mut rng);
        let x: Vec<f32> = (0..8 * 6).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..8).map(|_| rng.below(4) as i32).collect();
        (net, x, y)
    }

    #[test]
    fn numerical_gradient_check() {
        let (mut net, x, y) = toy(0);
        // random mask to exercise the masked path
        let mut rng = Rng::new(1);
        let masks: Vec<Vec<f32>> = net
            .masks
            .iter()
            .map(|m| m.iter().map(|_| if rng.uniform() < 0.6 { 1.0 } else { 0.0 }).collect())
            .collect();
        net.set_masks(masks);
        let l2 = 0.01;
        let out = net.step(&x, &y, 8, l2, None);
        let eps = 1e-3;
        let loss_at = |net: &DenseNet| {
            let o = net.step(&x, &y, 8, 0.0, None);
            let pen: f32 = net.w.iter().map(|w| w.iter().map(|v| v * v).sum::<f32>()).sum();
            o.loss + l2 * pen
        };
        for (ji, wlen) in [(0usize, 30usize), (1, 20)] {
            for &idx in &[0usize, wlen / 2, wlen - 1] {
                let mut net2 = net.clone();
                net2.w[ji][idx] += eps;
                let lp = loss_at(&net2);
                net2.w[ji][idx] -= 2.0 * eps;
                let lm = loss_at(&net2);
                let num = (lp - lm) / (2.0 * eps);
                let ana = out.grads.gw[ji][idx];
                // masked entries must have zero analytic grad
                if net.masks[ji][idx] == 0.0 {
                    assert_eq!(ana, 0.0);
                } else {
                    assert!(
                        (num - ana).abs() < 2e-2 * (1.0 + num.abs()),
                        "junction {ji} idx {idx}: num {num} vs ana {ana}"
                    );
                }
            }
        }
        // bias grads
        for ji in 0..2 {
            let mut net2 = net.clone();
            net2.b[ji][0] += eps;
            let lp = loss_at(&net2);
            net2.b[ji][0] -= 2.0 * eps;
            let lm = loss_at(&net2);
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - out.grads.gb[ji][0]).abs() < 2e-2 * (1.0 + num.abs()));
        }
    }

    #[test]
    fn masked_weights_stay_zero() {
        let (mut net, x, y) = toy(2);
        let masks: Vec<Vec<f32>> = net
            .masks
            .iter()
            .map(|m| m.iter().enumerate().map(|(i, _)| (i % 3 == 0) as u8 as f32).collect())
            .collect();
        net.set_masks(masks);
        let out = net.step(&x, &y, 8, 0.01, None);
        for (ji, gw) in out.grads.gw.iter().enumerate() {
            for (idx, g) in gw.iter().enumerate() {
                if net.masks[ji][idx] == 0.0 {
                    assert_eq!(*g, 0.0);
                    assert_eq!(net.w[ji][idx], 0.0);
                }
            }
        }
    }

    #[test]
    fn prune_to_density_keeps_largest() {
        let (mut net, _, _) = toy(3);
        net.w[0] = (0..30).map(|i| i as f32 / 30.0).collect();
        net.prune_to_density(&[0.2, 1.0]);
        let d = net.mask_densities();
        assert!((d[0] - 0.2).abs() < 0.05);
        assert_eq!(d[1], 1.0);
        // survivors are the 6 largest
        for i in 0..24 {
            assert_eq!(net.w[0][i], 0.0);
        }
        for i in 24..30 {
            assert!(net.w[0][i] > 0.0);
        }
    }

    #[test]
    fn l1_term_adds_sign_subgradient() {
        let (net, x, y) = toy(4);
        let base = net.step(&x, &y, 8, 0.0, None);
        let lss = net.step(&x, &y, 8, 0.0, Some(&[0.5, 0.0]));
        for idx in 0..net.w[0].len() {
            let want = base.grads.gw[0][idx] + 0.5 * net.w[0][idx].signum();
            assert!((lss.grads.gw[0][idx] - want).abs() < 1e-6);
        }
        for idx in 0..net.w[1].len() {
            assert!((lss.grads.gw[1][idx] - base.grads.gw[1][idx]).abs() < 1e-6);
        }
    }

    #[test]
    fn accuracy_is_fractional_correct() {
        let (net, x, y) = toy(5);
        let acc = net.accuracy(&x, &y);
        assert!((0.0..=1.0).contains(&acc));
    }
}
