//! Training loop for the native nets: epochs, shuffled minibatches, Adam,
//! L2 / LSS-L1 regularization, and the Sec. III-D pipeline-staleness
//! emulation (UP applied 2(L-i)+1 steps late, per junction).

use std::collections::VecDeque;

use super::adam::{Adam, AdamConfig};
use super::dense::DenseNet;
use super::sparse::SparseNet;
use crate::data::Dataset;
use crate::util::rng::Rng;

/// Either backend: masked-dense (FC / LSS) or compacted CSR (pre-defined
/// sparse patterns — compute proportional to |W|).
pub enum Network {
    /// Masked-dense backend (FC baselines, §V-B LSS).
    Dense(DenseNet),
    /// Compacted CSR backend (pre-defined sparse patterns).
    Sparse(SparseNet),
}

impl Network {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub fn layers(&self) -> &[usize] {
        match self {
            Network::Dense(n) => &n.layers,
            Network::Sparse(n) => &n.layers,
        }
    }

    /// Classification accuracy over one batch.
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        match self {
            Network::Dense(n) => n.accuracy(x, y),
            Network::Sparse(n) => n.accuracy(x, y),
        }
    }

    /// Trainable parameter count (weights + biases actually stored).
    pub fn n_params(&self) -> usize {
        match self {
            Network::Dense(n) => n
                .masks
                .iter()
                .map(|m| m.iter().filter(|&&v| v == 1.0).count())
                .sum::<usize>()
                + n.b.iter().map(|b| b.len()).sum::<usize>(),
            Network::Sparse(n) => {
                n.n_edges() + n.junctions.iter().map(|j| j.bias.len()).sum::<usize>()
            }
        }
    }
}

/// Sequential training-loop configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Epochs to run.
    pub epochs: usize,
    /// Minibatch size (the final partial batch is trained too).
    pub batch: usize,
    /// Optimizer hyperparameters.
    pub adam: AdamConfig,
    /// L2 penalty coefficient (the paper reduces it with sparsity since
    /// sparse nets overfit less, Sec. IV-A).
    pub l2: f32,
    /// Per-junction L1 penalty gammas: the §V-B LSS objective (dense only).
    pub l1: Option<Vec<f32>>,
    /// Seed for the epoch shuffles.
    pub seed: u64,
    /// Emulate the hardware pipeline's delayed updates (Sec. III-D) by
    /// queueing each junction's gradients `2(L-i)+1` steps. The
    /// `nn::pipeline` engine *runs* that schedule instead of emulating it.
    pub stale_updates: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 12,
            batch: 64,
            adam: AdamConfig::default(),
            l2: 1e-4,
            l1: None,
            seed: 0,
            stale_updates: false,
        }
    }
}

/// Scale the L2 coefficient down with density, mirroring Sec. IV-A's
/// "reduced the L2 penalty coefficient with increasing sparsity".
pub fn l2_for_density(base: f32, rho_net: f64) -> f32 {
    base * rho_net as f32
}

/// Metrics of one training epoch.
#[derive(Clone, Debug)]
pub struct EpochStat {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean train loss over the epoch's minibatches.
    pub train_loss: f32,
    /// Train-set accuracy over the epoch.
    pub train_acc: f64,
    /// Test accuracy after the epoch.
    pub test_acc: f64,
}

/// Per-epoch metrics of one training run.
#[derive(Clone, Debug)]
pub struct History {
    /// One entry per epoch, in order.
    pub epochs: Vec<EpochStat>,
}

impl History {
    /// Test accuracy after the last epoch (0.0 for an empty run).
    pub fn final_test_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    /// Best test accuracy seen across the run.
    pub fn best_test_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f64::max)
    }
}

/// Chunked accuracy over a whole dataset for any batch-accuracy
/// function — the single evaluation loop shared by the sequential and
/// pipelined trainers, so their test-accuracy numbers stay comparable
/// chunk for chunk.
pub fn evaluate_with(ds: &Dataset, mut batch_acc: impl FnMut(&[f32], &[i32]) -> f64) -> f64 {
    let chunk = 512;
    let mut correct = 0f64;
    let mut i = 0;
    while i < ds.n {
        let hi = (i + chunk).min(ds.n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, y) = ds.gather(&idx);
        correct += batch_acc(&x, &y) * (hi - i) as f64;
        i = hi;
    }
    correct / ds.n as f64
}

/// Chunked accuracy over a whole dataset.
pub fn evaluate(net: &Network, ds: &Dataset) -> f64 {
    evaluate_with(ds, |x, y| net.accuracy(x, y))
}

/// Train `net` on `train_ds`, reporting test accuracy each epoch.
pub fn train(
    net: &mut Network,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
) -> History {
    let shapes: Vec<(usize, usize)> = match net {
        Network::Dense(n) => n
            .w
            .iter()
            .zip(&n.b)
            .map(|(w, b)| (w.len(), b.len()))
            .collect(),
        Network::Sparse(n) => n
            .junctions
            .iter()
            .map(|j| (j.wc.len(), j.bias.len()))
            .collect(),
    };
    let l = shapes.len();
    let mut opt = Adam::new(cfg.adam, &shapes);
    let mut rng = Rng::new(cfg.seed ^ 0x7261696e);
    let mut order: Vec<usize> = (0..train_ds.n).collect();
    // staleness FIFOs: junction i (0-based) delays by 2(L-(i+1))+1 steps
    let mut queues: Vec<VecDeque<(Vec<f32>, Vec<f32>)>> = (0..l).map(|_| VecDeque::new()).collect();
    let depth = |i: usize| 2 * (l - (i + 1)) + 1;

    let mut history = History { epochs: Vec::new() };
    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0f64;
        let mut correct = 0usize;
        let mut seen = 0usize;
        for chunk in order.chunks(cfg.batch) {
            let (x, y) = train_ds.gather(chunk);
            let batch = chunk.len();
            let (loss, corr, mut gw, mut gb) = match net {
                Network::Dense(n) => {
                    let out = n.step(&x, &y, batch, cfg.l2, cfg.l1.as_deref());
                    (out.loss, out.correct, out.grads.gw, out.grads.gb)
                }
                Network::Sparse(n) => {
                    let out = n.step(&x, &y, batch, cfg.l2);
                    (out.loss, out.correct, out.grads.gwc, out.grads.gb)
                }
            };
            loss_sum += loss as f64 * batch as f64;
            correct += corr;
            seen += batch;
            if cfg.stale_updates {
                // push fresh grads; apply the delayed ones (zeros during
                // pipeline warmup — junction i's first updates are skipped)
                for i in 0..l {
                    queues[i].push_back((std::mem::take(&mut gw[i]), std::mem::take(&mut gb[i])));
                    if queues[i].len() > depth(i) {
                        let (dgw, dgb) = queues[i].pop_front().unwrap();
                        gw[i] = dgw;
                        gb[i] = dgb;
                    } else {
                        gw[i] = vec![0.0; shapes[i].0];
                        gb[i] = vec![0.0; shapes[i].1];
                    }
                }
            }
            match net {
                Network::Dense(n) => {
                    opt.step(&mut n.w, &mut n.b, &gw, &gb);
                    n.apply_masks();
                }
                Network::Sparse(n) => {
                    let mut ws: Vec<Vec<f32>> = n
                        .junctions
                        .iter_mut()
                        .map(|j| std::mem::take(&mut j.wc))
                        .collect();
                    let mut bs: Vec<Vec<f32>> = n
                        .junctions
                        .iter_mut()
                        .map(|j| std::mem::take(&mut j.bias))
                        .collect();
                    opt.step(&mut ws, &mut bs, &gw, &gb);
                    for ((j, w), b) in n.junctions.iter_mut().zip(ws).zip(bs) {
                        j.wc = w;
                        j.bias = b;
                    }
                }
            }
        }
        let test_acc = evaluate(net, test_ds);
        history.epochs.push(EpochStat {
            epoch,
            train_loss: (loss_sum / seen as f64) as f32,
            train_acc: correct as f64 / seen as f64,
            test_acc,
        });
    }
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Spec;
    use crate::sparsity::config::{DoutConfig, NetConfig};
    use crate::sparsity::{generate, Method};

    fn tiny_data() -> (Dataset, Dataset) {
        let spec = Spec {
            name: "toy",
            features: 16,
            classes: 4,
            latent_dim: 6,
            shaping: crate::data::Shaping::Continuous,
            separation: 3.0,
            noise: 0.3,
        };
        let s = spec.splits(400, 0, 120, 11);
        (s.train, s.test)
    }

    #[test]
    fn dense_fc_learns() {
        let (train_ds, test_ds) = tiny_data();
        let mut rng = Rng::new(0);
        let mut net = Network::Dense(DenseNet::init_he(&[16, 24, 4], 0.1, &mut rng));
        let cfg = TrainConfig {
            epochs: 8,
            batch: 32,
            ..Default::default()
        };
        let h = train(&mut net, &train_ds, &test_ds, &cfg);
        assert!(
            h.final_test_acc() > 0.8,
            "FC acc {} (chance 0.25)",
            h.final_test_acc()
        );
        assert!(h.epochs[0].train_loss > h.epochs.last().unwrap().train_loss);
    }

    #[test]
    fn sparse_backend_learns_comparably() {
        let (train_ds, test_ds) = tiny_data();
        let netc = NetConfig::new(vec![16, 24, 4]);
        let dout = DoutConfig(vec![12, 2]);
        let mut rng = Rng::new(1);
        let pattern = generate(Method::Structured, &netc, &dout, None, &mut rng);
        let mut net = Network::Sparse(SparseNet::init_he(&pattern, 0.1, &mut rng));
        let cfg = TrainConfig {
            epochs: 16,
            batch: 32,
            ..Default::default()
        };
        let h = train(&mut net, &train_ds, &test_ds, &cfg);
        assert!(h.final_test_acc() > 0.7, "sparse acc {}", h.final_test_acc());
    }

    #[test]
    fn stale_updates_do_not_break_training() {
        // Sec. III-D: "we found no performance degradation due to this
        // variation from the standard backpropagation algorithm"
        let (train_ds, test_ds) = tiny_data();
        let mut rng = Rng::new(2);
        let mut net = Network::Dense(DenseNet::init_he(&[16, 24, 24, 4], 0.1, &mut rng));
        let cfg = TrainConfig {
            epochs: 8,
            batch: 32,
            stale_updates: true,
            ..Default::default()
        };
        let h = train(&mut net, &train_ds, &test_ds, &cfg);
        assert!(h.final_test_acc() > 0.75, "stale acc {}", h.final_test_acc());
    }

    #[test]
    fn n_params_counts_stored_values() {
        let netc = NetConfig::new(vec![16, 8, 4]);
        let dout = DoutConfig(vec![4, 2]);
        let mut rng = Rng::new(3);
        let pattern = generate(Method::Structured, &netc, &dout, None, &mut rng);
        let net = Network::Sparse(SparseNet::init_he(&pattern, 0.1, &mut rng));
        assert_eq!(net.n_params(), 16 * 4 + 8 * 2 + 8 + 4);
    }

    #[test]
    fn l2_for_density_scales() {
        assert_eq!(l2_for_density(1e-3, 1.0), 1e-3);
        assert!(l2_for_density(1e-3, 0.2) < 3e-4);
    }
}
